//! Continuous-batching scheduler over the shared KV block pool.
//!
//! Each scheduling **round** in the default paged mode:
//!
//! 1. **Admit** a bounded burst of waiting requests against the pool's
//!    block budget: every active sequence is charged its worst-case
//!    final footprint in blocks, so admitted work can always grow to
//!    completion without exhausting the [`BlockPool`]. A request larger
//!    than the whole budget is force-admitted when the engine is idle
//!    (the pool's hard cap fits one `max_seq` sequence) — no livelock.
//! 2. **Batched prefill**: every admitted prompt first attaches any
//!    cached prefix blocks ([`BlockPool::attach_prefix`] — shared
//!    prompt prefixes are *not recomputed*), then all prompt suffixes
//!    run through **one** fused ragged forward
//!    ([`Model::forward_paged`]): one GEMM per linear layer for the
//!    whole admission burst, amortizing the (compressed) weight streams
//!    at admission exactly as PR 1's fused decode amortizes them per
//!    round. `BatchPolicy::batched_prefill = false` prefills one prompt
//!    at a time as the A/B baseline.
//! 3. **Fused decode**: one token for every active sequence in a single
//!    ragged batch (same `forward_paged`, one-token slices).
//! 4. **Retire** completed sequences, releasing their blocks — frozen
//!    prefix blocks stay cached in the pool for future prompt hits
//!    until LRU eviction reclaims them.
//!
//! `BatchPolicy::batched_decode = false` switches the whole scheduler
//! to the legacy per-sequence baseline (private chunked [`KvCache`]s,
//! one batch-1 forward per sequence, byte-budget admission) — the
//! benchmark's comparison arm and a live equivalence check: greedy
//! outputs are bit-identical across both modes.
//!
//! # Preemptive scheduling (`BatchPolicy::preempt`)
//!
//! Worst-case reservation is safe but wastes exactly the capacity that
//! compressed KV buys back: a sequence that *might* reach `max_seq` is
//! charged for it from round one, so the pool refuses work it could
//! actually hold. With `preempt = true` the scheduler oversubscribes —
//! admission charges only **resident** blocks — and manages the
//! resulting pressure by swapping sequences out and back in. Every
//! request then moves through this state machine:
//!
//! ```text
//!            admit (resident-block budget)          retire
//! waiting ───────────────────────────────▶ active ─────────▶ retired
//!                                          ▲    │
//!                                   resume │    │ preempt (KV pressure)
//!                        (FIFO, before any │    ▼
//!                          new admission)  └─ swapped
//! ```
//!
//! * **active → swapped** — before prefill and before every decode
//!   batch the scheduler checks that the round's staged rows fit the
//!   pool's [`BlockPool::headroom_blocks`]; while they don't, the
//!   lowest-priority active sequence (newest [`InFlight::arrival`], so
//!   the oldest work never starves) is suspended: its tail bytes move
//!   into a [`Snapshot`](crate::kv::Snapshot), its blocks return to the
//!   pool (frozen prefix blocks stay shareable in the content index),
//!   and it parks in a FIFO swapped queue. A sequence resumed within
//!   the last `resume_hysteresis_rounds` rounds is skipped (anti-thrash)
//!   unless it is the only candidate left.
//! * **swapped → active** — at the top of each round, swapped sequences
//!   re-enter FIFO while they fit the head-room; while any sequence is
//!   swapped, **no new request is admitted** (mid-flight work drains
//!   first — together with newest-first victims this is the
//!   no-starvation guarantee). Resume re-attaches surviving cached
//!   prefix blocks, re-installs the snapshot bytes, and — f32 pools
//!   only — re-prefills any LRU-evicted middle bit-exactly
//!   (`resume_reprefill_tokens` counts that work). If the pool is too
//!   tight but nothing is active, the head is force-resumed: the hard
//!   cap guarantees one `max_seq` sequence always fits, so there is no
//!   livelock.
//!
//! Suspend/resume is **byte-exact** (f32: verbatim rows + row-
//! independent kernels; quantized: the snapshot owns every block's
//! codes and scales), so greedy output with preemption on is
//! bit-identical to an unconstrained-pool run — `tests/preemption.rs`
//! stress-pins this for every `KvDtype` × drafter combination.

use std::collections::VecDeque;
use std::time::Instant;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{InFlight, Request, Response};
use crate::kv::{BlockPool, BlockTable, KvDtype, KvScratch, Snapshot};
use crate::model::generate::KvCache;
use crate::model::{Model, ModelConfig};
use crate::spec::SpecPolicy;
use crate::swap::{self, SwapConfig, SwapVerdict};
use crate::util::par::par_chunks_mut;

/// Disjoint `&mut BlockTable` borrows of the selected (ascending)
/// active sequences, handed to `body` — the split-borrow dance every
/// fused paged call in a round shares.
fn with_tables<R>(
    active: &mut [InFlight],
    idxs: &[usize],
    body: impl FnOnce(&mut [&mut BlockTable]) -> R,
) -> R {
    let mut tbs: Vec<&mut BlockTable> = Vec::with_capacity(idxs.len());
    let mut rest: &mut [InFlight] = active;
    let mut base = 0usize;
    for &i in idxs {
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(i - base + 1);
        tbs.push(head[i - base].table.as_mut().expect("prefilled"));
        rest = tail;
        base = i + 1;
    }
    body(&mut tbs)
}

/// Where a parked sequence's KV waits — the tier the victim cost model
/// ([`crate::swap::choose`]) picked for it at suspend time.
enum Parked {
    /// [`Snapshot`] held in host memory (the default tier).
    Resident(Snapshot),
    /// Serialized through [`crate::kv::wire`] into the configured
    /// [`crate::swap::SwapDir`], keyed by request id; only the
    /// committed length stays behind for the resume head-room check.
    Spilled { len: usize },
    /// Dropped outright (f32 pools only): resume replays the committed
    /// token history through the model, bit-exactly.
    Dropped { tokens: Vec<u8>, max_tokens: usize },
}

impl Parked {
    /// Committed sequence length, however the KV is parked.
    fn len(&self) -> usize {
        match self {
            Parked::Resident(s) => s.len(),
            Parked::Spilled { len } => *len,
            Parked::Dropped { tokens, .. } => tokens.len(),
        }
    }
}

/// A preempted (or migrated-in) sequence parked off-pool: its
/// in-flight request state plus wherever its swapped-out KV went.
struct Swapped {
    f: InFlight,
    park: Parked,
}

/// Scheduler over a (possibly compressed) model.
pub struct Scheduler<'m> {
    model: &'m Model,
    pub policy: BatchPolicy,
    active: Vec<InFlight>,
    /// Preempted sequences awaiting swap-in, FIFO. Resumed ahead of any
    /// new admission (no starvation of mid-flight work).
    swapped: VecDeque<Swapped>,
    pool: BlockPool,
    /// Dequant staging arena shared by every paged forward this
    /// scheduler issues — buffers are grown once and reused across
    /// rounds, so steady-state decode does no per-round allocation
    /// (pinned by [`KvScratch::alloc_events`] in `tests/qattn.rs`).
    scratch: KvScratch,
    /// Speculative decode policy (paged mode only): draft → fused
    /// verify → accept/rollback per round. `None` = plain decode.
    spec: Option<SpecPolicy>,
    /// Tiered spill policy consulted at every preemption; the default
    /// keeps every snapshot resident (PR 5 behavior).
    swap: SwapConfig,
    /// Monotonic round counter (paged mode) — the hysteresis clock.
    round_idx: u64,
    /// Monotonic admission stamp — the preemption priority order.
    arrival_seq: u64,
    /// Weight bytes one full forward streams / avoids
    /// ([`Model::weight_stream_bytes`]) — precomputed once (the model
    /// is immutable behind `&'m`), added to the metrics at every
    /// forward call site. Analytic accounting: deterministic, no
    /// hot-loop counters, and identical for fused and per-sequence
    /// schedules per *forward call* — which is exactly the point: the
    /// fused paths issue fewer calls.
    w_stream_per_fwd: u64,
    w_avoid_per_fwd: u64,
    pub metrics: Metrics,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m Model, policy: BatchPolicy) -> Self {
        Self::with_spec(model, policy, None)
    }

    /// Scheduler with an optional speculative-decode policy. Only the
    /// paged mode speculates; the legacy per-sequence baseline has no
    /// rollback story, so a policy handed to it is dropped here (and
    /// metrics honestly report `spec = "off"` rather than a drafter
    /// that never fires). Greedy output is bit-identical with
    /// speculation on or off — only the number of forward rounds
    /// changes.
    pub fn with_spec(model: &'m Model, policy: BatchPolicy, spec: Option<SpecPolicy>) -> Self {
        let mut policy = policy;
        let spec = if policy.batched_decode { spec } else { None };
        // Like speculation, preemption is a paged-mode feature: the
        // legacy baseline has no snapshot/restore story.
        if !policy.batched_decode {
            policy.preempt = false;
        }
        // Policy override first, model default second — the pool's
        // block geometry (and hence the admission budget) is fixed at
        // engine construction.
        let dtype = policy.kv_dtype.unwrap_or(model.cfg.kv_dtype);
        let mut pool = BlockPool::with_dtype(&model.cfg, policy.kv_budget_bytes, dtype);
        if let Some(n) = policy.max_resident_blocks {
            pool.clamp_budget_blocks(n);
        }
        let metrics = Metrics {
            kv_dtype: dtype.tag().to_string(),
            spec_drafter: spec.as_ref().map(|s| s.name()).unwrap_or("off").to_string(),
            pool_budget_blocks: pool.budget_blocks(),
            pool_block_bytes: pool.block_bytes(),
            ..Default::default()
        };
        let (w_stream_per_fwd, w_avoid_per_fwd) = model.weight_stream_bytes();
        Scheduler {
            model,
            policy,
            active: Vec::new(),
            swapped: VecDeque::new(),
            pool,
            scratch: KvScratch::new(),
            spec,
            swap: SwapConfig::default(),
            round_idx: 0,
            arrival_seq: 0,
            w_stream_per_fwd,
            w_avoid_per_fwd,
            metrics,
        }
    }

    /// Configure the tiered spill policy ([`crate::swap`]) consulted at
    /// every preemption. The default keeps every snapshot resident.
    pub fn set_swap(&mut self, cfg: SwapConfig) {
        self.swap = cfg;
    }

    /// Account `n` full weight streams (one per forward call issued).
    fn note_weight_stream(&mut self, n: u64) {
        self.metrics.record_weight_stream(n * self.w_stream_per_fwd, n * self.w_avoid_per_fwd);
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Sequences currently swapped out awaiting resume.
    pub fn swapped(&self) -> usize {
        self.swapped.len()
    }

    /// The shared KV block pool (paged mode's memory substrate).
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Whether any work remains (active, swapped-out, or waiting).
    pub fn has_work(&self, batcher: &Batcher) -> bool {
        !self.active.is_empty() || !self.swapped.is_empty() || batcher.waiting() > 0
    }

    /// Cancel an in-flight request mid-flight, reclaiming its KV now.
    ///
    /// An **active** sequence releases its block table back to the pool
    /// — the exact teardown retirement uses, so frozen prefix blocks
    /// stay cached/shareable and partial tail blocks free immediately.
    /// A **swapped** sequence just drops its off-pool [`Snapshot`] (its
    /// blocks already went back at suspend time). Returns `false` when
    /// the id is not in flight here (still queued in the `Batcher`,
    /// already completed, or unknown) — queue-stage cancellation is the
    /// caller's job ([`Batcher::cancel`]). A cancelled request never
    /// produces a [`Response`].
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.active.iter().position(|f| f.req.id == id) {
            let mut f = self.active.remove(i);
            if let Some(tb) = f.table.take() {
                self.metrics.cancel_freed_blocks += tb.block_ids().len() as u64;
                self.pool.release(tb);
            }
            self.metrics.requests_cancelled += 1;
            self.metrics.tokens_cancelled += f.generated.len() as u64;
            return true;
        }
        if let Some(i) = self.swapped.iter().position(|s| s.f.req.id == id) {
            let s = self.swapped.remove(i).expect("position() indexed into swapped");
            if matches!(s.park, Parked::Spilled { .. }) {
                if let Some(dir) = self.swap.dir.as_ref() {
                    dir.discard(s.f.req.id);
                }
            }
            self.metrics.requests_cancelled += 1;
            self.metrics.tokens_cancelled += s.f.generated.len() as u64;
            return true;
        }
        false
    }

    /// Per-token streaming hook for front-ends: calls `f` with every
    /// in-flight sequence's `(request id, tokens generated so far)` —
    /// active and swapped alike, in no particular order. Sequences that
    /// retired this round are *not* here; their final token vectors
    /// come back from [`Self::round`] as [`Response`]s.
    pub fn for_each_progress(&self, mut f: impl FnMut(u64, &[u8])) {
        for fl in &self.active {
            f(fl.req.id, &fl.generated);
        }
        for s in &self.swapped {
            f(s.f.req.id, &s.f.generated);
        }
    }

    /// Actual KV bytes resident: pool residency (paged) plus chunked
    /// caches (legacy mode).
    pub fn kv_bytes_in_use(&self) -> usize {
        self.pool.bytes_in_use()
            + self.active.iter().filter_map(|f| f.cache.as_ref()).map(|c| c.bytes()).sum::<usize>()
    }

    /// Legacy mode: KV bytes charged against the admission budget —
    /// each active sequence's actual residency or admission-time
    /// projection, whichever is larger.
    pub fn kv_bytes_reserved(&self) -> usize {
        self.active
            .iter()
            .map(|f| {
                let actual = f.cache.as_ref().map(|c| c.bytes()).unwrap_or(0);
                actual.max(f.kv_projected)
            })
            .sum()
    }

    /// Legacy mode: projected eventual KV residency of a request — its
    /// (clamped) prompt plus full decode budget, chunk-aligned.
    pub fn projected_kv_bytes(&self, req: &Request) -> usize {
        let cfg = &self.model.cfg;
        let prompt = req.prompt.len().min(cfg.max_seq - 1);
        let tokens = (prompt + req.max_new_tokens).min(cfg.max_seq);
        KvCache::bytes_for_tokens(cfg, tokens)
    }

    /// Paged mode: worst-case final footprint of a waiting request in
    /// pool blocks (clamped prompt + full decode budget).
    fn blocks_for_request(pool: &BlockPool, cfg: &ModelConfig, req: &Request) -> usize {
        let prompt = req.prompt.len().min(cfg.max_seq - 1);
        pool.blocks_for_tokens((prompt + req.max_new_tokens).min(cfg.max_seq))
    }

    /// Paged mode: blocks an active sequence is charged — its
    /// worst-case final footprint, so growth can never exhaust the pool.
    fn blocks_reserved(&self, f: &InFlight) -> usize {
        let len = f.table.as_ref().map(|t| t.len()).unwrap_or(0);
        self.pool.blocks_for_tokens((len + f.remaining()).min(self.model.cfg.max_seq))
    }

    /// Preempt mode: admission charge of a waiting request — the blocks
    /// its prompt needs *now* plus one decode row, not its worst-case
    /// final footprint (growth is handled by preemption, not refusal).
    fn blocks_for_admission(pool: &BlockPool, cfg: &ModelConfig, req: &Request) -> usize {
        let prompt = req.prompt.len().min(cfg.max_seq - 1);
        pool.blocks_for_tokens(prompt + 1)
    }

    // ---- preemption: swap-out / swap-in (paged mode, `policy.preempt`) ----

    /// Swap in swapped-out sequences, FIFO, while they fit the pool's
    /// head-room and the `max_active` width. A head that does not fit
    /// waits (no queue-jumping); if nothing at all is active it is
    /// **force-resumed** — the pool's hard cap fits one `max_seq`
    /// sequence, so the engine can always make progress.
    fn resume_swapped(&mut self) {
        loop {
            let Some(head) = self.swapped.front() else { return };
            if self.active.len() >= self.policy.max_active {
                return;
            }
            let max_seq = self.model.cfg.max_seq;
            let (need, have) = if self.policy.preempt {
                // +1: the first post-resume decode row must also fit.
                let need = self.pool.blocks_for_tokens((head.park.len() + 1).min(max_seq));
                (need, self.pool.headroom_blocks())
            } else {
                // A migrated-in sequence resuming on a non-preempt
                // engine is held to that engine's admission rule —
                // worst-case final footprint against unreserved budget
                // — so growth can never exhaust the pool.
                let fin = (head.park.len() + head.f.remaining()).min(max_seq);
                let reserved: usize = self.active.iter().map(|f| self.blocks_reserved(f)).sum();
                let need = self.pool.blocks_for_tokens(fin);
                (need, self.pool.budget_blocks().saturating_sub(reserved))
            };
            if need > have && !self.active.is_empty() {
                return;
            }
            let Swapped { mut f, park } = self.swapped.pop_front().expect("peeked");
            let want = park.len();
            let tb = match park {
                Parked::Resident(snap) => self.resume_snapshot(&snap),
                Parked::Spilled { .. } => {
                    let snap = self.restore_spilled(f.req.id);
                    self.resume_snapshot(&snap)
                }
                Parked::Dropped { tokens, max_tokens } => self.replay_dropped(&tokens, max_tokens),
            };
            debug_assert_eq!(tb.len(), want, "resume rebuilt the wrong length");
            f.table = Some(tb);
            f.resumed_round = Some(self.round_idx);
            self.metrics.resumes += 1;
            self.active.push(f);
        }
    }

    /// Rebuild a table from an in-memory [`Snapshot`], re-prefilling
    /// any LRU-evicted middle (f32 pools) bit-exactly.
    fn resume_snapshot(&mut self, snap: &Snapshot) -> BlockTable {
        let model = self.model;
        let (mut tb, ready) = self.pool.resume(snap);
        if ready < snap.len() {
            // Evicted-middle fallback (f32 pools): recompute the
            // missing rows through the normal paged forward — rows
            // are verbatim and kernels row-independent, so the
            // rebuilt KV is bit-identical to what was swapped out.
            let missing = &snap.tokens()[ready..];
            let _ = model.forward_paged_in(
                &[missing],
                &mut self.pool,
                &mut [&mut tb],
                &mut self.scratch,
            );
            self.metrics.resume_reprefill_tokens += missing.len() as u64;
            self.note_weight_stream(1);
        }
        tb
    }

    /// Read one spilled sequence back from the swap dir and decode it.
    /// A failed read or decode is unrecoverable — for quantized pools
    /// the bytes exist nowhere else — so fail loudly rather than
    /// silently corrupt the sequence.
    fn restore_spilled(&mut self, id: u64) -> Snapshot {
        let t0 = Instant::now();
        let dir = self.swap.dir.as_ref().expect("spilled sequences require a swap dir");
        let bytes =
            dir.restore(id).unwrap_or_else(|e| panic!("swap restore of seq {id} failed: {e}"));
        let snap = self
            .pool
            .snapshot_from_wire(&bytes)
            .unwrap_or_else(|e| panic!("swap decode of seq {id} failed: {e}"));
        self.metrics.restores += 1;
        self.metrics.restored_bytes += bytes.len() as u64;
        self.metrics.restore_time += t0.elapsed();
        snap
    }

    /// Rebuild a dropped sequence by replay: re-attach whatever of its
    /// chain is still cached, then recompute the suffix in one fused
    /// forward — bit-exact on the f32 pools this tier is restricted to.
    fn replay_dropped(&mut self, tokens: &[u8], max_tokens: usize) -> BlockTable {
        let model = self.model;
        let mut tb = BlockTable::new(max_tokens);
        let shared = self.pool.attach_cached(&mut tb, tokens);
        let missing = &tokens[shared..];
        let _ =
            model.forward_paged_in(&[missing], &mut self.pool, &mut [&mut tb], &mut self.scratch);
        self.metrics.resume_reprefill_tokens += missing.len() as u64;
        self.note_weight_stream(1);
        tb
    }

    /// Swap out active sequences (lowest priority first) until the pool
    /// has head-room for `need` new blocks or only `min_active`
    /// sequences remain. Two passes: hysteresis-respecting first, then
    /// — only if still short — ignoring it.
    fn make_headroom(&mut self, need: usize, min_active: usize) {
        while self.pool.headroom_blocks() < need {
            if !self.preempt_one(min_active, false) && !self.preempt_one(min_active, true) {
                return;
            }
        }
    }

    /// Head-room for the coming decode batch: every decodable sequence
    /// may stage up to `1 + k` rows (its input token plus drafts), so
    /// preempt until the worst-case new-block demand fits. Stops at one
    /// survivor — a single sequence always fits under the hard cap.
    fn make_decode_headroom(&mut self) {
        let k = self.spec.as_ref().map(|s| s.k).unwrap_or(0);
        loop {
            let need: usize = self
                .active
                .iter()
                .filter(|f| f.decodable())
                .map(|f| {
                    let tb = f.table.as_ref().expect("active sequences are prefilled");
                    let staged = (tb.len() + 1 + k).min(tb.capacity());
                    self.pool.blocks_for_tokens(staged) - tb.block_ids().len()
                })
                .sum();
            if need <= self.pool.headroom_blocks() {
                return;
            }
            if !self.preempt_one(1, false) && !self.preempt_one(1, true) {
                return;
            }
        }
    }

    /// Suspend one active sequence — the **lowest-priority** victim:
    /// newest `arrival` stamp, skipping sequences resumed within the
    /// hysteresis window unless `ignore_hysteresis`, never an
    /// undecodable sequence (it retires and frees its blocks this round
    /// anyway), and never below `min_active` survivors. Returns whether
    /// a victim was swapped out.
    fn preempt_one(&mut self, min_active: usize, ignore_hysteresis: bool) -> bool {
        if self.active.len() <= min_active {
            return false;
        }
        let hyst = self.policy.resume_hysteresis_rounds as u64;
        let mut victim: Option<usize> = None;
        for (i, f) in self.active.iter().enumerate() {
            if f.table.is_none() || !f.decodable() {
                continue;
            }
            if !ignore_hysteresis
                && f.resumed_round.is_some_and(|r| self.round_idx.saturating_sub(r) < hyst)
            {
                continue;
            }
            if victim.is_none_or(|v| f.arrival > self.active[v].arrival) {
                victim = Some(i);
            }
        }
        let Some(i) = victim else { return false };
        let mut f = self.active.remove(i);
        let tb = f.table.take().expect("victims carry tables");
        let snap = self.pool.suspend(tb);
        f.preempt_count += 1;
        self.metrics.preemptions += 1;
        self.metrics.swap_bytes += snap.bytes() as u64;
        let park = self.park(f.req.id, snap);
        self.swapped.push_back(Swapped { f, park });
        true
    }

    /// Host bytes currently held by resident snapshots — what the
    /// spill cost model budgets against.
    fn resident_snapshot_bytes(&self) -> usize {
        self.swapped
            .iter()
            .map(|s| match &s.park {
                Parked::Resident(snap) => snap.bytes(),
                _ => 0,
            })
            .sum()
    }

    /// Park one freshly suspended snapshot in the tier the victim cost
    /// model picks: resident (default), spilled to disk through the
    /// wire format, or dropped for bit-exact replay (f32 pools only).
    /// A disk write failure degrades to resident — spilling is an
    /// optimization, never a correctness dependency.
    fn park(&mut self, id: u64, snap: Snapshot) -> Parked {
        let exact = swap::reprefill_is_exact(self.pool.dtype());
        match swap::choose(&self.swap, self.resident_snapshot_bytes(), &snap, exact) {
            SwapVerdict::Resident => Parked::Resident(snap),
            SwapVerdict::Spill => {
                let (wire, raw, enc) = self.pool.snapshot_to_wire_ex(&snap, self.swap.codec);
                let dir = self.swap.dir.as_ref().expect("Spill verdict implies a dir");
                match dir.spill(id, &wire) {
                    Ok(()) => {
                        self.metrics.spills += 1;
                        self.metrics.spilled_bytes += wire.len() as u64;
                        self.metrics.codec_raw_bytes += raw;
                        self.metrics.codec_encoded_bytes += enc;
                        Parked::Spilled { len: snap.len() }
                    }
                    Err(_) => Parked::Resident(snap),
                }
            }
            SwapVerdict::Reprefill => {
                self.metrics.reprefill_drops += 1;
                let Snapshot { tokens, max_tokens, .. } = snap;
                Parked::Dropped { tokens, max_tokens }
            }
        }
    }

    // ---- cross-engine migration (suspend here, resume elsewhere) ----

    /// Migrate-out: pull one in-flight sequence out of this engine
    /// entirely. An active sequence is suspended exactly as
    /// preemption's swap-out (blocks return to the pool, frozen prefix
    /// blocks stay cached); an already-parked one is materialized back
    /// to a [`Snapshot`], reading the swap dir or replaying a dropped
    /// f32 history as needed. Returns `None` when the id is not in
    /// flight here. Serializing the snapshot for the wire
    /// ([`BlockPool::snapshot_to_wire`]) is the caller's job.
    pub fn extract(&mut self, id: u64) -> Option<(InFlight, Snapshot)> {
        if let Some(i) = self.active.iter().position(|f| f.req.id == id) {
            let mut f = self.active.remove(i);
            let tb = f.table.take().expect("active sequences are prefilled");
            let snap = self.pool.suspend(tb);
            self.metrics.migrations_out += 1;
            return Some((f, snap));
        }
        let i = self.swapped.iter().position(|s| s.f.req.id == id)?;
        let Swapped { f, park } =
            self.swapped.remove(i).expect("position() indexed into swapped");
        let snap = match park {
            Parked::Resident(snap) => snap,
            Parked::Spilled { .. } => self.restore_spilled(f.req.id),
            Parked::Dropped { tokens, max_tokens } => {
                let tb = self.replay_dropped(&tokens, max_tokens);
                self.pool.suspend(tb)
            }
        };
        self.metrics.migrations_out += 1;
        Some((f, snap))
    }

    /// Migrate-in: hand this engine a sequence extracted elsewhere. It
    /// parks in the swapped queue (resident tier) and re-enters through
    /// the normal resume machinery ahead of any new admission — the
    /// same expect-guarded attach + re-install (+ f32 re-prefill) path
    /// that makes preemption byte-exact makes migration byte-exact.
    /// Paged mode only: the legacy baseline has no snapshot story.
    pub fn inject(&mut self, mut f: InFlight, snap: Snapshot) {
        assert!(self.policy.batched_decode, "migration needs the paged scheduler");
        f.table = None;
        f.cache = None;
        f.arrival = self.arrival_seq;
        self.arrival_seq += 1;
        f.resumed_round = None;
        self.metrics.migrations_in += 1;
        self.swapped.push_back(Swapped { f, park: Parked::Resident(snap) });
    }

    /// One scheduling round. Returns completed responses.
    pub fn round(&mut self, batcher: &mut Batcher) -> Vec<Response> {
        if self.policy.batched_decode {
            self.round_paged(batcher)
        } else {
            self.round_legacy(batcher)
        }
    }

    // ---- paged serving (default) ----

    fn round_paged(&mut self, batcher: &mut Batcher) -> Vec<Response> {
        let t0 = Instant::now();
        let model = self.model;
        self.round_idx += 1;

        // ---- swap-in: preempted sequences re-enter first (FIFO) ----
        // Migrated-in sequences park in the same queue, so resume must
        // run even on engines with preemption off.
        if self.policy.preempt || !self.swapped.is_empty() {
            self.resume_swapped();
        }

        // ---- admission against pool free blocks ----
        let mut admitted = if !self.swapped.is_empty() {
            // Mid-flight (preempted or migrated-in) sequences drain
            // first — no new admission while anything is parked, so
            // they cannot starve behind fresh arrivals.
            Vec::new()
        } else if !self.policy.preempt {
            // Worst-case reservation: admitted work can always run to
            // completion without touching anyone else.
            let reserved: usize = self.active.iter().map(|f| self.blocks_reserved(f)).sum();
            let pool = &self.pool;
            let cfg = &model.cfg;
            batcher.admit(&self.policy, self.active.len(), reserved, pool.budget_blocks(), |r| {
                Self::blocks_for_request(pool, cfg, r)
            })
        } else {
            // Oversubscribed admission: charge only blocks actually
            // resident — growth pressure is preemption's job, not the
            // admission gate's.
            let resident: usize = self
                .active
                .iter()
                .map(|f| f.table.as_ref().map_or(0, |t| t.block_ids().len()))
                .sum();
            let pool = &self.pool;
            let cfg = &model.cfg;
            batcher.admit(&self.policy, self.active.len(), resident, pool.budget_blocks(), |r| {
                Self::blocks_for_admission(pool, cfg, r)
            })
        };
        if admitted.is_empty() && self.active.is_empty() && self.swapped.is_empty() {
            // Over-budget head-of-queue: run it alone — the pool's hard
            // cap guarantees one max_seq sequence always fits.
            if let Some(f) = batcher.pop_front() {
                admitted.push(f);
            }
        }
        for f in &mut admitted {
            f.arrival = self.arrival_seq;
            self.arrival_seq += 1;
        }

        // ---- prefix attach + batched prefill ----
        if !admitted.is_empty() {
            if self.policy.preempt {
                // Make room for the whole admission burst's prompts
                // before any block is staged (attach hits only shrink
                // the real need — the estimate is safely conservative).
                let need: usize = admitted
                    .iter()
                    .map(|f| {
                        let keep = f.req.prompt.len().min(model.cfg.max_seq - 1);
                        self.pool.blocks_for_tokens(keep)
                    })
                    .sum();
                self.make_headroom(need, 0);
            }
            let max_seq = model.cfg.max_seq;
            let mut tables: Vec<BlockTable> = Vec::with_capacity(admitted.len());
            let mut suffixes: Vec<Vec<u8>> = Vec::with_capacity(admitted.len());
            for f in &mut admitted {
                f.started = Some(Instant::now());
                // Clamp over-long prompts to leave ≥1 slot for generation.
                let keep = f.req.prompt.len().min(max_seq - 1);
                let prompt = &f.req.prompt[f.req.prompt.len() - keep..];
                let mut tb = BlockTable::new(max_seq);
                let shared = self.pool.attach_prefix(&mut tb, prompt);
                suffixes.push(prompt[shared..].to_vec());
                tables.push(tb);
            }
            if self.policy.batched_prefill {
                // One fused ragged forward per layer over every prompt
                // admitted this round.
                let logits = {
                    let tok_slices: Vec<&[u8]> = suffixes.iter().map(|s| s.as_slice()).collect();
                    let mut tb_refs: Vec<&mut BlockTable> = tables.iter_mut().collect();
                    model.forward_paged_in(
                        &tok_slices,
                        &mut self.pool,
                        &mut tb_refs,
                        &mut self.scratch,
                    )
                };
                for (i, f) in admitted.iter_mut().enumerate() {
                    let tok = model.sample_row(&logits, i, f.req.temperature, &mut f.rng);
                    f.generated.push(tok);
                    f.first_token = Some(Instant::now());
                }
                self.metrics.record_prefill_batch(admitted.len());
                self.note_weight_stream(1);
            } else {
                // Per-prompt prefill baseline (A/B lever): same paged
                // machinery, weights re-streamed per prompt.
                for (i, f) in admitted.iter_mut().enumerate() {
                    let logits = model.forward_paged_in(
                        &[suffixes[i].as_slice()],
                        &mut self.pool,
                        &mut [&mut tables[i]],
                        &mut self.scratch,
                    );
                    let tok = model.sample_row(&logits, 0, f.req.temperature, &mut f.rng);
                    f.generated.push(tok);
                    f.first_token = Some(Instant::now());
                    self.metrics.record_prefill_batch(1);
                    self.note_weight_stream(1);
                }
            }
            self.metrics.prefill_tokens += suffixes.iter().map(|s| s.len() as u64).sum::<u64>();
            for (f, tb) in admitted.iter_mut().zip(tables) {
                f.table = Some(tb);
            }
            self.active.append(&mut admitted);
        }

        // ---- one fused decode batch across all active sequences ----
        // With speculation on, each greedy sequence may first get up to
        // `k` drafted tokens; the verify pass scores them all and keeps
        // the longest greedy-exact prefix (abstentions plain-decode).
        if self.policy.preempt {
            // Swap out until this round's worst-case staged rows fit —
            // the oversubscription debt comes due here, not as a pool
            // exhaustion panic mid-forward.
            self.make_decode_headroom();
        }
        let td = Instant::now();
        let decode_idx: Vec<usize> = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, f)| f.decodable())
            .map(|(i, _)| i)
            .collect();
        if !decode_idx.is_empty() {
            let last: Vec<u8> = decode_idx
                .iter()
                .map(|&i| *self.active[i].generated.last().expect("has first token"))
                .collect();
            let drafts = self.draft_tokens(&decode_idx, &last);
            if drafts.iter().all(|d| d.is_empty()) {
                self.plain_decode(&decode_idx, &last);
            } else if self.pool.dtype() == KvDtype::F32 {
                self.spec_verify_fused(&decode_idx, &last, &drafts);
            } else {
                self.spec_verify_stepwise(&decode_idx, &last, &drafts);
            }
        }
        self.metrics.decode_time += td.elapsed();
        self.metrics.decode_rounds += 1;
        let resident = self.kv_bytes_in_use();
        self.metrics.kv_bytes_peak = self.metrics.kv_bytes_peak.max(resident);
        self.metrics.sync_pool(&self.pool.stats, self.pool.utilization());
        self.metrics.kv_dequant_bytes = self.pool.dequant_bytes();
        self.metrics.kv_dequant_bytes_avoided = self.pool.dequant_bytes_avoided();
        self.metrics.kv_outlier_rows = self.pool.outlier_rows();

        // ---- retire completed ----
        let mut done = Vec::new();
        let mut still = Vec::with_capacity(self.active.len());
        for mut f in self.active.drain(..) {
            let out_of_kv = f.table.as_ref().map(|t| t.remaining() == 0).unwrap_or(false);
            if f.remaining() == 0 || out_of_kv {
                if let Some(tb) = f.table.take() {
                    self.pool.release(tb);
                }
                let resp = f.finish();
                self.metrics.requests_completed += 1;
                self.metrics.tokens_generated += resp.tokens.len() as u64;
                self.metrics.ttft.record(resp.timing.ttft);
                self.metrics.total_latency.record(resp.timing.total);
                done.push(resp);
            } else {
                still.push(f);
            }
        }
        self.active = still;
        self.metrics.serve_time += t0.elapsed();
        done
    }

    // ---- decode-phase flavours (paged mode) ----

    /// Propose draft tokens for every decodable sequence this round. An
    /// empty per-sequence vec means plain decode for that sequence:
    /// speculation off, drafter abstained, sampled (temperature > 0)
    /// request — speculation must not touch an RNG stream — or no
    /// decode-budget / KV-capacity head-room for even one draft.
    fn draft_tokens(&mut self, decode_idx: &[usize], last: &[u8]) -> Vec<Vec<u8>> {
        let Some(spec) = self.spec.as_mut() else {
            return vec![Vec::new(); decode_idx.len()];
        };
        let active = &self.active;
        decode_idx
            .iter()
            .zip(last)
            .map(|(&i, &tok)| {
                let f = &active[i];
                let tb = f.table.as_ref().expect("prefilled");
                // Emitted tokens ≤ k+1 must fit the decode budget, and
                // the verify pass stages k+1 rows into the table.
                let k_cap = spec
                    .k
                    .min(f.remaining().saturating_sub(1))
                    .min(tb.remaining().saturating_sub(1));
                if k_cap == 0 || f.req.temperature > 0.0 {
                    return Vec::new();
                }
                let mut ctx = Vec::with_capacity(tb.len() + 1);
                ctx.extend_from_slice(tb.tokens());
                ctx.push(tok);
                let mut d = spec.drafter.draft(&ctx, k_cap);
                d.truncate(k_cap);
                d
            })
            .collect()
    }

    /// One plain fused decode token for every selected sequence (the
    /// non-speculative round, and the fallback when every drafter
    /// abstained).
    fn plain_decode(&mut self, decode_idx: &[usize], last: &[u8]) {
        let model = self.model;
        let logits = {
            let pool = &mut self.pool;
            let scratch = &mut self.scratch;
            let tok_slices: Vec<&[u8]> = last.iter().map(std::slice::from_ref).collect();
            with_tables(&mut self.active, decode_idx, |tbs| {
                model.forward_paged_in(&tok_slices, pool, tbs, scratch)
            })
        };
        for (row, &i) in decode_idx.iter().enumerate() {
            let f = &mut self.active[i];
            let tok = model.sample_row(&logits, row, f.req.temperature, &mut f.rng);
            f.generated.push(tok);
        }
        self.metrics.record_decode_batch(decode_idx.len());
        self.note_weight_stream(1);
    }

    /// Fused speculative verify (f32 pools): one ragged forward scores
    /// every sequence's input token plus all its drafts (`n_new = k+1`)
    /// and rejected tokens roll back by **truncating** the sequence's
    /// block table to the accepted length. F32 rows are stored verbatim
    /// and every kernel is row-independent, so (a) the fused logits are
    /// bit-identical to stepping one token at a time and (b) the kept
    /// rows are already byte-exact in place — truncation alone restores
    /// exactly the state plain decode would have built, no snapshot or
    /// replay needed. Quantized pools satisfy neither property (a
    /// drafted row can grow the slab amax and re-scale the committed
    /// codes the earlier positions read), so they verify stepwise
    /// instead ([`Self::spec_verify_stepwise`]); the byte-exact
    /// [`BlockPool::checkpoint`]/[`BlockPool::rollback`] pair remains
    /// the kv-level primitive a quantized *fused* verifier would need.
    fn spec_verify_fused(&mut self, decode_idx: &[usize], last: &[u8], drafts: &[Vec<u8>]) {
        debug_assert_eq!(self.pool.dtype(), KvDtype::F32);
        let model = self.model;
        // Committed lengths before the verify pass — the truncation
        // anchors for rejected drafts.
        let lens: Vec<usize> = decode_idx
            .iter()
            .map(|&i| self.active[i].table.as_ref().expect("prefilled").len())
            .collect();
        let new_tokens: Vec<Vec<u8>> = last
            .iter()
            .zip(drafts)
            .map(|(&t, d)| {
                let mut v = Vec::with_capacity(1 + d.len());
                v.push(t);
                v.extend_from_slice(d);
                v
            })
            .collect();
        let (logits, offs) = {
            let pool = &mut self.pool;
            let scratch = &mut self.scratch;
            let tok_slices: Vec<&[u8]> = new_tokens.iter().map(|t| t.as_slice()).collect();
            with_tables(&mut self.active, decode_idx, |tbs| {
                model.forward_paged_spec_in(&tok_slices, pool, tbs, scratch)
            })
        };
        for (j, &i) in decode_idx.iter().enumerate() {
            let f = &mut self.active[i];
            if drafts[j].is_empty() {
                let tok = model.sample_row(&logits, offs[j], f.req.temperature, &mut f.rng);
                f.generated.push(tok);
                continue;
            }
            let (accepted, emitted) = crate::spec::accept_greedy(&logits, offs[j], &drafts[j]);
            self.metrics.record_spec(drafts[j].len(), accepted, accepted);
            if accepted < drafts[j].len() {
                // Roll the rejected tokens back: keep the input token
                // plus the accepted drafts, release everything after.
                let tb = f.table.as_mut().expect("prefilled");
                self.pool.truncate(tb, lens[j] + accepted + 1);
            }
            f.generated.extend_from_slice(&emitted);
        }
        self.metrics.record_decode_batch(decode_idx.len());
        self.note_weight_stream(1);
    }

    /// Stepwise speculative verify (quantized pools). A quantized slab
    /// re-quantizes its committed codes when a later row in the same
    /// block grows the running amax, so a fused multi-token verify
    /// would read — and act on — different low-bit KV than plain
    /// one-token decode, breaking bit-identity. Instead, each drafted
    /// depth is one fused sub-batch across the sequences still
    /// matching: a sequence's next draft is fed only after the model's
    /// own greedy choice confirmed the previous one, every write lands
    /// with exactly the incremental history, only kept tokens are ever
    /// staged, and no rollback is needed. Bit-identical by
    /// construction; keeps the multi-token-per-round win, gives up the
    /// single-fused-GEMM win that f32 pools get.
    fn spec_verify_stepwise(&mut self, decode_idx: &[usize], last: &[u8], drafts: &[Vec<u8>]) {
        let model = self.model;
        let mut emitted: Vec<Vec<u8>> = vec![Vec::new(); decode_idx.len()];
        // Positions (into decode_idx) still advancing at this depth.
        let mut cur: Vec<usize> = (0..decode_idx.len()).collect();
        let mut step = 0usize;
        while !cur.is_empty() {
            let idxs: Vec<usize> = cur.iter().map(|&j| decode_idx[j]).collect();
            let toks: Vec<u8> = cur
                .iter()
                .map(|&j| if step == 0 { last[j] } else { drafts[j][step - 1] })
                .collect();
            let logits = {
                let pool = &mut self.pool;
                let scratch = &mut self.scratch;
                let tok_slices: Vec<&[u8]> = toks.iter().map(std::slice::from_ref).collect();
                with_tables(&mut self.active, &idxs, |tbs| {
                    model.forward_paged_in(&tok_slices, pool, tbs, scratch)
                })
            };
            let mut next = Vec::with_capacity(cur.len());
            for (row, &j) in cur.iter().enumerate() {
                let f = &mut self.active[decode_idx[j]];
                let g = model.sample_row(&logits, row, f.req.temperature, &mut f.rng);
                emitted[j].push(g);
                // Feed the next draft only while the chain keeps
                // matching the model's own greedy choice.
                if step < drafts[j].len() && g == drafts[j][step] {
                    next.push(j);
                }
            }
            self.metrics.record_decode_batch(idxs.len());
            self.note_weight_stream(1);
            cur = next;
            step += 1;
        }
        for (j, &i) in decode_idx.iter().enumerate() {
            if !drafts[j].is_empty() {
                // Stepwise sub-batches already counted every emitted
                // token, so no extras ride record_spec.
                self.metrics.record_spec(drafts[j].len(), emitted[j].len() - 1, 0);
            }
            self.active[i].generated.extend_from_slice(&emitted[j]);
        }
    }

    // ---- legacy per-sequence baseline (batched_decode = false) ----

    fn round_legacy(&mut self, batcher: &mut Batcher) -> Vec<Response> {
        let t0 = Instant::now();
        // ---- admission + per-request prefill ----
        let kv_reserved = self.kv_bytes_reserved();
        let mut admitted = batcher.admit(
            &self.policy,
            self.active.len(),
            kv_reserved,
            self.policy.kv_budget_bytes,
            |r| self.projected_kv_bytes(r),
        );
        for f in &mut admitted {
            f.kv_projected = self.projected_kv_bytes(&f.req);
            f.started = Some(Instant::now());
            let mut cache = KvCache::new(self.model);
            // Clamp over-long prompts to leave ≥1 slot for generation.
            let keep = f.req.prompt.len().min(self.model.cfg.max_seq - 1);
            let prompt = &f.req.prompt[f.req.prompt.len() - keep..];
            let logits = self.model.forward_cached(prompt, &mut cache);
            self.metrics.prefill_tokens += prompt.len() as u64;
            let tok = self.model.sample(&logits, f.req.temperature, &mut f.rng);
            f.generated.push(tok);
            f.first_token = Some(Instant::now());
            f.cache = Some(cache);
            self.metrics.record_prefill_batch(1);
            self.note_weight_stream(1);
        }
        self.active.append(&mut admitted);

        // ---- decode one token per sequence, parallel across sequences
        // (each batch-1 GEMM re-streams the weights — the baseline the
        // fused path is measured against) ----
        let model = self.model;
        let td = Instant::now();
        let width = self.active.iter().filter(|f| f.decodable()).count();
        par_chunks_mut(&mut self.active, 1, |_i, slot| {
            let f = &mut slot[0];
            if !f.decodable() {
                return;
            }
            let cache = f.cache.as_mut().expect("prefilled");
            let last = *f.generated.last().expect("has first token");
            let logits = model.forward_cached(&[last], cache);
            let tok = model.sample(&logits, f.req.temperature, &mut f.rng);
            f.generated.push(tok);
        });
        for _ in 0..width {
            self.metrics.record_decode_batch(1);
        }
        // Each batch-1 decode re-streamed the full weights — the
        // baseline's per-forward traffic the fused path amortizes away.
        self.note_weight_stream(width as u64);
        self.metrics.decode_time += td.elapsed();
        self.metrics.decode_rounds += 1;
        let resident = self.kv_bytes_in_use();
        self.metrics.kv_bytes_peak = self.metrics.kv_bytes_peak.max(resident);

        // ---- retire completed ----
        let mut done = Vec::new();
        let mut still = Vec::with_capacity(self.active.len());
        for f in self.active.drain(..) {
            let out_of_cache = f.cache.as_ref().map(|c| c.remaining() == 0).unwrap_or(false);
            if f.remaining() == 0 || out_of_cache {
                let resp = f.finish();
                self.metrics.requests_completed += 1;
                self.metrics.tokens_generated += resp.tokens.len() as u64;
                self.metrics.ttft.record(resp.timing.ttft);
                self.metrics.total_latency.record(resp.timing.total);
                done.push(resp);
            } else {
                still.push(f);
            }
        }
        self.active = still;
        self.metrics.serve_time += t0.elapsed();
        done
    }

    /// Drive rounds until the queue and active set drain.
    pub fn run_to_completion(&mut self, batcher: &mut Batcher) -> Vec<Response> {
        let mut out = Vec::new();
        while self.has_work(batcher) {
            out.extend(self.round(batcher));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::kv::KV_BLOCK_TOKENS;
    use crate::model::testutil::tiny_model;
    use crate::model::Arch;

    #[test]
    fn serves_all_requests() {
        let model = tiny_model(Arch::Gpt, 1);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        for i in 0..6 {
            batcher.enqueue(Request::new(i, vec![(i + 65) as u8; 4], 5));
        }
        let responses = sched.run_to_completion(&mut batcher);
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert_eq!(r.tokens.len(), 5);
            assert!(r.timing.ttft <= r.timing.total);
        }
        assert_eq!(sched.metrics.requests_completed, 6);
        assert_eq!(sched.metrics.tokens_generated, 30);
        // Plain f32 model: every forward streamed dense weights and
        // avoided nothing — exactly (prefill + decode calls) × model f32
        // bytes of traffic.
        let (per_fwd, avoid) = model.weight_stream_bytes();
        assert_eq!(avoid, 0);
        let calls = sched.metrics.prefill_batches + sched.metrics.decode_batches;
        assert_eq!(sched.metrics.weight_bytes_streamed, calls * per_fwd);
        assert_eq!(sched.metrics.weight_bytes_avoided, 0);
    }

    #[test]
    fn deterministic_greedy_matches_generate() {
        let model = tiny_model(Arch::Llama, 2);
        let prompt = b"abcd".to_vec();
        let direct = model.generate(&prompt, 6, 0.0, 0);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, prompt, 6));
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp[0].tokens, direct);
    }

    #[test]
    fn respects_max_active() {
        let model = tiny_model(Arch::Gpt, 3);
        let policy = BatchPolicy { max_active: 2, max_prefill_per_round: 2, ..Default::default() };
        let mut sched = Scheduler::new(&model, policy);
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 2], 3));
        }
        let _ = sched.round(&mut batcher);
        assert!(sched.active() <= 2);
        let all = sched.run_to_completion(&mut batcher);
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn long_prompt_is_clamped() {
        let model = tiny_model(Arch::Gpt, 4);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, vec![66u8; 200], 4)); // > max_seq=64
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp.len(), 1);
        assert!(!resp[0].tokens.is_empty());
    }

    #[test]
    fn per_seq_fallback_matches_batched() {
        // The A/B lever must not change tokens: greedy output is
        // bit-identical between the paged fused engine and the legacy
        // per-sequence chunked-cache baseline.
        let model = tiny_model(Arch::Llama, 5);
        let run = |batched: bool| {
            let policy = BatchPolicy { batched_decode: batched, ..Default::default() };
            let mut sched = Scheduler::new(&model, policy);
            let mut batcher = Batcher::new();
            for i in 0..5u64 {
                let plen = 1 + (i as usize * 2) % 7;
                batcher.enqueue(Request::new(i, vec![(65 + i) as u8; plen], 3 + i as usize));
            }
            let mut resp = sched.run_to_completion(&mut batcher);
            resp.sort_by_key(|r| r.id);
            resp.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn per_prompt_prefill_matches_batched_prefill() {
        // The prefill A/B lever must not change tokens either.
        let model = tiny_model(Arch::Gpt, 14);
        let run = |batched_prefill: bool| {
            let policy = BatchPolicy { batched_prefill, ..Default::default() };
            let mut sched = Scheduler::new(&model, policy);
            let mut batcher = Batcher::new();
            for i in 0..6u64 {
                let plen = 2 + (i as usize * 3) % 9;
                batcher.enqueue(Request::new(i, vec![(70 + i) as u8; plen], 4));
            }
            let mut resp = sched.run_to_completion(&mut batcher);
            resp.sort_by_key(|r| r.id);
            resp.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn decode_width_metrics() {
        let model = tiny_model(Arch::Gpt, 6);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        for i in 0..6 {
            batcher.enqueue(Request::new(i, vec![65u8; 4], 5));
        }
        sched.run_to_completion(&mut batcher);
        let m = &sched.metrics;
        assert!(m.decode_batches > 0);
        // Round 1 admits 4 (prefill burst limit) and decodes width 4;
        // round 2 admits the remaining 2 and decodes width 6.
        assert_eq!(m.decode_width_max, 6);
        assert!(m.mean_decode_width() > 1.0);
        assert!(m.kv_bytes_peak > 0);
        assert!(!m.decode_time.is_zero());
        // Prefill fused per admission burst: widths 4 then 2.
        assert_eq!(m.prefill_batches, 2);
        assert_eq!(m.prefill_width_max, 4);
        assert!((m.mean_prefill_width() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn admission_budgets_on_projected_kv() {
        let model = tiny_model(Arch::Gpt, 7);
        // Budget fits exactly two projected caches (prompt 4 + 8 new →
        // one pool block each; one block and one chunk are the same
        // bytes at matching granularity).
        let one = KvCache::bytes_for_tokens(&model.cfg, 4 + 8);
        let policy = BatchPolicy { kv_budget_bytes: 2 * one, ..Default::default() };
        let mut sched = Scheduler::new(&model, policy);
        assert_eq!(sched.pool().budget_blocks(), 2);
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 4], 8));
        }
        let _ = sched.round(&mut batcher);
        assert_eq!(sched.active(), 2, "projected KV budget must cap admission");
        // Everything still completes once the first wave retires.
        let all = sched.run_to_completion(&mut batcher);
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn budget_holds_across_cache_growth() {
        // Requests whose KV grows over several blocks after admission:
        // worst-case block reservations must keep both the active count
        // and the actual residency under budget in every round, not
        // just at admission time.
        let model = tiny_model(Arch::Gpt, 8);
        let one = KvCache::bytes_for_tokens(&model.cfg, 4 + 40);
        let policy = BatchPolicy { kv_budget_bytes: 2 * one, ..Default::default() };
        let mut sched = Scheduler::new(&model, policy);
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 4], 40));
        }
        let mut rounds = 0;
        while sched.has_work(&batcher) && rounds < 200 {
            let _ = sched.round(&mut batcher);
            rounds += 1;
            assert!(sched.active() <= 2, "admission exceeded the block budget");
            assert!(
                sched.kv_bytes_in_use() <= policy.kv_budget_bytes,
                "actual KV residency broke the budget"
            );
        }
        assert_eq!(sched.metrics.requests_completed, 4);
    }

    #[test]
    fn oversized_request_is_force_admitted() {
        // A request whose projection exceeds the whole budget must
        // still run (alone) instead of livelocking the queue.
        let model = tiny_model(Arch::Gpt, 15);
        let policy = BatchPolicy {
            kv_budget_bytes: 1, // less than one block
            ..Default::default()
        };
        let mut sched = Scheduler::new(&model, policy);
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, vec![65u8; 40], 10));
        batcher.enqueue(Request::new(1, vec![66u8; 40], 10));
        let all = sched.run_to_completion(&mut batcher);
        assert_eq!(all.len(), 2, "oversized requests must drain one at a time");
        for r in &all {
            assert_eq!(r.tokens.len(), 10);
        }
    }

    #[test]
    fn quantized_pool_multiplies_admission_capacity() {
        use crate::kv::KvDtype;
        let model = tiny_model(Arch::Gpt, 17);
        // Budget that fits exactly two projected f32 caches (see
        // `admission_budgets_on_projected_kv`).
        let one = KvCache::bytes_for_tokens(&model.cfg, 4 + 8);
        let f32_sched =
            Scheduler::new(&model, BatchPolicy { kv_budget_bytes: 2 * one, ..Default::default() });
        let mut sched = Scheduler::new(
            &model,
            BatchPolicy {
                kv_budget_bytes: 2 * one,
                kv_dtype: Some(KvDtype::Int8),
                ..Default::default()
            },
        );
        // Same byte budget, ~4× the blocks: compressed storage is what
        // admission actually accounts in.
        assert!(sched.pool().block_bytes() * 3 < f32_sched.pool().block_bytes());
        assert!(
            sched.pool().budget_blocks() as f64 >= 1.8 * f32_sched.pool().budget_blocks() as f64,
            "int8 budget must be ≥1.8× f32: {} vs {}",
            sched.pool().budget_blocks(),
            f32_sched.pool().budget_blocks()
        );
        assert_eq!(sched.metrics.kv_dtype, "int8");
        assert_eq!(sched.metrics.pool_block_bytes, sched.pool().block_bytes());
        // The f32 pool admitted these 4 requests two at a time; the
        // int8 pool takes the whole prefill burst in round one.
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 4], 8));
        }
        let _ = sched.round(&mut batcher);
        assert_eq!(sched.active(), 4, "compressed blocks must widen admission");
        let all = sched.run_to_completion(&mut batcher);
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn quantized_kv_serves_deterministically() {
        // Quantized KV changes logits within tolerance, not determinism:
        // two identical runs must emit identical tokens.
        use crate::kv::KvDtype;
        let model = tiny_model(Arch::Llama, 18);
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3, KvDtype::Int4Outlier] {
            let run = || {
                let policy = BatchPolicy { kv_dtype: Some(dtype), ..Default::default() };
                let mut sched = Scheduler::new(&model, policy);
                let mut batcher = Batcher::new();
                for i in 0..4u64 {
                    let plen = 3 + (i as usize * 5) % 11;
                    batcher.enqueue(Request::new(i, vec![(65 + i) as u8; plen], 4 + i as usize));
                }
                let mut resp = sched.run_to_completion(&mut batcher);
                resp.sort_by_key(|r| r.id);
                resp.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
            };
            let a = run();
            assert_eq!(a, run(), "{dtype:?}: serving must be deterministic");
            assert_eq!(a.len(), 4);
            for (i, toks) in a.iter().enumerate() {
                assert_eq!(toks.len(), 4 + i, "every request runs to its token budget");
            }
        }
    }

    /// Tiny model rigged so every logit row is all-zeros (zeroed token
    /// embeddings kill the tied head), making greedy decode emit token 0
    /// forever — a deterministic worst-best-case for n-gram lookup:
    /// every draft of zeros is accepted.
    fn constant_output_model(seed: u64) -> Model {
        let mut m = tiny_model(Arch::Gpt, seed);
        m.tok_emb.data.fill(0.0);
        m
    }

    #[test]
    fn spec_ngram_matches_plain_greedy() {
        // Bit-identity: speculative greedy output == plain greedy
        // output, drafts accepted or not, across ragged lengths.
        use crate::spec::SpecPolicy;
        let model = tiny_model(Arch::Llama, 40);
        let reqs = |n: u64| -> Vec<Request> {
            (0..n)
                .map(|i| {
                    let plen = 2 + (i as usize * 3) % 9;
                    Request::new(i, vec![(65 + i) as u8; plen], 4 + i as usize % 5)
                })
                .collect()
        };
        let run = |spec: Option<SpecPolicy>| {
            let mut sched = Scheduler::with_spec(&model, BatchPolicy::default(), spec);
            let mut batcher = Batcher::new();
            for r in reqs(6) {
                batcher.enqueue(r);
            }
            let mut resp = sched.run_to_completion(&mut batcher);
            resp.sort_by_key(|r| r.id);
            resp.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(Some(SpecPolicy::ngram(3))), run(None));
    }

    #[test]
    fn spec_accepts_and_shrinks_rounds_on_repetitive_output() {
        // The constant-output model loops immediately, so n-gram drafts
        // are guaranteed to match: acceptance must be 1.0 and the whole
        // generation must take far fewer decode rounds than tokens.
        use crate::spec::SpecPolicy;
        let model = constant_output_model(41);
        let want = model.generate(&[9, 0, 0], 12, 0.0, 0);
        assert!(want.iter().all(|t| *t == 0), "rigged model must emit zeros");
        let mut sched =
            Scheduler::with_spec(&model, BatchPolicy::default(), Some(SpecPolicy::ngram(4)));
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, vec![9, 0, 0], 12));
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp[0].tokens, want, "speculative output diverged");
        let m = &sched.metrics;
        assert_eq!(m.spec_drafter, "ngram");
        assert!(m.spec_drafted > 0, "drafter never fired");
        assert_eq!(m.spec_accepted, m.spec_drafted, "all zero-drafts must be accepted");
        assert!((m.spec_acceptance_rate() - 1.0).abs() < 1e-12);
        assert!(
            m.decode_rounds < 11,
            "12 tokens must take < 11 decode rounds with accepted drafts (got {})",
            m.decode_rounds
        );
        assert!(m.tokens_per_round() > 1.0);
    }

    #[test]
    fn spec_rollback_keeps_serving_consistent() {
        // A deliberately wrong drafter: every draft gets rejected, so
        // every round exercises the truncation rollback. Output must
        // still be bit-identical to plain greedy, and the pool must
        // stay consistent to the last block.
        use crate::spec::{Drafter, SpecPolicy};
        struct WrongDrafter;
        impl Drafter for WrongDrafter {
            fn name(&self) -> &'static str {
                "wrong"
            }
            fn draft(&mut self, context: &[u8], k: usize) -> Vec<u8> {
                // Propose the bit-flipped last byte, k times: almost
                // surely not the greedy continuation.
                vec![context.last().map(|b| b ^ 0xA5).unwrap_or(1); k]
            }
        }
        for arch in [Arch::Gpt, Arch::Llama] {
            let model = tiny_model(arch, 42);
            let plain = {
                let mut sched = Scheduler::new(&model, BatchPolicy::default());
                let mut batcher = Batcher::new();
                for i in 0..4u64 {
                    batcher.enqueue(Request::new(i, vec![(70 + i) as u8; 3], 6));
                }
                let mut r = sched.run_to_completion(&mut batcher);
                r.sort_by_key(|r| r.id);
                r.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
            };
            let policy = BatchPolicy::default();
            let spec = SpecPolicy::new(3, Box::new(WrongDrafter));
            let mut sched = Scheduler::with_spec(&model, policy, Some(spec));
            let mut batcher = Batcher::new();
            for i in 0..4u64 {
                batcher.enqueue(Request::new(i, vec![(70 + i) as u8; 3], 6));
            }
            let mut resp = sched.run_to_completion(&mut batcher);
            resp.sort_by_key(|r| r.id);
            let got: Vec<_> = resp.into_iter().map(|r| r.tokens).collect();
            assert_eq!(got, plain, "{arch:?}: rejected drafts perturbed the output");
            sched.pool().assert_consistent();
            assert_eq!(sched.pool().referenced_blocks(), 0, "{arch:?}: leaked blocks");
            let m = &sched.metrics;
            assert!(m.spec_drafted > 0);
            assert!(
                m.spec_accepted < m.spec_drafted,
                "{arch:?}: the wrong drafter cannot be this right"
            );
        }
    }

    #[test]
    fn spec_quantized_stepwise_matches_plain() {
        // Quantized pools verify stepwise; output must equal the plain
        // quantized run bit-for-bit — including with a drafter that is
        // (deliberately) sometimes right: the constant-output model
        // makes every n-gram draft right, a real model makes most wrong.
        use crate::kv::KvDtype;
        use crate::spec::SpecPolicy;
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3, KvDtype::Int4Outlier] {
            for (seed, constant) in [(43u64, false), (44, true)] {
                let model =
                    if constant { constant_output_model(seed) } else { tiny_model(Arch::Gpt, seed) };
                let policy = BatchPolicy { kv_dtype: Some(dtype), ..Default::default() };
                let run = |spec: Option<SpecPolicy>| {
                    let mut sched = Scheduler::with_spec(&model, policy, spec);
                    let mut batcher = Batcher::new();
                    for i in 0..4u64 {
                        let plen = 3 + (i as usize * 5) % 7;
                        batcher.enqueue(Request::new(i, vec![(80 + i) as u8; plen], 5));
                    }
                    let mut resp = sched.run_to_completion(&mut batcher);
                    resp.sort_by_key(|r| r.id);
                    let toks: Vec<_> = resp.into_iter().map(|r| r.tokens).collect();
                    (toks, sched.metrics.spec_accepted)
                };
                let (plain, _) = run(None);
                let (spec, accepted) = run(Some(SpecPolicy::ngram(3)));
                assert_eq!(spec, plain, "{dtype:?} constant={constant}: stepwise diverged");
                if constant {
                    assert!(accepted > 0, "{dtype:?}: constant model must accept drafts");
                }
            }
        }
    }

    #[test]
    fn spec_sdq_drafter_full_acceptance_on_identical_model() {
        // A draft model numerically identical to the target (f32 pool,
        // no compression on either) always proposes the target's own
        // greedy tokens → every draft is accepted and rounds shrink.
        use crate::spec::{SdqDrafter, SpecPolicy};
        let model = tiny_model(Arch::Llama, 45);
        let want = model.generate(b"abcdef", 10, 0.0, 0);
        let spec = SpecPolicy::sdq(3, SdqDrafter::new(model.clone()));
        let mut sched = Scheduler::with_spec(&model, BatchPolicy::default(), Some(spec));
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, b"abcdef".to_vec(), 10));
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp[0].tokens, want);
        let m = &sched.metrics;
        assert_eq!(m.spec_drafter, "sdq-draft");
        assert!(m.spec_drafted > 0);
        assert_eq!(m.spec_accepted, m.spec_drafted, "identical draft model must fully accept");
        assert!(m.decode_rounds < 9, "acceptance must shrink rounds (got {})", m.decode_rounds);
    }

    #[test]
    fn spec_ignores_sampled_requests() {
        // temperature > 0 sequences must keep their exact RNG streams:
        // a spec engine and a plain engine give identical sampled
        // output because sampled sequences never speculate.
        use crate::spec::SpecPolicy;
        let model = tiny_model(Arch::Gpt, 46);
        let run = |spec: Option<SpecPolicy>| {
            let mut sched = Scheduler::with_spec(&model, BatchPolicy::default(), spec);
            let mut batcher = Batcher::new();
            batcher.enqueue(Request::new(0, b"abc".to_vec(), 6).with_temperature(0.9));
            batcher.enqueue(Request::new(1, b"xyz".to_vec(), 6)); // greedy rides along
            let mut resp = sched.run_to_completion(&mut batcher);
            resp.sort_by_key(|r| r.id);
            resp.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(Some(SpecPolicy::ngram(3))), run(None));
    }

    #[test]
    fn sequential_shared_prefix_hits_cache() {
        // Request B arrives after request A completed; their prompts
        // share a full block of prefix → B attaches A's cached block
        // instead of recomputing it, and the answer is unchanged.
        let model = tiny_model(Arch::Llama, 16);
        let bt = KV_BLOCK_TOKENS;
        let mut prefix: Vec<u8> = (0..bt as u8).map(|j| 100 + j).collect();
        let mut prompt_a = prefix.clone();
        prompt_a.extend_from_slice(b"AAAA");
        let mut prompt_b = std::mem::take(&mut prefix);
        prompt_b.extend_from_slice(b"BBBB");
        let want_b = model.generate(&prompt_b, 5, 0.0, 1);

        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, prompt_a, 5));
        sched.run_to_completion(&mut batcher);
        let single_peak = sched.metrics.kv_bytes_peak;
        batcher.enqueue(Request::new(1, prompt_b, 5));
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp[0].tokens, want_b, "shared prefix must not change output");
        assert_eq!(sched.metrics.prefix_shared_tokens, bt as u64);
        assert!(sched.metrics.prefix_hit_rate() > 0.0);
        assert!(
            sched.metrics.kv_bytes_peak < 2 * single_peak,
            "sharing must keep peak residency under 2× a single request"
        );
    }

    // ---- preemptive scheduling ----

    /// Run `reqs` to completion under `policy`, returning sorted
    /// responses + metrics, with pool invariants checked every round.
    fn run_checked(
        model: &Model,
        policy: BatchPolicy,
        reqs: Vec<Request>,
    ) -> (Vec<crate::coordinator::request::Response>, Metrics) {
        let mut sched = Scheduler::new(model, policy);
        let mut batcher = Batcher::new();
        for r in reqs {
            batcher.enqueue(r);
        }
        let mut out = Vec::new();
        let mut rounds = 0;
        while sched.has_work(&batcher) {
            out.extend(sched.round(&mut batcher));
            sched.pool().assert_consistent();
            rounds += 1;
            assert!(rounds < 2000, "scheduler failed to drain (livelock?)");
        }
        assert_eq!(sched.pool().referenced_blocks(), 0, "retired sequences leaked blocks");
        assert_eq!(sched.swapped(), 0, "swapped sequences were stranded");
        out.sort_by_key(|r| r.id);
        (out, sched.metrics)
    }

    /// Short prompts + long decode budgets under a tight block budget:
    /// the workload where worst-case reservation serializes and
    /// residency-charged admission + preemption oversubscribes.
    fn pressure_reqs(n: u64) -> Vec<Request> {
        (0..n).map(|i| Request::new(i, vec![(65 + i) as u8; 3 + (i as usize % 4)], 24)).collect()
    }

    #[test]
    fn preemption_oversubscribes_and_stays_bit_exact() {
        use crate::coordinator::request::assert_bit_identical;
        let model = tiny_model(Arch::Llama, 50);
        // 3 blocks: each request peaks at 2 blocks (≤ 31 tokens), so
        // worst-case reservation admits one at a time while resident
        // charging packs several and swaps under pressure.
        let blk = KvCache::bytes_for_tokens(&model.cfg, 1);
        let tight = BatchPolicy { kv_budget_bytes: 3 * blk, ..Default::default() };
        let (want, _) = run_checked(&model, BatchPolicy::default(), pressure_reqs(6));
        let (base, base_m) = run_checked(&model, tight, pressure_reqs(6));
        let (got, m) = run_checked(
            &model,
            BatchPolicy { preempt: true, ..tight },
            pressure_reqs(6),
        );
        assert_bit_identical("tight baseline vs unconstrained", &base, &want);
        assert_bit_identical("preemptive vs unconstrained", &got, &want);
        assert!(m.preemptions > 0, "a 3-block pool under 6 requests must preempt");
        assert_eq!(m.resumes, m.preemptions, "every swap-out must swap back in");
        assert!(m.swap_bytes > 0);
        assert!(
            m.decode_width_max > base_m.decode_width_max,
            "oversubscription must widen concurrency beyond the reserved pool's \
             ({} vs {})",
            m.decode_width_max,
            base_m.decode_width_max
        );
        assert!(m.preemption_rate() > 0.0);
    }

    #[test]
    fn preemption_matches_across_kv_dtypes_and_spec() {
        // Bit-identity under pressure for every dtype, with and without
        // an n-gram drafter riding on top (spec rollback + preemption
        // must compose).
        use crate::coordinator::request::assert_bit_identical;
        use crate::spec::SpecPolicy;
        let model = tiny_model(Arch::Gpt, 51);
        for dtype in [KvDtype::F32, KvDtype::Int8, KvDtype::Fp8E4M3, KvDtype::Int4Outlier] {
            for spec in [false, true] {
                let mk_spec = || spec.then(|| SpecPolicy::ngram(3));
                let roomy = BatchPolicy { kv_dtype: Some(dtype), ..Default::default() };
                let (want, _) = {
                    let mut sched = Scheduler::with_spec(&model, roomy, mk_spec());
                    let mut batcher = Batcher::new();
                    for r in pressure_reqs(5) {
                        batcher.enqueue(r);
                    }
                    let mut out = sched.run_to_completion(&mut batcher);
                    out.sort_by_key(|r| r.id);
                    (out, sched.metrics)
                };
                let tight = BatchPolicy {
                    kv_budget_bytes: usize::MAX,
                    max_resident_blocks: Some(3),
                    kv_dtype: Some(dtype),
                    preempt: true,
                    ..Default::default()
                };
                let mut sched = Scheduler::with_spec(&model, tight, mk_spec());
                assert_eq!(sched.pool().budget_blocks(), 3, "max_resident must clamp");
                let mut batcher = Batcher::new();
                for r in pressure_reqs(5) {
                    batcher.enqueue(r);
                }
                let mut rounds = 0;
                let mut got = Vec::new();
                while sched.has_work(&batcher) {
                    got.extend(sched.round(&mut batcher));
                    sched.pool().assert_consistent();
                    rounds += 1;
                    assert!(rounds < 2000, "{dtype:?}/spec={spec}: livelock");
                }
                got.sort_by_key(|r| r.id);
                assert_bit_identical(&format!("{dtype:?}/spec={spec}"), &got, &want);
                assert!(
                    sched.metrics.preemptions > 0,
                    "{dtype:?}/spec={spec}: pressure workload must preempt"
                );
                if dtype != KvDtype::F32 {
                    assert_eq!(
                        sched.metrics.resume_reprefill_tokens, 0,
                        "{dtype:?}: quantized resume must never re-prefill"
                    );
                }
            }
        }
    }

    #[test]
    fn preemption_survives_single_block_budget() {
        // Degenerate pressure: a budget of one block cannot hold even
        // one growing sequence — force-admission, force-resume, and the
        // hard cap must together still drain everything, bit-exactly.
        use crate::coordinator::request::assert_bit_identical;
        let model = tiny_model(Arch::Gpt, 52);
        let blk = KvCache::bytes_for_tokens(&model.cfg, 1);
        let (want, _) = run_checked(&model, BatchPolicy::default(), pressure_reqs(3));
        let tight = BatchPolicy { kv_budget_bytes: blk, preempt: true, ..Default::default() };
        let (got, m) = run_checked(&model, tight, pressure_reqs(3));
        assert_bit_identical("single-block budget", &got, &want);
        assert_eq!(m.requests_completed, 3);
    }

    #[test]
    fn preempted_sampled_requests_keep_their_rng_streams() {
        // Suspension must not perturb a temperature > 0 sequence: the
        // RNG state swaps out and back in with the request.
        use crate::coordinator::request::assert_bit_identical;
        let model = tiny_model(Arch::Llama, 53);
        let blk = KvCache::bytes_for_tokens(&model.cfg, 1);
        let reqs = || -> Vec<Request> {
            (0..4u64)
                .map(|i| {
                    Request::new(i, vec![(70 + i) as u8; 4], 20)
                        .with_temperature(if i % 2 == 0 { 0.8 } else { 0.0 })
                })
                .collect()
        };
        let (want, _) = run_checked(&model, BatchPolicy::default(), reqs());
        let tight = BatchPolicy { kv_budget_bytes: 3 * blk, preempt: true, ..Default::default() };
        let (got, m) = run_checked(&model, tight, reqs());
        assert_bit_identical("sampled under preemption", &got, &want);
        assert!(m.preemptions > 0, "pressure workload must preempt");
    }

    #[test]
    fn preemption_off_is_the_reserved_scheduler() {
        // The default path must be byte-for-byte the old scheduler: no
        // preemption counters move, worst-case reservation caps
        // admission exactly as before.
        let model = tiny_model(Arch::Gpt, 54);
        let one = KvCache::bytes_for_tokens(&model.cfg, 4 + 8);
        let policy = BatchPolicy { kv_budget_bytes: 2 * one, ..Default::default() };
        let mut sched = Scheduler::new(&model, policy);
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 4], 8));
        }
        let _ = sched.round(&mut batcher);
        assert_eq!(sched.active(), 2, "worst-case reservation must still cap admission");
        sched.run_to_completion(&mut batcher);
        assert_eq!(sched.metrics.preemptions, 0);
        assert_eq!(sched.metrics.resumes, 0);
        assert_eq!(sched.metrics.swap_bytes, 0);
        assert_eq!(sched.swapped(), 0);
    }

    #[test]
    fn legacy_mode_drops_preempt_like_it_drops_spec() {
        let model = tiny_model(Arch::Gpt, 55);
        let policy =
            BatchPolicy { batched_decode: false, preempt: true, ..Default::default() };
        let sched = Scheduler::new(&model, policy);
        assert!(!sched.policy.preempt, "legacy baseline has no snapshot story");
    }

    // ---- mid-flight cancellation (the gateway's reclaim path) ----

    #[test]
    fn cancel_active_releases_blocks_and_suppresses_response() {
        let model = tiny_model(Arch::Gpt, 56);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![(65 + i) as u8; 4], 10));
        }
        let mut out = sched.round(&mut batcher);
        out.extend(sched.round(&mut batcher));
        let before = sched.pool().referenced_blocks();
        assert!(sched.cancel(1), "id 1 must be active after two rounds");
        assert!(sched.pool().referenced_blocks() < before, "cancel must release blocks now");
        sched.pool().assert_consistent();
        assert!(!sched.cancel(1), "double cancel is a no-op");
        assert!(!sched.cancel(99), "unknown id is a no-op");
        while sched.has_work(&batcher) {
            out.extend(sched.round(&mut batcher));
        }
        let mut ids: Vec<u64> = out.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 2, 3], "a cancelled request must never produce a response");
        assert_eq!(sched.pool().referenced_blocks(), 0);
        assert_eq!(sched.metrics.requests_cancelled, 1);
        assert!(sched.metrics.cancel_freed_blocks >= 1);
        assert!(sched.metrics.tokens_cancelled >= 1, "two rounds in, ≥2 tokens existed");
    }

    #[test]
    fn cancel_swapped_drops_snapshot_without_touching_pool() {
        let model = tiny_model(Arch::Llama, 57);
        let tight = BatchPolicy {
            kv_budget_bytes: usize::MAX,
            max_resident_blocks: Some(3),
            preempt: true,
            ..Default::default()
        };
        let mut sched = Scheduler::new(&model, tight);
        let mut batcher = Batcher::new();
        for r in pressure_reqs(5) {
            batcher.enqueue(r);
        }
        let mut out = Vec::new();
        let mut rounds = 0;
        while sched.swapped() == 0 {
            out.extend(sched.round(&mut batcher));
            rounds += 1;
            assert!(rounds < 2000, "pressure workload never swapped");
        }
        // Cancel a swapped sequence: its blocks went back at suspend, so
        // residency must not move and no snapshot may be stranded.
        let mut victim = None;
        sched.for_each_progress(|id, _| {
            if victim.is_none() && !sched_active_ids(&sched).contains(&id) {
                victim = Some(id);
            }
        });
        let victim = victim.expect("swapped() > 0 ⇒ some non-active id in progress");
        let before = sched.pool().referenced_blocks();
        assert!(sched.cancel(victim));
        assert_eq!(sched.pool().referenced_blocks(), before);
        sched.pool().assert_consistent();
        while sched.has_work(&batcher) {
            out.extend(sched.round(&mut batcher));
            rounds += 1;
            assert!(rounds < 2000, "livelock after cancelling a swapped sequence");
        }
        assert_eq!(out.len(), 4, "4 of 5 must complete");
        assert!(out.iter().all(|r| r.id != victim));
        assert_eq!(sched.pool().referenced_blocks(), 0);
        assert_eq!(sched.swapped(), 0);
        assert_eq!(sched.metrics.cancel_freed_blocks, 0, "swapped cancel frees nothing now");
    }

    /// Ids currently in the active set (test helper for picking a
    /// swapped victim via `for_each_progress`).
    fn sched_active_ids(s: &Scheduler) -> Vec<u64> {
        s.active.iter().map(|f| f.req.id).collect()
    }

    #[test]
    fn cancel_storm_empties_pool_immediately() {
        let model = tiny_model(Arch::Gpt, 58);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        for i in 0..6 {
            batcher.enqueue(Request::new(i, vec![(70 + i) as u8; 5], 20));
        }
        let _ = sched.round(&mut batcher);
        let _ = sched.round(&mut batcher);
        // Storm: every id, wherever it currently lives.
        for id in 0..6 {
            let _ = sched.cancel(id) || batcher.cancel(id).is_some();
        }
        assert_eq!(sched.pool().referenced_blocks(), 0, "storm must leave zero resident blocks");
        sched.pool().assert_consistent();
        assert!(!sched.has_work(&batcher), "nothing may remain anywhere");
        assert_eq!(
            sched.metrics.requests_cancelled as usize + batcher.waiting(),
            6 - sched.metrics.requests_completed as usize,
            "every unfinished request was cancelled somewhere"
        );
    }

    #[test]
    fn progress_snapshots_are_prefixes_of_final_output() {
        // The streaming contract: what `for_each_progress` reports after
        // round N is a prefix of the request's final token vector.
        let model = tiny_model(Arch::Llama, 59);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        for i in 0..3 {
            batcher.enqueue(Request::new(i, vec![(75 + i) as u8; 3], 8));
        }
        let mut seen: std::collections::HashMap<u64, Vec<u8>> = Default::default();
        let mut out = Vec::new();
        while sched.has_work(&batcher) {
            out.extend(sched.round(&mut batcher));
            sched.for_each_progress(|id, toks| {
                let prev = seen.entry(id).or_default();
                assert!(toks.len() >= prev.len(), "progress went backwards");
                assert_eq!(&toks[..prev.len()], &prev[..], "progress rewrote history");
                *prev = toks.to_vec();
            });
        }
        for r in &out {
            let prev = &seen[&r.id];
            assert_eq!(&r.tokens[..prev.len()], &prev[..], "final output rewrote the stream");
        }
    }
}
