//! Unique temporary directories for tests (replaces `tempfile`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh directory under the system temp dir, tagged with
    /// the pid and a process-unique counter.
    pub fn new(tag: &str) -> Self {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "sdq-test-{}-{}-{}",
            tag,
            std::process::id(),
            n
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new("selftest");
            p = d.path().to_path_buf();
            std::fs::write(d.path().join("x"), b"1").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u");
        let b = TempDir::new("u");
        assert_ne!(a.path(), b.path());
    }
}
