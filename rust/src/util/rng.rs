//! Deterministic PRNG substrate (no external `rand` crate).
//!
//! xoshiro256** — fast, high-quality, and stable across platforms, so
//! every corpus / task / sweep in the repo is bit-reproducible from its
//! seed. API mirrors the subset of `rand` the crate needs.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = move || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// The raw generator state — with [`Self::from_state`], lets a
    /// mid-stream sampled sequence carry its RNG across a process or
    /// engine boundary (sequence migration) and keep its exact stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Self::state`] capture. The all-zero
    /// state is a fixed point of xoshiro256**; reject it so a corrupt
    /// envelope cannot smuggle in a degenerate stream.
    pub fn from_state(s: [u64; 4]) -> Self {
        assert!(s.iter().any(|&w| w != 0), "all-zero rng state");
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform usize in [0, n). `n` must be positive.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // non-cryptographic use; use 128-bit multiply for uniformity.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f64 = xs.iter().map(|v| *v as f64).sum::<f64>() / n as f64;
        let var: f64 = xs.iter().map(|v| (*v as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::seed_from_u64(4);
        let mut xs: Vec<u32> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(xs, (0..20).collect::<Vec<_>>());
    }
}
