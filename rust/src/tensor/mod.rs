//! Dense tensor substrate.
//!
//! A deliberately small, fast, row-major `f32` matrix type plus the GEMM
//! kernels the rest of the crate builds on. Everything in the eval and
//! compression paths ultimately reduces to [`Matrix`] operations, so this
//! module is the CPU hot path (see `benches/hotpath.rs`).

mod matmul;

pub use matmul::{
    dot, matmul, matmul_bias_into, matmul_into, matmul_nn, matmul_nn_into, matmul_q_into,
    WeightPlane,
};


/// Row-major 2-D `f32` matrix: `rows x cols`, index `[r * cols + c]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an existing buffer; `data.len()` must equal `rows*cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "matrix shape/buffer mismatch");
        Matrix { rows, cols, data }
    }

    /// Element access (debug-checked).
    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Mutable element access (debug-checked).
    #[inline(always)]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Immutable view of row `r`.
    #[inline(always)]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the matrix holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Fraction of exactly-zero entries (sparsity diagnostics).
    pub fn zero_fraction(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let z = self.data.iter().filter(|v| **v == 0.0).count();
        z as f64 / self.data.len() as f64
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt()
    }

    /// Max absolute element.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Relative Frobenius distance `||a-b||_F / ||a||_F` (0 when both empty).
    pub fn rel_frob_dist(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let num: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt();
        let den = self.frob_norm();
        if den == 0.0 {
            num
        } else {
            num / den
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_and_access() {
        let mut m = Matrix::zeros(3, 4);
        assert_eq!(m.len(), 12);
        *m.at_mut(2, 3) = 5.0;
        assert_eq!(m.at(2, 3), 5.0);
        assert_eq!(m.row(2), &[0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.at(0, 1), 4.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn zero_fraction_counts() {
        let m = Matrix::from_vec(1, 4, vec![0., 1., 0., 2.]);
        assert_eq!(m.zero_fraction(), 0.5);
    }

    #[test]
    fn rel_frob_dist_zero_for_equal() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        assert_eq!(m.rel_frob_dist(&m), 0.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 5]);
    }
}
