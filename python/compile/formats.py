"""Low-bit number-format quantizers in jnp (build-time mirror of
`rust/src/formats/`).

Each `quantize_*` snaps values onto the format's representable grid with
round-to-nearest-even, matching VS-Quant. These run inside the Pallas
kernels (interpret=True lowers them to plain HLO ops) and inside the
pure-jnp reference oracles, so kernel-vs-ref comparisons are exact.
"""

from __future__ import annotations

import jax.numpy as jnp

# Largest finite magnitudes (mirrors NumFormat::max_value()).
MAX_VALUE = {
    "fp32": jnp.finfo(jnp.float32).max,
    "fp16": 65504.0,
    "fp8-e4m3": 448.0,
    "fp8-e5m2": 57344.0,
    "fp4": 6.0,
    "ufp8-e6m2": (2.0**32) * 1.75,
    "int8": 127.0,
    "int4": 7.0,
}

BITS = {
    "fp32": 32,
    "fp16": 16,
    "fp8-e4m3": 8,
    "fp8-e5m2": 8,
    "fp4": 4,
    "ufp8-e6m2": 8,
    "int8": 8,
    "int4": 4,
}


def _round_half_even(x):
    # jnp.round implements banker's rounding (ties to even).
    return jnp.round(x)


def quantize_int(x, bits: int):
    """Symmetric signed integer grid: ±(2^(b-1)-1)."""
    m = float((1 << (bits - 1)) - 1)
    return jnp.clip(_round_half_even(x), -m, m)


def quantize_minifloat(x, man_bits: int, bias: int, max_value: float):
    """Generic minifloat RNE with subnormal support (mirror of
    `minifloat_round` in rust)."""
    a = jnp.abs(x)
    sign = jnp.sign(x)
    e_min = 1 - bias
    # exponent of the value, clamped at the subnormal floor
    safe = jnp.maximum(a, 1e-45)
    e = jnp.floor(jnp.log2(safe))
    e_eff = jnp.maximum(e, float(e_min))
    quantum = jnp.exp2(e_eff - man_bits)
    q = _round_half_even(a / quantum) * quantum
    q = jnp.minimum(q, max_value)
    return jnp.where(a == 0.0, 0.0, sign * q)


def quantize(x, fmt: str):
    """Snap `x` onto `fmt`'s grid (RNE, clamp to ±max)."""
    if fmt == "fp32":
        return x
    if fmt == "fp16":
        return x.astype(jnp.float16).astype(jnp.float32)
    if fmt == "fp8-e4m3":
        return quantize_minifloat(x, 3, 7, 448.0)
    if fmt == "fp8-e5m2":
        return quantize_minifloat(x, 2, 15, 57344.0)
    if fmt == "fp4":
        return quantize_minifloat(x, 1, 1, 6.0)
    if fmt == "ufp8-e6m2":
        return quantize_minifloat(x, 2, 31, MAX_VALUE["ufp8-e6m2"])
    if fmt == "int8":
        return quantize_int(x, 8)
    if fmt == "int4":
        return quantize_int(x, 4)
    raise ValueError(f"unknown format {fmt}")
