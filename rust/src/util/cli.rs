//! Flag-parsing substrate for the `sdq` binary (no external `clap`).
//!
//! Supports `command --flag value --switch positional` style invocations
//! with typed accessors, defaults, and generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, flags, and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse() -> Self {
        Self::from_iter(std::env::args().skip(1))
    }

    /// Parse from an explicit iterator (testable).
    pub fn from_iter<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut it = iter.into_iter().peekable();
        let mut args = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--flag=value`, `--flag value`, or boolean `--switch`
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::from_iter(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse("serve pos1 --model artifacts/m.bin --batch 8 --verbose");
        assert_eq!(a.command, "serve");
        assert_eq!(a.get("model"), Some("artifacts/m.bin"));
        assert_eq!(a.get_usize("batch", 1).unwrap(), 8);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_style() {
        let a = parse("eval --config=SDQ-W7:8-1:8int8-6:8fp4");
        assert_eq!(a.get("config"), Some("SDQ-W7:8-1:8int8-6:8fp4"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("missing", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn flag_followed_by_flag_is_switch() {
        let a = parse("cmd --fast --out x.json");
        assert!(a.has("fast"));
        assert_eq!(a.get("out"), Some("x.json"));
    }
}
