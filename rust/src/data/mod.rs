//! Data substrate: synthetic corpus + byte-level tokenization + batching.
//!
//! The paper evaluates on raw-WikiText2, which is not available in this
//! sandbox; per DESIGN.md we substitute a deterministic **synthetic
//! natural-language-like corpus**: a Zipfian vocabulary of syllabic
//! words driven by a structured bigram Markov chain, with sentence and
//! paragraph structure. A byte-level (vocab 256) tokenizer keeps the
//! model and evaluation pipeline identical to the paper's protocol
//! (perplexity over a held-out split, non-overlapping windows).
//!
//! The Rust generator is canonical: `sdq gen-corpus` writes
//! `artifacts/corpus.bin` at build time and both the JAX trainer and the
//! Rust evaluator consume the same bytes.

use crate::util::rng::Rng;

use crate::tensor::Matrix;
use crate::Result;

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusCfg {
    /// Total bytes to generate.
    pub bytes: usize,
    /// Vocabulary size (distinct words).
    pub vocab_words: usize,
    /// Markov branching: likely successors per word.
    pub successors: usize,
    /// RNG seed (corpus is fully deterministic given cfg).
    pub seed: u64,
}

impl Default for CorpusCfg {
    fn default() -> Self {
        CorpusCfg { bytes: 4 << 20, vocab_words: 800, successors: 24, seed: 1234 }
    }
}

const SYLLABLES: &[&str] = &[
    "ba", "be", "bi", "bo", "bu", "da", "de", "di", "do", "du", "ka", "ke", "ki", "ko", "ku",
    "la", "le", "li", "lo", "lu", "ma", "me", "mi", "mo", "mu", "na", "ne", "ni", "no", "nu",
    "ra", "re", "ri", "ro", "ru", "sa", "se", "si", "so", "su", "ta", "te", "ti", "to", "tu",
    "va", "ve", "vi", "vo", "vu", "za", "ze", "zi", "zo", "zu", "sh", "th", "an", "en", "in",
    "on", "un", "ar", "er", "ir", "or", "ur", "al", "el", "il", "ol", "ul",
];

/// Build the synthetic vocabulary: syllabic words, short words get low
/// ranks (Zipf-style length/frequency correlation).
fn build_vocab(cfg: &CorpusCfg, rng: &mut Rng) -> Vec<String> {
    let mut vocab = Vec::with_capacity(cfg.vocab_words);
    let mut seen = std::collections::HashSet::new();
    while vocab.len() < cfg.vocab_words {
        // Rank-correlated length: earlier words are shorter.
        let frac = vocab.len() as f64 / cfg.vocab_words as f64;
        let syls = 1 + (frac * 3.0) as usize + rng.below(2);
        let mut w = String::new();
        for _ in 0..syls {
            w.push_str(SYLLABLES[rng.below(SYLLABLES.len())]);
        }
        if seen.insert(w.clone()) {
            vocab.push(w);
        }
    }
    vocab
}

/// Generate the corpus bytes.
pub fn generate_corpus(cfg: &CorpusCfg) -> Vec<u8> {
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let vocab = build_vocab(cfg, &mut rng);
    let v = vocab.len();

    // Structured bigram chain: each word transitions to a small successor
    // set with Zipfian weights; successor identity is deterministic.
    let succ: Vec<Vec<usize>> = (0..v)
        .map(|_| (0..cfg.successors).map(|_| zipf(&mut rng, v)).collect())
        .collect();

    let mut out = Vec::with_capacity(cfg.bytes + 64);
    let mut word = zipf(&mut rng, v);
    let mut sentence_len = 0usize;
    let mut sentences_in_par = 0usize;
    let mut capitalize = true;
    while out.len() < cfg.bytes {
        let w = &vocab[word];
        if capitalize {
            let mut chars = w.chars();
            if let Some(c) = chars.next() {
                out.extend(c.to_ascii_uppercase().to_string().as_bytes());
                out.extend(chars.as_str().as_bytes());
            }
            capitalize = false;
        } else {
            out.extend(w.as_bytes());
        }
        sentence_len += 1;
        // Sentence termination: 6–18 words.
        if sentence_len >= 6 && (sentence_len >= 18 || rng.bool(0.15)) {
            out.push(b'.');
            sentence_len = 0;
            sentences_in_par += 1;
            capitalize = true;
            if sentences_in_par >= 5 && (sentences_in_par >= 12 || rng.bool(0.3)) {
                out.push(b'\n');
                out.push(b'\n');
                sentences_in_par = 0;
            } else {
                out.push(b' ');
            }
            word = zipf(&mut rng, v);
            continue;
        }
        if sentence_len > 2 && rng.bool(0.08) {
            out.push(b',');
        }
        out.push(b' ');
        // Bigram step: mostly follow the chain, sometimes jump (topic shift).
        word = if rng.bool(0.85) {
            let s = &succ[word];
            s[zipf(&mut rng, s.len())]
        } else {
            zipf(&mut rng, v)
        };
    }
    out.truncate(cfg.bytes);
    out
}

/// Zipf(1.1)-ish sampler over `0..n` (rank 0 most likely).
fn zipf(rng: &mut Rng, n: usize) -> usize {
    // Inverse-CDF approximation: u^a maps uniform to heavy head.
    let u: f64 = rng.f64();
    let r = (u.powf(3.0) * n as f64) as usize;
    r.min(n - 1)
}

/// A tokenized corpus with canonical train/valid/test splits.
#[derive(Clone, Debug)]
pub struct TokenDataset {
    pub tokens: Vec<u8>,
}

/// Which split to read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// First 90%.
    Train,
    /// Next 5%.
    Valid,
    /// Final 5%.
    Test,
}

impl TokenDataset {
    pub fn new(tokens: Vec<u8>) -> Self {
        TokenDataset { tokens }
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        Ok(TokenDataset { tokens: std::fs::read(path)? })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, &self.tokens)?;
        Ok(())
    }

    /// Token slice for a split (90/5/5).
    pub fn split(&self, s: Split) -> &[u8] {
        let n = self.tokens.len();
        let (a, b) = match s {
            Split::Train => (0, n * 90 / 100),
            Split::Valid => (n * 90 / 100, n * 95 / 100),
            Split::Test => (n * 95 / 100, n),
        };
        &self.tokens[a..b]
    }

    /// Non-overlapping `[batch, seq]` evaluation windows over a split:
    /// yields `(inputs, targets)` where `targets[i] = inputs[i+1]`
    /// (next-token prediction), as `u8` matrices row-per-sequence.
    pub fn windows(&self, s: Split, batch: usize, seq: usize) -> Vec<(Vec<u8>, Vec<u8>)> {
        let data = self.split(s);
        let win = seq + 1;
        let n_windows = data.len() / win;
        let mut out = Vec::new();
        let mut w = 0;
        while w < n_windows {
            let b = batch.min(n_windows - w);
            let mut inp = Vec::with_capacity(b * seq);
            let mut tgt = Vec::with_capacity(b * seq);
            for i in 0..b {
                let start = (w + i) * win;
                inp.extend_from_slice(&data[start..start + seq]);
                tgt.extend_from_slice(&data[start + 1..start + seq + 1]);
            }
            out.push((inp, tgt));
            w += b;
        }
        out
    }
}

/// One-hot-free embedding lookup helper: tokens → `[n, d]` rows gathered
/// from an embedding matrix.
pub fn embed(tokens: &[u8], emb: &Matrix) -> Matrix {
    let d = emb.cols;
    let mut out = Matrix::zeros(tokens.len(), d);
    for (i, t) in tokens.iter().enumerate() {
        out.row_mut(i).copy_from_slice(emb.row(*t as usize));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CorpusCfg {
        CorpusCfg { bytes: 20_000, vocab_words: 100, successors: 8, seed: 7 }
    }

    #[test]
    fn corpus_is_deterministic() {
        let a = generate_corpus(&small_cfg());
        let b = generate_corpus(&small_cfg());
        assert_eq!(a, b);
        assert_eq!(a.len(), 20_000);
    }

    #[test]
    fn corpus_changes_with_seed() {
        let a = generate_corpus(&small_cfg());
        let mut cfg = small_cfg();
        cfg.seed = 8;
        assert_ne!(a, generate_corpus(&cfg));
    }

    #[test]
    fn corpus_is_texty() {
        let c = generate_corpus(&small_cfg());
        let text = String::from_utf8(c).unwrap();
        assert!(text.contains(". "));
        assert!(text.contains("\n\n"));
        // Mostly lowercase ascii letters + punctuation
        let letters = text.chars().filter(|c| c.is_ascii_lowercase()).count();
        assert!(letters as f64 / text.len() as f64 > 0.6);
    }

    #[test]
    fn corpus_has_zipfian_structure() {
        // Common bytes should dominate: 'a' much more frequent than 'z'-ish.
        let c = generate_corpus(&CorpusCfg { bytes: 100_000, ..small_cfg() });
        let mut hist = [0usize; 256];
        for b in &c {
            hist[*b as usize] += 1;
        }
        let space = hist[b' ' as usize];
        assert!(space > c.len() / 20, "spaces should be frequent");
        assert_eq!(hist[0], 0, "no NUL bytes");
    }

    #[test]
    fn splits_partition() {
        let ds = TokenDataset::new((0..=255u8).cycle().take(10_000).collect());
        let total = ds.split(Split::Train).len()
            + ds.split(Split::Valid).len()
            + ds.split(Split::Test).len();
        assert_eq!(total, 10_000);
        assert_eq!(ds.split(Split::Train).len(), 9_000);
    }

    #[test]
    fn windows_shift_targets() {
        let ds = TokenDataset::new((0..200u8).collect());
        let w = ds.windows(Split::Train, 2, 9);
        let (inp, tgt) = &w[0];
        assert_eq!(inp.len(), 18);
        assert_eq!(inp[0] + 1, tgt[0]);
        assert_eq!(inp[8] + 1, tgt[8]);
        // second sequence starts where the first window ended
        assert_eq!(inp[9], 10);
    }

    #[test]
    fn embed_gathers_rows() {
        let emb = Matrix::from_vec(4, 2, vec![0., 0., 1., 1., 2., 2., 3., 3.]);
        let x = embed(&[3, 0, 2], &emb);
        assert_eq!(x.data, vec![3., 3., 0., 0., 2., 2.]);
    }
}
