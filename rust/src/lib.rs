//! # SDQ — Sparse Decomposed Quantization for LLM Inference
//!
//! Full-system reproduction of *SDQ: Sparse Decomposed Quantization for
//! LLM Inference* (Jeong, Tsai, Keckler, Krishna; cs.LG 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the compression library (sparsify → decompose
//!   → quantize), the serving coordinator, the analytical performance
//!   model for N:M structured-sparse tensor-core hardware, and every
//!   substrate the paper's evaluation depends on (transformer inference
//!   engine, perplexity / zero-shot harness, synthetic corpus).
//! * **L2 (python/compile/model.py)** — JAX model graphs lowered AOT to
//!   HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the decomposed
//!   dual-quantized GEMM hot spot (interpret=True for CPU PJRT).
//!
//! Python never runs on the request path: the [`runtime`] module loads
//! the AOT artifacts via PJRT and the coordinator serves from Rust.
//!
//! ## Quick tour
//!
//! ```no_run
//! use sdq::sdq::config::CompressionConfig;
//! // Parse the paper's own configuration naming scheme:
//! let cfg: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
//! assert_eq!(cfg.effective_throughput(), 4.0);
//! ```

pub mod artifacts;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod formats;
pub mod harness;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod sdq;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
