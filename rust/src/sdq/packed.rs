//! Packed N:M structured-sparse storage (§3.3, Fig. 4).
//!
//! ELLPACK-like layout: for every M-block of every row we store exactly
//! `N` value slots plus `log2(M)`-bit intra-block indices — the format a
//! structured-sparse tensor core streams. Blocks with fewer than N
//! survivors are zero-padded (a zero value with index 0 is a no-op MAC).
//!
//! The packed form powers
//! * the **bits-per-weight accounting** (`perfmodel::bits`),
//! * the **sparse compute path**: [`PackedNm::spmm_into`] skips all
//!   pruned positions, the CPU analogue of the paper's sparse-TC SpMM.
//!   Like the dense GEMM, it switches to a column-parallel schedule for
//!   small ragged serving batches, so compressed layers ride the fused
//!   decode/prefill path at full core occupancy.

use anyhow::bail;
use crate::util::par::par_chunks_mut;

use super::nm::NmPattern;
use crate::tensor::Matrix;
use crate::Result;

/// A matrix packed under an N:M pattern along the column (input) dim.
#[derive(Clone, Debug)]
pub struct PackedNm {
    pub pattern: NmPattern,
    pub rows: usize,
    pub cols: usize,
    /// `rows × blocks × N` value slots (zero-padded).
    pub values: Vec<f32>,
    /// Intra-block position of each value slot (0..M).
    pub indices: Vec<u8>,
    /// Absolute column of each value slot (precomputed for the hot loop).
    pub abs_cols: Vec<u32>,
}

impl PackedNm {
    /// Blocks per row.
    pub fn blocks(&self) -> usize {
        self.cols / self.pattern.m
    }

    /// Value slots per row.
    pub fn slots_per_row(&self) -> usize {
        self.blocks() * self.pattern.n
    }

    /// Stored non-zero count (excludes padding).
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|v| **v != 0.0).count()
    }

    /// Unpack to a dense matrix.
    pub fn unpack(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let spr = self.slots_per_row();
        for r in 0..self.rows {
            for s in 0..spr {
                let v = self.values[r * spr + s];
                if v != 0.0 {
                    out.data[r * self.cols + self.abs_cols[r * spr + s] as usize] = v;
                }
            }
        }
        out
    }

    /// One output element's gather-dot: `Σ_s values[o, s] · x[col(o, s)]`.
    /// 4 independent accumulators hide the FMA latency of the serial
    /// gather chain (§Perf iteration 7). Shared by both parallel
    /// schedules below so their numerics are identical.
    #[inline]
    fn row_dot(&self, o: usize, xrow: &[f32]) -> f32 {
        let spr = self.slots_per_row();
        let vals = &self.values[o * spr..(o + 1) * spr];
        let cols = &self.abs_cols[o * spr..(o + 1) * spr];
        let mut acc = [0.0f32; 4];
        let q = spr / 4 * 4;
        for i in (0..q).step_by(4) {
            for l in 0..4 {
                acc[l] += vals[i + l] * xrow[cols[i + l] as usize];
            }
        }
        let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
        for i in q..spr {
            s += vals[i] * xrow[cols[i] as usize];
        }
        s
    }

    /// Structured-sparse GEMM: `out[t, o] += Σ_s values[o, s] · x[t, col(o, s)]`.
    ///
    /// `x: [tokens, cols]`, `out: [tokens, rows]`. This is the CPU
    /// analogue of the sparse tensor-core SpMM: work scales with N/M.
    ///
    /// Parallel schedule mirrors `tensor::matmul_into`: wide activations
    /// parallelize over token rows; small ragged decode/prefill batches
    /// (fewer rows than a row tile) parallelize over output-column
    /// blocks instead, so compressed layers keep every core busy on the
    /// fused serving path. Single rows stay sequential — the
    /// per-sequence baseline parallelizes across sequences and must not
    /// nest thread scopes.
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        assert_eq!(x.cols, self.cols);
        assert_eq!(out.rows, x.rows);
        assert_eq!(out.cols, self.rows);
        let n = self.rows;
        // Token-row tile / column-block sizes matching the dense GEMM's
        // column-parallel crossover.
        const TB: usize = 16;
        const CB: usize = 64;
        if x.rows > 1 && x.rows < TB && n >= 2 * CB && crate::util::par::num_threads() > 1 {
            let rows = x.rows;
            let nb = n.div_ceil(CB);
            let parts: Vec<Vec<f32>> = crate::util::par::par_map(nb, |bi| {
                let o0 = bi * CB;
                let o1 = (o0 + CB).min(n);
                let mut part = vec![0.0f32; rows * (o1 - o0)];
                for t in 0..rows {
                    let xrow = x.row(t);
                    for o in o0..o1 {
                        part[t * (o1 - o0) + (o - o0)] = self.row_dot(o, xrow);
                    }
                }
                part
            });
            for (bi, part) in parts.iter().enumerate() {
                let o0 = bi * CB;
                let o1 = (o0 + CB).min(n);
                let bw = o1 - o0;
                for t in 0..rows {
                    let orow = &mut out.data[t * n + o0..t * n + o1];
                    for (c, p) in orow.iter_mut().zip(&part[t * bw..(t + 1) * bw]) {
                        *c += *p;
                    }
                }
            }
            return;
        }
        par_chunks_mut(&mut out.data, n, |t, orow| {
            let xrow = x.row(t);
            for (o, o_el) in orow.iter_mut().enumerate() {
                *o_el += self.row_dot(o, xrow);
            }
        });
    }

    /// Storage bits for values at `value_bits` per element, *excluding*
    /// scale-factor metadata (that is format-level, see `perfmodel`).
    pub fn value_bits_total(&self, value_bits: u32) -> u64 {
        (self.values.len() as u64) * value_bits as u64
    }

    /// Index-metadata bits: `log2(M)` per stored slot.
    pub fn index_bits_total(&self) -> u64 {
        (self.indices.len() as u64) * self.pattern.index_bits() as u64
    }
}

/// Pack `w` under `pat`. Fails if any block exceeds N non-zeros (i.e. the
/// matrix does not actually satisfy the pattern).
pub fn pack(w: &Matrix, pat: NmPattern) -> Result<PackedNm> {
    if w.cols % pat.m != 0 {
        bail!("cols {} not a multiple of M={}", w.cols, pat.m);
    }
    let blocks = w.cols / pat.m;
    let spr = blocks * pat.n;
    let mut values = vec![0.0f32; w.rows * spr];
    let mut indices = vec![0u8; w.rows * spr];
    let mut abs_cols = vec![0u32; w.rows * spr];
    for r in 0..w.rows {
        let row = w.row(r);
        for b in 0..blocks {
            let blk = &row[b * pat.m..(b + 1) * pat.m];
            let mut slot = 0;
            for (i, v) in blk.iter().enumerate() {
                if *v != 0.0 {
                    if slot >= pat.n {
                        bail!(
                            "row {r} block {b} has more than N={} non-zeros; \
                             matrix violates {pat}",
                            pat.n
                        );
                    }
                    let s = r * spr + b * pat.n + slot;
                    values[s] = *v;
                    indices[s] = i as u8;
                    abs_cols[s] = (b * pat.m + i) as u32;
                    slot += 1;
                }
            }
            // Padding slots keep index 0 / abs col = block start: value 0
            // makes them no-op MACs.
            for pad in slot..pat.n {
                let s = r * spr + b * pat.n + pad;
                abs_cols[s] = (b * pat.m) as u32;
            }
        }
    }
    Ok(PackedNm { pattern: pat, rows: w.rows, cols: w.cols, values, indices, abs_cols })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdq::nm::topn_block_mask;
    use crate::tensor::matmul;
    use crate::util::rng::Rng;

    fn sparse_matrix(rows: usize, cols: usize, pat: NmPattern, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from_u64(seed);
        let mut w = Matrix::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
        );
        for r in 0..rows {
            let row = w.row_mut(r);
            let scores: Vec<f32> = row.iter().map(|v| v.abs()).collect();
            let mut mask = vec![false; cols];
            topn_block_mask(&scores, pat, &mut mask);
            for (v, keep) in row.iter_mut().zip(&mask) {
                if !keep {
                    *v = 0.0;
                }
            }
        }
        w
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let pat = NmPattern::new(2, 8);
        let w = sparse_matrix(16, 64, pat, 1);
        let p = pack(&w, pat).unwrap();
        assert_eq!(p.unpack(), w);
        assert_eq!(p.values.len(), 16 * (64 / 8) * 2);
    }

    #[test]
    fn pack_rejects_violations() {
        let w = Matrix::from_vec(1, 8, vec![1., 1., 1., 0., 0., 0., 0., 0.]);
        assert!(pack(&w, NmPattern::new(2, 8)).is_err());
        assert!(pack(&w, NmPattern::new(3, 8)).is_ok());
    }

    #[test]
    fn spmm_matches_dense_gemm() {
        let pat = NmPattern::new(2, 4);
        let w = sparse_matrix(24, 32, pat, 2);
        let p = pack(&w, pat).unwrap();
        let mut rng = Rng::seed_from_u64(3);
        let x = Matrix::from_vec(5, 32, (0..160).map(|_| rng.range_f32(-1.0, 1.0)).collect());
        let dense = matmul(&x, &w);
        let mut sparse = Matrix::zeros(5, 24);
        p.spmm_into(&x, &mut sparse);
        for (a, b) in dense.data.iter().zip(&sparse.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn spmm_column_parallel_path_matches_dense() {
        // 4 activation rows × ≥128 output rows triggers the
        // column-parallel schedule (when threads > 1); numerics must
        // match the row-parallel path and the dense GEMM.
        let pat = NmPattern::new(2, 8);
        let w = sparse_matrix(160, 64, pat, 6);
        let p = pack(&w, pat).unwrap();
        let mut rng = Rng::seed_from_u64(7);
        let x =
            Matrix::from_vec(4, 64, (0..4 * 64).map(|_| rng.range_f32(-1.0, 1.0)).collect());
        let dense = matmul(&x, &w);
        // Accumulation semantics must survive the parallel split too.
        let mut sparse = Matrix::from_vec(4, 160, vec![1.0; 4 * 160]);
        p.spmm_into(&x, &mut sparse);
        for (a, b) in dense.data.iter().zip(&sparse.data) {
            assert!((a + 1.0 - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn spmm_accumulates() {
        let pat = NmPattern::new(1, 4);
        let w = sparse_matrix(4, 8, pat, 4);
        let p = pack(&w, pat).unwrap();
        let x = Matrix::from_vec(1, 8, vec![1.0; 8]);
        let mut out = Matrix::zeros(1, 4);
        p.spmm_into(&x, &mut out);
        let first = out.clone();
        p.spmm_into(&x, &mut out);
        for (a, b) in out.data.iter().zip(&first.data) {
            assert!((a - 2.0 * b).abs() < 1e-6);
        }
    }

    #[test]
    fn metadata_bits_match_formula() {
        // Fig 4 arithmetic: 2:4 → 2 bits/index × 2 slots per block.
        let pat = NmPattern::new(2, 4);
        let w = sparse_matrix(1, 8, pat, 5);
        let p = pack(&w, pat).unwrap();
        assert_eq!(p.index_bits_total(), 4 * 2); // 2 blocks × 2 slots × 2 bits
        assert_eq!(p.value_bits_total(4), 4 * 4);
    }

    #[test]
    fn underfull_blocks_pad_with_zero() {
        let w = Matrix::from_vec(1, 8, vec![0., 0., 0., 0., 5., 0., 0., 0.]);
        let p = pack(&w, NmPattern::new(2, 4)).unwrap();
        assert_eq!(p.nnz(), 1);
        assert_eq!(p.unpack(), w);
        let x = Matrix::from_vec(1, 8, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let mut out = Matrix::zeros(1, 1);
        p.spmm_into(&x, &mut out);
        assert_eq!(out.data[0], 25.0);
    }
}
