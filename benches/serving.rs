//! Serving benchmark: the **paged** engine (shared KV block pool,
//! prefix sharing, batched multi-prompt prefill, one fused GEMM per
//! layer per decode round) vs the **per-sequence** baseline (private
//! chunked caches, one batch-1 forward per sequence), dense vs SDQ
//! compressed, across batch widths **and KV storage dtypes** — the
//! end-to-end L3 numbers. Requests share a common prompt prefix, so the
//! pool's prefix-share hit-rate, utilization and eviction counters are
//! exercised and reported. Greedy outputs are asserted bit-identical
//! between the f32-paged and per-sequence engines on every row; the
//! quantized-KV rows (fp8-e4m3 / int8 blocks with per-block-per-layer
//! scales) report their greedy-token divergence vs the f32 run and the
//! compressed pool geometry — the same byte budget buys ~4× the blocks
//! at int8, which the bench asserts (≥ 1.8× effective capacity). The
//! `kv dequant / kv avoided KiB` columns report the pool's dequant
//! traffic counters; the int8 rows assert the quantized-domain
//! attention path left the scratch counter at exactly zero (every read
//! decoded codes in register via `kv::qattn`).
//!
//! A **preemption arm** rides per config: an oversubscribed workload —
//! more concurrent requests than worst-case reservation can admit at a
//! deliberately tight block budget — served three ways: unconstrained
//! (the token oracle), tight budget with the worst-case-reservation
//! baseline, and tight budget with preemptive swap-out/swap-in
//! (`BatchPolicy::preempt`). The preemptive row must admit ≥ 1.5× the
//! baseline's peak concurrency **and** finish in fewer decode rounds
//! (higher admitted throughput), with every request's greedy output
//! bit-identical to the unconstrained run — asserted for f32 *and* int8
//! pools (quantized resumes re-install snapshot bytes, so preemption is
//! exact at every dtype).
//!
//! A **speculative-decode sweep** rides on top: per config/width, two
//! extra f32-pool rows serve the same requests with drafting on —
//! `ngram` (self-lookup, zero extra weights) and `sdq-draft` (a draft
//! model built from the same base weights at the same config: the
//! acceptance *ceiling* arm — identical numerics mean every draft
//! matches; rougher draft configs are `examples/serve.rs --draft-config`
//! territory). Both rows are asserted **bit-identical** to the non-spec
//! f32 greedy outputs; the sdq-draft row additionally asserts
//! acceptance rate > 0 and **fewer decode rounds** than the identical
//! non-spec run (structural guarantees — plain batching already puts
//! tokens/round near the batch width, so round count is the metric a
//! broken accept path can't fake). The n-gram row's acceptance depends
//! on how repetitive the model's output is and is reported, not
//! asserted.
//!
//! The `weight MiB / w streamed KiB / w avoided KiB` columns report the
//! **actual packed weight bytes** (quantized codes + scales + N:M
//! sparse metadata — `Model::weight_bytes`) and the per-run weight
//! traffic split (`Metrics::weight_bytes_streamed/avoided`): compressed
//! configs serve from real codes (`QuantMat` planes decoded in-register
//! by `matmul_q_into`, value-packed SpMM), so every int8-bearing config
//! is asserted to stream ≥3.5× fewer weight bytes than its dense f32
//! view.
//!
//! Emits `BENCH_serving.json` (cwd) plus the usual
//! `target/bench-results/serving.json` record so the perf trajectory is
//! tracked across PRs (and gated by CI's `bench-regression` job against
//! `ci/bench_baseline.json`). Falls back to a synthetic model when
//! `make artifacts` hasn't been run, so the A/B comparison is always
//! available. `--smoke` runs one config at one width with a few short
//! requests — the CI guard that keeps this bench compiling *and*
//! running; in smoke mode the int8 row is additionally asserted to
//! produce the exact f32 greedy tokens on the synthetic model.

use sdq::coordinator::batcher::{BatchPolicy, Batcher};
use sdq::coordinator::scheduler::Scheduler;
use sdq::coordinator::{assert_bit_identical, Request};
use sdq::harness;
use sdq::kv::KvDtype;
use sdq::model::testutil::synth_model;
use sdq::model::Model;
use sdq::sdq::calib::CalibStats;
use sdq::sdq::config::CompressionConfig;
use sdq::spec::{SdqDrafter, SpecPolicy};
use sdq::util::bench::Table;
use sdq::util::rng::Rng;

/// Drafted tokens per sequence per round in the spec rows.
const SPEC_K: usize = 3;

/// Calibration stats from a forward pass over random tokens (fallback
/// path — no corpus on disk).
fn synth_calib(model: &Model) -> CalibStats {
    let mut stats = CalibStats::new(false);
    let mut rng = Rng::seed_from_u64(7);
    let seq = model.cfg.max_seq / 2;
    let tokens: Vec<u8> = (0..4 * seq).map(|_| rng.below(256) as u8).collect();
    model.forward(&tokens, 4, seq, Some(&mut stats));
    stats
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let artifacts = harness::artifacts_ready();
    let (mname, base) = if artifacts {
        ("gpt-micro".to_string(), harness::load_model("gpt-micro").expect("model"))
    } else {
        eprintln!("benchmarking on a synthetic model instead");
        ("synthetic-gpt".to_string(), synth_model())
    };
    let ds = if artifacts { Some(harness::load_dataset().expect("corpus")) } else { None };

    let mut table = Table::new(
        &format!(
            "Serving: paged+batched vs per-sequence decode, KV dtype + speculative sweep — \
             {mname}"
        ),
        &[
            "Config",
            "kv dtype",
            "spec",
            "preempt",
            "max_active",
            "req",
            "batched tok/s",
            "per-seq tok/s",
            "speedup",
            "occupancy",
            "kv peak KiB",
            "pool blocks",
            "blk bytes",
            "pool util",
            "prefix hit",
            "evict",
            "kv dequant KiB",
            "kv avoided KiB",
            "weight MiB",
            "w streamed KiB",
            "w avoided KiB",
            "div vs f32",
            "spec drafted",
            "spec accepted",
            "accept rate",
            "tok/round",
        ],
    );
    let configs: &[&str] = if smoke {
        &["SDQ-W7:8-1:8int8-6:8fp4"]
    } else {
        &["Dense-WA16", "Q-VSQuant-WAint8", "SDQ-W7:8-1:8int8-6:8fp4"]
    };
    let widths: &[usize] = if smoke { &[4] } else { &[1, 4, 8] };
    let (n_req, max_new, plen) = if smoke { (6, 8, 24) } else { (16, 24, 32) };
    let mut prompt_rng = Rng::seed_from_u64(99);
    // All requests share a 16-token prompt prefix (one KV block): the
    // realistic system-prompt shape that paged sharing exploits —
    // later admission waves attach it instead of recomputing.
    let shared_prefix: Vec<u8> = match &ds {
        Some(ds) => ds.split(sdq::data::Split::Test)[..16].to_vec(),
        None => (0..16).map(|_| prompt_rng.below(256) as u8).collect(),
    };
    for cfg_str in configs {
        let cfg: CompressionConfig = cfg_str.parse().unwrap();
        let mut model = base.clone();
        let calib = match &ds {
            Some(ds) => harness::calibrate(&model, ds, 1024, harness::needs_gram(&cfg)),
            None => synth_calib(&model),
        };
        model.compress(&cfg, &calib).unwrap();
        // Honest weight accounting: actual packed resident bytes (codes
        // + scales + N:M metadata) and the per-forward stream split.
        // Every int8-bearing compressed config must stream ≥3.5× fewer
        // weight bytes than its dense f32 view — the point of carrying
        // real codes (QuantMat / value-quantized SpMM) to serving time.
        let weight_mib = model.weight_bytes() as f64 / (1024.0 * 1024.0);
        let (w_streamed, w_avoided) = model.weight_stream_bytes();
        if *cfg_str != "Dense-WA16" {
            let dense_w = (w_streamed + w_avoided) as f64;
            assert!(
                dense_w / w_streamed as f64 >= 3.5,
                "{cfg_str}: packed planes stream {w_streamed} of {dense_w} dense bytes \
                 (ratio {:.2} < 3.5)",
                dense_w / w_streamed as f64
            );
        }
        for &max_active in widths {
            // Same prompts for both modes — the A/B must only vary the
            // serving engine.
            let reqs: Vec<Request> = (0..n_req)
                .map(|i| {
                    let mut prompt = shared_prefix.clone();
                    let tail: Vec<u8> = match &ds {
                        Some(ds) => {
                            let test = ds.split(sdq::data::Split::Test);
                            let start = (i * 1013) % (test.len() - plen - 1);
                            test[start..start + plen - 16].to_vec()
                        }
                        None => {
                            (0..plen - 16).map(|_| prompt_rng.below(256) as u8).collect()
                        }
                    };
                    prompt.extend_from_slice(&tail);
                    Request::new(i as u64, prompt, max_new)
                })
                .collect();
            // Synchronous scheduler drive (not the threaded `Engine`):
            // every request is enqueued before round one, so admission
            // waves — and with them the pool's prefix-hit-rate and
            // utilization counters — are exactly reproducible. The CI
            // regression gate compares those numbers against a committed
            // baseline, so they must not depend on submission timing.
            let run = |batched: bool, dtype: KvDtype, spec: Option<SpecPolicy>, reqs: Vec<Request>| {
                let policy = BatchPolicy {
                    max_active,
                    batched_decode: batched,
                    kv_dtype: Some(dtype),
                    ..Default::default()
                };
                let mut sched = Scheduler::with_spec(&model, policy, spec);
                let mut batcher = Batcher::new();
                for r in reqs {
                    batcher.enqueue(r);
                }
                let mut resps = sched.run_to_completion(&mut batcher);
                assert_eq!(resps.len(), n_req);
                resps.sort_by_key(|r| r.id);
                (resps, sched.metrics)
            };
            let (legacy_out, per_seq) = run(false, KvDtype::F32, None, reqs.clone());
            // KV dtype sweep: the f32 row is the exact reference; the
            // quantized rows report compressed pool geometry and their
            // greedy-token divergence against it.
            let mut f32_out: Vec<sdq::coordinator::Response> = Vec::new();
            let mut f32_blocks = 0usize;
            let mut f32_rounds = 0u64;
            let mut int8_blocks = 0usize;
            for dtype in [KvDtype::F32, KvDtype::Int8, KvDtype::Fp8E4M3, KvDtype::Int4Outlier] {
                let (paged_out, batched) = run(true, dtype, None, reqs.clone());
                let divergence: usize = if dtype == KvDtype::F32 {
                    // Live equivalence guard: paged + fused must not
                    // change a single greedy token vs the chunked
                    // per-sequence baseline.
                    assert_bit_identical(
                        &format!("{cfg_str} active={max_active} paged vs per-seq"),
                        &paged_out,
                        &legacy_out,
                    );
                    f32_out = paged_out.clone();
                    f32_blocks = batched.pool_budget_blocks;
                    f32_rounds = batched.decode_rounds;
                    0
                } else {
                    paged_out
                        .iter()
                        .zip(&f32_out)
                        .map(|(r, want)| {
                            let same = r
                                .tokens
                                .iter()
                                .zip(&want.tokens)
                                .filter(|(a, b)| a == b)
                                .count();
                            r.tokens.len().max(want.tokens.len()) - same
                        })
                        .sum()
                };
                if dtype == KvDtype::Int8 {
                    int8_blocks = batched.pool_budget_blocks;
                    // Compressed storage is the point: the same byte
                    // budget must buy substantially more blocks.
                    assert!(
                        batched.pool_budget_blocks as f64 >= 1.8 * f32_blocks as f64,
                        "int8 pool must hold ≥1.8× the blocks of f32 at the same budget \
                         ({} vs {})",
                        batched.pool_budget_blocks,
                        f32_blocks
                    );
                    // Quantized-domain acceptance: int8 decode must
                    // never stage dequantized KV through scratch — every
                    // read rides `layer_code_views` + `kv::qattn`.
                    assert_eq!(
                        batched.kv_dequant_bytes, 0,
                        "int8 decode staged dequantized KV through scratch"
                    );
                    assert!(
                        batched.kv_dequant_bytes_avoided > 0,
                        "int8 decode reported no quantized-domain reads"
                    );
                    if smoke {
                        // CI acceptance: on the synthetic model the
                        // int8-KV engine reproduces the f32 greedy
                        // tokens exactly.
                        assert_eq!(
                            divergence, 0,
                            "smoke: int8 KV diverged from f32 greedy outputs"
                        );
                    }
                }
                if dtype == KvDtype::Int4Outlier {
                    // Packed nibbles halve the dense plane again (the
                    // bounded outlier side-table rides outside the
                    // uniform block charge), so the same byte budget
                    // must admit ≥1.8× int8's blocks.
                    assert!(
                        batched.pool_budget_blocks as f64 >= 1.8 * int8_blocks as f64,
                        "int4 pool must hold ≥1.8× the blocks of int8 at the same budget \
                         ({} vs {})",
                        batched.pool_budget_blocks,
                        int8_blocks
                    );
                    assert_eq!(
                        batched.kv_dequant_bytes, 0,
                        "int4 decode staged dequantized KV through scratch"
                    );
                    assert!(
                        batched.kv_dequant_bytes_avoided > 0,
                        "int4 decode reported no quantized-domain reads"
                    );
                    // Divergence vs f32 is *reported* (the table's
                    // div column), only bounded here: a 4-bit dense
                    // plane is lossy, but outlier rows cap the error —
                    // blowing past half the tokens means the
                    // decomposition is broken, not merely coarse.
                    let total_tokens: usize =
                        f32_out.iter().map(|r| r.tokens.len()).sum();
                    assert!(
                        divergence <= total_tokens / 2,
                        "int4 KV diverged on {divergence}/{total_tokens} greedy tokens \
                         — outlier decomposition is not bounding the error"
                    );
                }
                let speedup =
                    batched.decode_tokens_per_second() / per_seq.decode_tokens_per_second();
                table.row(vec![
                    cfg_str.to_string(),
                    dtype.tag().to_string(),
                    "off".to_string(),
                    "off".to_string(),
                    max_active.to_string(),
                    n_req.to_string(),
                    format!("{:.1}", batched.decode_tokens_per_second()),
                    format!("{:.1}", per_seq.decode_tokens_per_second()),
                    format!("{speedup:.2}x"),
                    format!("{:.2}", batched.decode_occupancy(max_active)),
                    format!("{:.1}", batched.kv_bytes_peak as f64 / 1024.0),
                    batched.pool_budget_blocks.to_string(),
                    batched.pool_block_bytes.to_string(),
                    format!("{:.3}", batched.pool_utilization_peak),
                    format!("{:.2}", batched.prefix_hit_rate()),
                    batched.kv_evictions.to_string(),
                    format!("{:.1}", batched.kv_dequant_bytes as f64 / 1024.0),
                    format!("{:.1}", batched.kv_dequant_bytes_avoided as f64 / 1024.0),
                    format!("{weight_mib:.2}"),
                    format!("{:.1}", batched.weight_bytes_streamed as f64 / 1024.0),
                    format!("{:.1}", batched.weight_bytes_avoided as f64 / 1024.0),
                    divergence.to_string(),
                    "0".to_string(),
                    "0".to_string(),
                    "0.00".to_string(),
                    format!("{:.2}", batched.tokens_per_round()),
                ]);
                eprintln!(
                    "  {cfg_str} kv={} active={max_active}: batched {} | per-seq decode \
                     {:.1} tok/s | div vs f32 = {divergence}",
                    dtype.tag(),
                    batched.summary(),
                    per_seq.decode_tokens_per_second()
                );
            }

            // ---- speculative arms (f32 pool, same requests) ----
            // `sdq-draft` here is the acceptance-ceiling arm: the draft
            // is compressed from the same base at the same config, so
            // its greedy proposals always match and acceptance is
            // structural (asserted), not statistical. `ngram` reports
            // whatever the workload's self-similarity buys.
            for mode in ["ngram", "sdq-draft"] {
                let spec = if mode == "ngram" {
                    SpecPolicy::ngram(SPEC_K)
                } else {
                    let drafter =
                        SdqDrafter::from_base(&base, &cfg, &calib).expect("draft compression");
                    SpecPolicy::sdq(SPEC_K, drafter)
                };
                let (spec_out, sm) = run(true, KvDtype::F32, Some(spec), reqs.clone());
                // Speculative greedy output must be bit-identical to the
                // non-speculative f32 run on every request.
                assert_bit_identical(
                    &format!("{cfg_str} active={max_active} spec={mode} vs plain greedy"),
                    &spec_out,
                    &f32_out,
                );
                if mode == "sdq-draft" {
                    assert!(sm.spec_drafted > 0, "sdq-draft: drafter never fired");
                    assert!(
                        sm.spec_acceptance_rate() > 0.0,
                        "sdq-draft: identical draft model must accept"
                    );
                    assert!(sm.tokens_per_round() > 1.0, "tokens/round must exceed 1");
                    // The teeth: accepted drafts must actually shrink
                    // the round count vs the identical non-spec run —
                    // plain batching alone already puts tokens/round
                    // near the batch width, so rounds are the metric a
                    // broken accept path can't fake.
                    assert!(
                        sm.decode_rounds < f32_rounds,
                        "sdq-draft: full acceptance must finish in fewer rounds \
                         ({} vs non-spec {})",
                        sm.decode_rounds,
                        f32_rounds
                    );
                }
                table.row(vec![
                    cfg_str.to_string(),
                    "f32".to_string(),
                    mode.to_string(),
                    "off".to_string(),
                    max_active.to_string(),
                    n_req.to_string(),
                    format!("{:.1}", sm.decode_tokens_per_second()),
                    format!("{:.1}", per_seq.decode_tokens_per_second()),
                    format!(
                        "{:.2}x",
                        sm.decode_tokens_per_second() / per_seq.decode_tokens_per_second()
                    ),
                    format!("{:.2}", sm.decode_occupancy(max_active)),
                    format!("{:.1}", sm.kv_bytes_peak as f64 / 1024.0),
                    sm.pool_budget_blocks.to_string(),
                    sm.pool_block_bytes.to_string(),
                    format!("{:.3}", sm.pool_utilization_peak),
                    format!("{:.2}", sm.prefix_hit_rate()),
                    sm.kv_evictions.to_string(),
                    format!("{:.1}", sm.kv_dequant_bytes as f64 / 1024.0),
                    format!("{:.1}", sm.kv_dequant_bytes_avoided as f64 / 1024.0),
                    format!("{weight_mib:.2}"),
                    format!("{:.1}", sm.weight_bytes_streamed as f64 / 1024.0),
                    format!("{:.1}", sm.weight_bytes_avoided as f64 / 1024.0),
                    "0".to_string(),
                    sm.spec_drafted.to_string(),
                    sm.spec_accepted.to_string(),
                    format!("{:.2}", sm.spec_acceptance_rate()),
                    format!("{:.2}", sm.tokens_per_round()),
                ]);
                eprintln!(
                    "  {cfg_str} kv=f32 spec={mode} active={max_active}: {} | accept {:.2} | \
                     {:.2} tok/round",
                    sm.summary(),
                    sm.spec_acceptance_rate(),
                    sm.tokens_per_round()
                );
            }
        }

        // ---- oversubscribed preemption arm (per config) ----
        // 8 concurrent requests whose worst-case footprint (3 blocks
        // each) more than doubles a 6-block budget: worst-case
        // reservation caps concurrency at 2, resident-charged admission
        // with preemption packs the pool and swaps under pressure. The
        // preemptive run must beat the baseline's peak concurrency by
        // ≥ 1.5× and finish in fewer decode rounds, with greedy output
        // bit-identical to an unconstrained pool — at f32 AND int8.
        {
            let mut over_rng = Rng::seed_from_u64(1234);
            let (n_over, over_new, over_plen, over_blocks) = (8usize, 40usize, 8usize, 6usize);
            let over_reqs: Vec<Request> = (0..n_over)
                .map(|i| {
                    let prompt: Vec<u8> =
                        (0..over_plen).map(|_| over_rng.below(256) as u8).collect();
                    Request::new(i as u64, prompt, over_new)
                })
                .collect();
            let mut over_f32: Vec<sdq::coordinator::Response> = Vec::new();
            for dtype in [KvDtype::F32, KvDtype::Int8] {
                let block_bytes =
                    sdq::kv::BlockPool::with_dtype(&model.cfg, 1, dtype).block_bytes();
                let run_over = |budget_blocks: usize, preempt: bool| {
                    let policy = BatchPolicy {
                        max_active: n_over,
                        kv_budget_bytes: budget_blocks * block_bytes,
                        kv_dtype: Some(dtype),
                        preempt,
                        ..Default::default()
                    };
                    let mut sched = Scheduler::new(&model, policy);
                    let mut batcher = Batcher::new();
                    for r in over_reqs.clone() {
                        batcher.enqueue(r);
                    }
                    let mut resps = sched.run_to_completion(&mut batcher);
                    assert_eq!(resps.len(), n_over);
                    sched.pool().assert_consistent();
                    resps.sort_by_key(|r| r.id);
                    (resps, sched.metrics)
                };
                // Unconstrained pool: the bit-identity oracle (1024
                // blocks ≫ the 24-block worst case).
                let (want, _) = run_over(1024, false);
                let (base_out, base) = run_over(over_blocks, false);
                let (pre_out, pre) = run_over(over_blocks, true);
                let ctx = |arm: &str| format!("{cfg_str} kv={} oversubscribed {arm}", dtype.tag());
                assert_bit_identical(&ctx("baseline"), &base_out, &want);
                assert_bit_identical(&ctx("preempt"), &pre_out, &want);
                assert!(pre.preemptions > 0, "{}: pressure never preempted", ctx("preempt"));
                assert_eq!(pre.resumes, pre.preemptions, "{}: stranded swaps", ctx("preempt"));
                assert!(
                    pre.decode_width_max as f64 >= 1.5 * base.decode_width_max as f64,
                    "{}: admitted concurrency {} must be ≥1.5× the reserved baseline's {}",
                    ctx("preempt"),
                    pre.decode_width_max,
                    base.decode_width_max
                );
                assert!(
                    pre.decode_rounds < base.decode_rounds,
                    "{}: preemption must raise admitted throughput \
                     ({} rounds vs baseline {})",
                    ctx("preempt"),
                    pre.decode_rounds,
                    base.decode_rounds
                );
                // "div vs f32" reports the int8 row's token distance
                // from the f32 oracle (bit-identity *within* a dtype is
                // asserted above; cross-dtype drift is informational,
                // exactly like the main sweep's quantized rows).
                let divergence: usize = if dtype == KvDtype::F32 {
                    over_f32 = want.clone();
                    0
                } else {
                    pre_out
                        .iter()
                        .zip(&over_f32)
                        .map(|(a, b)| {
                            let same =
                                a.tokens.iter().zip(&b.tokens).filter(|(x, y)| x == y).count();
                            a.tokens.len().max(b.tokens.len()) - same
                        })
                        .sum()
                };
                table.row(vec![
                    cfg_str.to_string(),
                    dtype.tag().to_string(),
                    "off".to_string(),
                    "on".to_string(),
                    n_over.to_string(),
                    n_over.to_string(),
                    format!("{:.1}", pre.decode_tokens_per_second()),
                    format!("{:.1}", base.decode_tokens_per_second()),
                    format!(
                        "{:.2}x",
                        pre.decode_tokens_per_second() / base.decode_tokens_per_second()
                    ),
                    format!("{:.2}", pre.decode_occupancy(n_over)),
                    format!("{:.1}", pre.kv_bytes_peak as f64 / 1024.0),
                    pre.pool_budget_blocks.to_string(),
                    pre.pool_block_bytes.to_string(),
                    format!("{:.3}", pre.pool_utilization_peak),
                    format!("{:.2}", pre.prefix_hit_rate()),
                    pre.kv_evictions.to_string(),
                    format!("{:.1}", pre.kv_dequant_bytes as f64 / 1024.0),
                    format!("{:.1}", pre.kv_dequant_bytes_avoided as f64 / 1024.0),
                    format!("{weight_mib:.2}"),
                    format!("{:.1}", pre.weight_bytes_streamed as f64 / 1024.0),
                    format!("{:.1}", pre.weight_bytes_avoided as f64 / 1024.0),
                    divergence.to_string(),
                    "0".to_string(),
                    "0".to_string(),
                    "0.00".to_string(),
                    format!("{:.2}", pre.tokens_per_round()),
                ]);
                eprintln!(
                    "  {cfg_str} kv={} oversubscribed preempt: {} | width {}→{} | rounds {}→{} \
                     | preempts {} swap {:.1}KiB reprefill {}",
                    dtype.tag(),
                    pre.summary(),
                    base.decode_width_max,
                    pre.decode_width_max,
                    base.decode_rounds,
                    pre.decode_rounds,
                    pre.preemptions,
                    pre.swap_bytes as f64 / 1024.0,
                    pre.resume_reprefill_tokens
                );
            }
        }

        // ---- tiered-spill arm (SDQ config only: 5 rows) ----
        // The same oversubscribed shape with preemption on and the
        // victim cost model pinned to one tier per row: `resident`
        // (snapshots stay in host memory — the preemption arm's
        // behavior), `spill` (zero resident budget, every victim
        // round-trips the disk tier through the versioned wire format),
        // and `reprefill` (no disk tier at all — f32 victims drop their
        // KV and replay it at resume; quantized replay is not bit-exact,
        // so int8 has no reprefill row). Every tier must reproduce the
        // unconstrained pool's greedy output bit-identically; the spill
        // rows additionally assert the disk round-trip was byte-exact
        // (bytes restored == bytes spilled). The `speedup` column is
        // tier throughput vs the resident tier — the cost of each rung.
        if *cfg_str == "SDQ-W7:8-1:8int8-6:8fp4" {
            use sdq::swap::{SwapConfig, SwapDir};
            use sdq::util::testdir::TempDir;
            let mut tier_rng = Rng::seed_from_u64(4321);
            let (n_t, t_new, t_plen, t_blocks) = (8usize, 40usize, 8usize, 6usize);
            let tier_reqs: Vec<Request> = (0..n_t)
                .map(|i| {
                    let prompt: Vec<u8> = (0..t_plen).map(|_| tier_rng.below(256) as u8).collect();
                    Request::new(i as u64, prompt, t_new)
                })
                .collect();
            let tmp = TempDir::new("serving-spill-bench");
            for dtype in [KvDtype::F32, KvDtype::Int8] {
                let block_bytes =
                    sdq::kv::BlockPool::with_dtype(&model.cfg, 1, dtype).block_bytes();
                let run_tier = |budget_blocks: usize, preempt: bool, swap: Option<SwapConfig>| {
                    let policy = BatchPolicy {
                        max_active: n_t,
                        kv_budget_bytes: budget_blocks * block_bytes,
                        kv_dtype: Some(dtype),
                        preempt,
                        ..Default::default()
                    };
                    let mut sched = Scheduler::new(&model, policy);
                    if let Some(cfg) = swap {
                        sched.set_swap(cfg);
                    }
                    let mut batcher = Batcher::new();
                    for r in tier_reqs.clone() {
                        batcher.enqueue(r);
                    }
                    let mut resps = sched.run_to_completion(&mut batcher);
                    assert_eq!(resps.len(), n_t);
                    sched.pool().assert_consistent();
                    resps.sort_by_key(|r| r.id);
                    (resps, sched.metrics)
                };
                let (want, _) = run_tier(1024, false, None);
                let tiers: &[&str] = if dtype == KvDtype::F32 {
                    &["resident", "spill", "reprefill"]
                } else {
                    &["resident", "spill"]
                };
                let mut resident_tps = 0.0f64;
                for tier in tiers {
                    let swap = match *tier {
                        "resident" => SwapConfig::default(),
                        "spill" => SwapConfig {
                            dir: Some(
                                SwapDir::new(tmp.path().join(format!("{}-{tier}", dtype.tag())))
                                    .expect("swap dir"),
                            ),
                            resident_budget_bytes: 0,
                            ..Default::default()
                        },
                        _ => SwapConfig { resident_budget_bytes: 0, ..Default::default() },
                    };
                    let (out, m) = run_tier(t_blocks, true, Some(swap));
                    let ctx = format!("{cfg_str} kv={} tier={tier}", dtype.tag());
                    assert_bit_identical(&ctx, &out, &want);
                    assert!(m.preemptions > 0, "{ctx}: pressure never preempted");
                    match *tier {
                        "spill" => {
                            assert!(m.spills > 0, "{ctx}: zero resident budget never spilled");
                            assert_eq!(m.restores, m.spills, "{ctx}: stranded spill files");
                            assert_eq!(
                                m.restored_bytes, m.spilled_bytes,
                                "{ctx}: disk round-trip must be byte-exact"
                            );
                            if dtype != KvDtype::F32 {
                                assert_eq!(
                                    m.reprefill_drops, 0,
                                    "{ctx}: quantized replay is not bit-exact"
                                );
                            }
                        }
                        "reprefill" => {
                            assert!(m.reprefill_drops > 0, "{ctx}: no disk tier: must replay");
                            assert_eq!(m.spills, 0, "{ctx}: spilled without a dir");
                        }
                        _ => assert_eq!(
                            m.spills + m.reprefill_drops,
                            0,
                            "{ctx}: unlimited resident budget must not leave host memory"
                        ),
                    }
                    let tps = m.decode_tokens_per_second();
                    if *tier == "resident" {
                        resident_tps = tps;
                    }
                    table.row(vec![
                        cfg_str.to_string(),
                        dtype.tag().to_string(),
                        "off".to_string(),
                        tier.to_string(),
                        n_t.to_string(),
                        n_t.to_string(),
                        format!("{tps:.1}"),
                        format!("{resident_tps:.1}"),
                        format!("{:.2}x", tps / resident_tps.max(f64::MIN_POSITIVE)),
                        format!("{:.2}", m.decode_occupancy(n_t)),
                        format!("{:.1}", m.kv_bytes_peak as f64 / 1024.0),
                        m.pool_budget_blocks.to_string(),
                        m.pool_block_bytes.to_string(),
                        format!("{:.3}", m.pool_utilization_peak),
                        format!("{:.2}", m.prefix_hit_rate()),
                        m.kv_evictions.to_string(),
                        format!("{:.1}", m.kv_dequant_bytes as f64 / 1024.0),
                        format!("{:.1}", m.kv_dequant_bytes_avoided as f64 / 1024.0),
                        format!("{weight_mib:.2}"),
                        format!("{:.1}", m.weight_bytes_streamed as f64 / 1024.0),
                        format!("{:.1}", m.weight_bytes_avoided as f64 / 1024.0),
                        "0".to_string(),
                        "0".to_string(),
                        "0".to_string(),
                        "0.00".to_string(),
                        format!("{:.2}", m.tokens_per_round()),
                    ]);
                    eprintln!(
                        "  {ctx}: {tps:.1} tok/s | preempts {} | spilled {:.1} KiB in {} files \
                         | restore {:.3} ms/seq | codec ratio {:.2} | reprefill drops {}",
                        m.preemptions,
                        m.spilled_bytes as f64 / 1024.0,
                        m.spills,
                        m.restore_mean_ms(),
                        m.spill_codec_ratio(),
                        m.reprefill_drops
                    );
                }
            }
        }
    }
    table.print();
    table.save_json("serving");
    // Cross-PR trajectory record at the repo root.
    let _ = std::fs::write("BENCH_serving.json", table.to_json().to_string());
}
