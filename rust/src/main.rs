//! `sdq` — command-line front end for the SDQ reproduction.
//!
//! Subcommands:
//!   gen-corpus   generate the synthetic corpus artifact
//!   info         model + configuration summary
//!   compress     compress a model and report per-layer stats
//!   eval-ppl     perplexity of a (compressed) model on the test split
//!   zeroshot     zero-shot task-suite accuracy
//!   serve        batched generation through the coordinator
//!   simulate     simulated sparse-tensor-core GEMM timing
//!   coverage     Fig. 5 local-outlier coverage analysis
//!   runtime      load + execute AOT PJRT artifacts (smoke)

use std::path::PathBuf;

use sdq::coordinator::{batcher::BatchPolicy, Engine, Request};
use sdq::data::{generate_corpus, CorpusCfg, Split, TokenDataset};
use sdq::eval::zeroshot;
use sdq::harness;
use sdq::perfmodel::simtc::TensorCoreSpec;
use sdq::sdq::config::CompressionConfig;
use sdq::sdq::decompose::{coverage, OutlierScope};
use sdq::sdq::nm::NmPattern;
use sdq::util::cli::Args;
use sdq::Result;

fn main() {
    let args = Args::parse();
    let r = match args.command.as_str() {
        "gen-corpus" => gen_corpus(&args),
        "info" => info(&args),
        "compress" => compress(&args),
        "eval-ppl" => eval_ppl(&args),
        "zeroshot" => zeroshot_cmd(&args),
        "serve" => serve(&args),
        "simulate" => simulate(&args),
        "coverage" => coverage_cmd(&args),
        "runtime" => runtime_cmd(&args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "sdq — Sparse Decomposed Quantization for LLM inference\n\n\
         USAGE: sdq <command> [--flags]\n\n\
         COMMANDS:\n\
           gen-corpus  --out PATH --bytes N --seed S      generate corpus artifact\n\
           info        --model NAME                        model summary\n\
           compress    --model NAME --config CFG           per-layer compression report\n\
           eval-ppl    --model NAME --config CFG           test-split perplexity\n\
           zeroshot    --model NAME --config CFG           zero-shot suite accuracy\n\
           serve       --model NAME --config CFG --requests N --max-new N\n\
           simulate    --config CFG --t N --k N --o N      simulated sparse-TC GEMM\n\
           coverage    --model NAME --extract N:M          Fig. 5 outlier coverage\n\
           runtime     --artifact NAME                     PJRT artifact smoke-run\n\n\
         CFG examples: Dense-WA16, S-Wanda-4:8, Q-VSQuant-WAint4,\n\
                       SDQ-W7:8-1:8int8-6:8fp4 (paper naming)"
    );
}

fn gen_corpus(args: &Args) -> Result<()> {
    let cfg = CorpusCfg {
        bytes: args.get_usize("bytes", 4 << 20)?,
        vocab_words: args.get_usize("vocab-words", 800)?,
        successors: args.get_usize("successors", 24)?,
        seed: args.get_u64("seed", 1234)?,
    };
    let out = PathBuf::from(args.get_or("out", "artifacts/corpus.bin"));
    let corpus = generate_corpus(&cfg);
    let ds = TokenDataset::new(corpus);
    ds.save(&out)?;
    println!(
        "wrote {} bytes to {} (train/valid/test = {}/{}/{})",
        ds.tokens.len(),
        out.display(),
        ds.split(Split::Train).len(),
        ds.split(Split::Valid).len(),
        ds.split(Split::Test).len()
    );
    Ok(())
}

fn parse_config(args: &Args) -> Result<CompressionConfig> {
    let s = args.get_or("config", "Dense-WA16");
    s.parse::<CompressionConfig>().map_err(|e| anyhow::anyhow!(e))
}

fn info(args: &Args) -> Result<()> {
    let name = args.get_or("model", "gpt-micro");
    let model = harness::load_model(name)?;
    let c = &model.cfg;
    println!(
        "model {name}: arch={:?} d_model={} n_layer={} n_head={} d_ff={}",
        c.arch, c.d_model, c.n_layer, c.n_head, c.d_ff
    );
    println!(
        "params: {:.2}M  max_seq={}  vocab={}",
        c.param_count() as f64 / 1e6,
        c.max_seq,
        c.vocab
    );
    for cfg_str in harness::table2_configs() {
        let cfg: CompressionConfig = cfg_str.parse().unwrap();
        let mc = sdq::perfmodel::model_cost(&cfg, &c.linear_shapes());
        println!(
            "  {:<28} tput {:>5.2}x  bits/w {:>6.3}  weight MiB {:>7.2}",
            cfg_str,
            mc.effective_throughput,
            mc.bits_per_weight,
            mc.weight_bytes / (1 << 20) as f64
        );
    }
    Ok(())
}

fn compress(args: &Args) -> Result<()> {
    let name = args.get_or("model", "gpt-micro");
    let cfg = parse_config(args)?;
    let mut model = harness::load_model(name)?;
    let ds = harness::load_dataset()?;
    let calib_tokens = args.get_usize("calib-tokens", 2048)?;
    let calib = harness::calibrate(&model, &ds, calib_tokens, harness::needs_gram(&cfg));
    let reports = model.compress(&cfg, &calib)?;
    println!("{:<20} {:>8} {:>10} {:>8} {:>8}", "layer", "density", "rel_err", "bits/w", "tput");
    for r in &reports {
        println!(
            "{:<20} {:>8.3} {:>10.5} {:>8.3} {:>7.2}x",
            r.name, r.density, r.rel_err, r.bits_per_weight, r.effective_throughput
        );
    }
    if let Some(out) = args.get("save") {
        let tensors: Vec<(String, sdq::tensor::Matrix)> = model
            .linears()
            .iter()
            .map(|l| (l.name.clone(), l.lin.dense_view().into_owned()))
            .collect();
        let refs: Vec<(String, &sdq::tensor::Matrix)> =
            tensors.iter().map(|(n, m)| (n.clone(), m)).collect();
        sdq::artifacts::save_weights(&PathBuf::from(out), &model.cfg.to_json(), &refs)?;
        println!("saved compressed dense views to {out}");
    }
    Ok(())
}

fn eval_ppl(args: &Args) -> Result<()> {
    let name = args.get_or("model", "gpt-micro");
    let cfg = parse_config(args)?;
    let model = harness::load_model(name)?;
    let ds = harness::load_dataset()?;
    let ecfg = harness::EvalCfg {
        calib_tokens: args.get_usize("calib-tokens", 2048)?,
        eval_tokens: args.get_usize("eval-tokens", 4096)?,
        batch: args.get_usize("batch", 8)?,
        seq: args.get_usize("seq", 64)?,
    };
    let t0 = std::time::Instant::now();
    let r = harness::eval_config(&model, &ds, &cfg, ecfg)?;
    println!(
        "{name} {cfg}: ppl {:.4} (nll {:.4}, {} tokens, tput {:.2}x, bits/w {:.3}, \
         rel_err {:.4}) [{:.1}s]",
        r.ppl.ppl,
        r.ppl.mean_nll,
        r.ppl.tokens,
        r.effective_throughput,
        r.bits_per_weight,
        r.mean_rel_err,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn zeroshot_cmd(args: &Args) -> Result<()> {
    let name = args.get_or("model", "gpt-micro");
    let cfg = parse_config(args)?;
    let mut model = harness::load_model(name)?;
    let ds = harness::load_dataset()?;
    let calib = harness::calibrate(&model, &ds, 2048, harness::needs_gram(&cfg));
    model.compress(&cfg, &calib)?;
    let per_task = args.get_usize("examples", 25)?;
    let tasks = zeroshot::build_tasks(&ds, per_task, 42);
    let (results, avg) = zeroshot::eval_suite(&model, &tasks);
    for r in &results {
        println!("  {:<12} {:>6.2}% ({} examples)", r.task, r.accuracy, r.examples);
    }
    println!("{name} {cfg}: average {avg:.2}%");
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let name = args.get_or("model", "gpt-micro");
    let cfg = parse_config(args)?;
    let mut model = harness::load_model(name)?;
    let ds = harness::load_dataset()?;
    let calib = harness::calibrate(&model, &ds, 1024, harness::needs_gram(&cfg));
    model.compress(&cfg, &calib)?;

    let n = args.get_usize("requests", 16)?;
    let max_new = args.get_usize("max-new", 32)?;
    let temperature = args.get_f64("temperature", 0.7)? as f32;
    let policy =
        BatchPolicy { max_active: args.get_usize("max-active", 8)?, ..Default::default() };
    // Prompts: snippets from the test split.
    let test = ds.split(Split::Test);
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let start = (i * 997) % (test.len() - 33);
            Request::new(i as u64, test[start..start + 32].to_vec(), max_new)
                .with_temperature(temperature)
        })
        .collect();
    let (responses, metrics) = Engine::run_batch(model, policy, reqs);
    for r in responses.iter().take(3) {
        println!(
            "--- request {} ({} tokens, ttft {:.1}ms) ---",
            r.id,
            r.tokens.len(),
            r.timing.ttft.as_secs_f64() * 1e3
        );
        println!("{}", r.text());
    }
    println!("{}", metrics.summary());
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let cfg = parse_config(args)?;
    let t = args.get_usize("t", 512)?;
    let k = args.get_usize("k", 4096)?;
    let o = args.get_usize("o", 4096)?;
    let spec = TensorCoreSpec::default();
    let r = spec.simulate(&cfg, t, k, o);
    println!(
        "{cfg} on [{t}x{k}]·[{o}x{k}]ᵀ: {} cycles ({:.3} ms), speedup {:.3}x \
         (analytic {:.3}x, tax {:.1}%)",
        r.cycles,
        spec.seconds(r.cycles) * 1e3,
        r.speedup,
        r.analytic_speedup,
        r.tax * 100.0
    );
    Ok(())
}

fn coverage_cmd(args: &Args) -> Result<()> {
    let name = args.get_or("model", "gpt-micro");
    let extract: NmPattern =
        args.get_or("extract", "1:8").parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let model = harness::load_model(name)?;
    let w = model.linears()[0].lin.dense_view();
    println!("coverage of {extract} local extraction on {name} layer0 q-proj:");
    println!("{:>8} {:>10} {:>12}", "ratio%", "global", "semi-local64");
    for pct in [0.5, 1.0, 2.0, 3.0, 4.0, 5.0, 8.0, 10.0] {
        let ratio = pct / 100.0;
        let g = coverage(&w, extract, ratio, OutlierScope::Global);
        let s = coverage(&w, extract, ratio, OutlierScope::SemiLocal { qvec: 64 });
        println!("{pct:>8.1} {g:>10.4} {s:>12.4}");
    }
    Ok(())
}

fn runtime_cmd(args: &Args) -> Result<()> {
    let name = args.get_or("artifact", "sdq_gemm");
    let mut rt = sdq::runtime::PjrtRuntime::cpu()?;
    let path = sdq::runtime::artifact_path(&harness::repo_root(), name);
    rt.load_hlo(name, &path)?;
    println!("loaded {} on {}", path.display(), rt.platform());
    Ok(())
}
