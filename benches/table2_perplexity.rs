//! Table 2 — perplexity of the GPT (OPT-family stand-in) model ladder on
//! the held-out corpus under every compression configuration.
//!
//! Regenerates the paper's Table 2 rows (configs × model sizes) with the
//! same grouping by effective compute throughput. Run via
//! `cargo bench --bench table2_perplexity` (artifacts required).

use sdq::harness;
use sdq::sdq::config::CompressionConfig;
use sdq::util::bench::Table;

fn main() {
    if !harness::artifacts_ready() {
        return;
    }
    let models = harness::available_models("gpt-");
    if models.is_empty() {
        eprintln!("no gpt-* models trained");
        return;
    }
    let ds = harness::load_dataset().expect("corpus");
    let full = std::env::var("SDQ_FULL_EVAL").is_ok();

    let mut headers: Vec<&str> = vec!["Configuration", "Tput"];
    headers.extend(models.iter().map(|s| s.as_str()));
    let mut table = Table::new(
        "Table 2: GPT-family perplexity on held-out corpus (lower is better)",
        &headers,
    );

    // Baselines per model for Δ% reporting.
    let mut baselines = vec![f64::NAN; models.len()];
    for cfg_str in harness::table2_configs() {
        let cfg: CompressionConfig = cfg_str.parse().unwrap();
        let mut row =
            vec![cfg_str.to_string(), format!("{:.2}x", cfg.effective_throughput())];
        for (mi, mname) in models.iter().enumerate() {
            let model = harness::load_model(mname).expect("model");
            let ecfg = harness::eval_cfg_for(&model, full);
            let t0 = std::time::Instant::now();
            match harness::eval_config(&model, &ds, &cfg, ecfg) {
                Ok(r) => {
                    if cfg_str == "Dense-WA16" {
                        baselines[mi] = r.ppl.ppl;
                    }
                    let delta = (r.ppl.ppl - baselines[mi]) / baselines[mi] * 100.0;
                    row.push(format!("{:.3} ({:+.1}%)", r.ppl.ppl, delta));
                    eprintln!(
                        "  {mname} {cfg_str}: ppl {:.3} [{:.1}s]",
                        r.ppl.ppl,
                        t0.elapsed().as_secs_f64()
                    );
                }
                Err(e) => row.push(format!("err: {e}")),
            }
        }
        table.row(row);
    }
    table.print();
    table.save_json("table2_perplexity");
    println!("\n(JSON saved under target/bench-results/table2_perplexity.json)");
}
