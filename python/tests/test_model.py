"""L2 JAX model tests: shapes, causality, loss, SDQ forward parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    FAMILY,
    ModelConfig,
    compress_params_sdq,
    forward,
    forward_sdq,
    init_params,
    loss_fn,
)

TINY_GPT = ModelConfig("t-gpt", "gpt", 32, 2, 4, 64, max_seq=32)
TINY_LLAMA = ModelConfig("t-llama", "llama", 32, 2, 4, 64, max_seq=32)


@pytest.mark.parametrize("cfg", [TINY_GPT, TINY_LLAMA], ids=["gpt", "llama"])
def test_forward_shapes_and_finite(cfg):
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) % 256
    logits = forward(cfg, params, tokens)
    assert logits.shape == (2, 16, 256)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("cfg", [TINY_GPT, TINY_LLAMA], ids=["gpt", "llama"])
def test_causality(cfg):
    params = init_params(cfg, jax.random.PRNGKey(1))
    t1 = jnp.arange(16, dtype=jnp.int32)[None, :] % 256
    t2 = t1.at[0, 15].set(99)
    l1 = forward(cfg, params, t1)
    l2 = forward(cfg, params, t2)
    np.testing.assert_allclose(l1[0, :15], l2[0, :15], atol=1e-5)
    assert float(jnp.max(jnp.abs(l1[0, 15] - l2[0, 15]))) > 1e-6


def test_loss_decreases_with_one_step():
    cfg = TINY_GPT
    params = init_params(cfg, jax.random.PRNGKey(2))
    tokens = (jnp.arange(8 * 17, dtype=jnp.int32).reshape(8, 17) * 7) % 256
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    l0, g = jax.value_and_grad(lambda p: loss_fn(cfg, p, inp, tgt))(params)
    params2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    l1 = loss_fn(cfg, params2, inp, tgt)
    assert float(l1) < float(l0)


def test_initial_loss_near_uniform():
    cfg = TINY_GPT
    params = init_params(cfg, jax.random.PRNGKey(3))
    tokens = (jnp.arange(4 * 17, dtype=jnp.int32).reshape(4, 17) * 13) % 256
    l = float(loss_fn(cfg, params, tokens[:, :-1], tokens[:, 1:]))
    assert abs(l - np.log(256)) < 0.5


@pytest.mark.parametrize("cfg", [TINY_GPT, TINY_LLAMA], ids=["gpt", "llama"])
def test_forward_sdq_close_to_fp32(cfg):
    """SDQ-kernel forward ≈ fp32 forward (quantization noise only)."""
    params = init_params(cfg, jax.random.PRNGKey(4))
    tokens = (jnp.arange(2 * 16, dtype=jnp.int32).reshape(2, 16) * 3) % 256
    full = forward(cfg, params, tokens)
    sdq_params = compress_params_sdq(cfg, params)
    sdq = forward_sdq(cfg, sdq_params, tokens)
    # logits differ by quantization noise; correlation must stay high
    a = np.asarray(full).ravel()
    b = np.asarray(sdq).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    # Random-init models have near-uniform logits, so quantization noise
    # looms large; trained models are pinned much tighter by the Rust
    # probe integration test.
    assert corr > 0.9, f"corr {corr}"
    assert bool(jnp.all(jnp.isfinite(sdq)))


def test_family_registry_dims_compressible():
    """Every family member must have linear dims divisible by M=8 and
    qvec=16 (compression layout requirement)."""
    for name, cfg in FAMILY.items():
        assert cfg.d_model % 16 == 0, name
        assert cfg.d_ff % 16 == 0, name
        assert cfg.d_model % cfg.n_head == 0, name
        assert (cfg.d_model // cfg.n_head) % 2 == 0, f"{name}: odd head dim breaks RoPE"
