//! Serving metrics: counters and latency histograms.

use std::time::Duration;

/// Number of gateway priority classes
/// ([`crate::gateway::Priority`]): interactive, standard, batch. The
/// per-class fairness counters below are fixed-size arrays indexed by
/// `Priority as usize`.
pub const PRIORITY_CLASSES: usize = 3;

/// Fixed-bucket latency histogram (log-spaced, µs to minutes).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Bucket upper bounds in µs.
    bounds: Vec<u64>,
    counts: Vec<u64>,
    sum_us: u64,
    n: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        // 1µs … ~134s in ×2 steps
        let bounds: Vec<u64> = (0..28).map(|i| 1u64 << i).collect();
        let len = bounds.len();
        Histogram { bounds, counts: vec![0; len + 1], sum_us: 0, n: 0 }
    }
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self.bounds.partition_point(|b| *b < us);
        self.counts[idx] += 1;
        self.sum_us += us;
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> Duration {
        if self.n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.n)
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.n == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                let us = if i < self.bounds.len() { self.bounds[i] } else { u64::MAX / 2 };
                return Duration::from_micros(us);
            }
        }
        Duration::from_micros(*self.bounds.last().unwrap())
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub decode_rounds: u64,
    /// Decode GEMM invocations (fused batches; in per-sequence fallback
    /// mode every sequence counts as its own width-1 batch).
    pub decode_batches: u64,
    /// Σ sequences over decode batches.
    /// `decode_batched_tokens / decode_batches` is the mean number of
    /// sequences each weight stream was amortized over. (A fused
    /// speculative verify stages `k+1` activation rows per sequence, so
    /// its GEMM row count exceeds this sequence count.)
    pub decode_batched_tokens: u64,
    /// Widest decode batch seen.
    pub decode_width_max: u64,
    /// Tokens actually **emitted** by decode rounds. Equals
    /// `decode_batched_tokens` in plain decode (one token per sequence
    /// per batch); speculative rounds emit more than one token per
    /// sequence, so this is the numerator decode throughput and
    /// tokens-per-round use. [`Self::record_decode_batch`] adds the
    /// batch width; the scheduler adds accepted speculative tokens on
    /// top.
    pub tokens_decoded: u64,
    /// Draft tokens proposed to the speculative verify pass.
    pub spec_drafted: u64,
    /// Draft tokens accepted (greedy-exact prefix matches).
    pub spec_accepted: u64,
    /// Drafter tag (`"off"` when speculation is disabled; empty until a
    /// scheduler stamps it).
    pub spec_drafter: String,
    /// Fused prefill invocations (a batch of N admitted prompts through
    /// one ragged forward counts once; the per-prompt baseline counts
    /// each prompt as its own width-1 batch).
    pub prefill_batches: u64,
    /// Σ prompts over prefill batches (mean width =
    /// `prefill_batched_seqs / prefill_batches`).
    pub prefill_batched_seqs: u64,
    /// Widest prefill batch seen.
    pub prefill_width_max: u64,
    /// Peak KV residency in **actual compressed bytes** (paged: pool
    /// blocks referenced + cached at the pool's storage dtype; legacy:
    /// chunked caches' actual allocated fp32 bytes).
    pub kv_bytes_peak: usize,
    /// Storage dtype tag of the paged pool (`"f32"`, `"fp8-e4m3"`,
    /// `"int8"`, `"int4"`); empty until a scheduler stamps it.
    pub kv_dtype: String,
    /// The pool's admission budget in blocks at its compressed block
    /// size — the capacity the byte budget actually buys (int8 ≈ 4×
    /// the f32 count at the same `kv_budget_bytes`).
    pub pool_budget_blocks: usize,
    /// Compressed bytes of one pool block (payload + scale metadata).
    pub pool_block_bytes: usize,
    /// Peak pool residency as a fraction of the block budget.
    pub pool_utilization_peak: f64,
    /// Prompt tokens served straight from cached prefix blocks.
    pub prefix_shared_tokens: u64,
    /// Total prompt tokens that went through prefix matching.
    pub prefix_prompt_tokens: u64,
    /// Cached KV blocks evicted (LRU) to make room or trim to budget.
    pub kv_evictions: u64,
    /// Copy-on-write block copies (forked tables diverging).
    pub kv_cow_copies: u64,
    /// Duplicate blocks merged at freeze time (identical concurrent
    /// streams).
    pub kv_dedup_merges: u64,
    /// Active sequences swapped out under KV pressure (preemptive
    /// scheduling; each suspension snapshots the sequence's tail/bytes
    /// and releases its blocks back to the pool).
    pub preemptions: u64,
    /// Swapped sequences re-admitted to the active set.
    pub resumes: u64,
    /// Cumulative compressed bytes carried out of the pool by
    /// preemption snapshots (the swap-out traffic a host-memory tier
    /// would absorb).
    pub swap_bytes: u64,
    /// Tokens recomputed by the resume re-prefill fallback (an f32
    /// sequence whose cached middle blocks were LRU-evicted while it
    /// was swapped; quantized pools never re-prefill).
    pub resume_reprefill_tokens: u64,
    /// Preemption snapshots the victim cost model ([`crate::swap`])
    /// sent to the disk tier instead of keeping resident.
    pub spills: u64,
    /// Wire-format bytes written to the swap dir by those spills
    /// (after the optional RLE codec).
    pub spilled_bytes: u64,
    /// Spilled sequences read back from the swap dir at resume.
    pub restores: u64,
    /// Wire-format bytes read back by those restores.
    pub restored_bytes: u64,
    /// Wall time spent reading + decoding spilled sequences.
    pub restore_time: Duration,
    /// Preemption snapshots dropped outright for bit-exact replay
    /// (f32 pools only — the cheapest tier for short sequences).
    pub reprefill_drops: u64,
    /// Raw quantized code-slab bytes that went through the spill
    /// codec (denominator of [`Self::spill_codec_ratio`]).
    pub codec_raw_bytes: u64,
    /// Those same slabs as framed on the wire (RLE where it won, raw
    /// where it did not) — numerator of [`Self::spill_codec_ratio`].
    pub codec_encoded_bytes: u64,
    /// Sequences migrated out of this engine mid-flight (suspended
    /// here, resumed on another engine).
    pub migrations_out: u64,
    /// Sequences migrated into this engine mid-flight.
    pub migrations_in: u64,
    /// f32 bytes a quantized pool staged through the [`KvScratch`]
    /// dequant route ([`BlockPool::layer_views`]) — write-then-reread
    /// traffic the quantized-domain attention path exists to avoid.
    /// Always 0 for f32 pools (reads are zero-copy borrows).
    ///
    /// [`KvScratch`]: crate::kv::KvScratch
    /// [`BlockPool::layer_views`]: crate::kv::BlockPool::layer_views
    pub kv_dequant_bytes: u64,
    /// f32 bytes the quantized-domain route
    /// ([`BlockPool::layer_code_views`] + [`crate::kv::qattn`]) *would
    /// have* staged had it gone through scratch — the dequant traffic
    /// actually avoided by decoding codes in register.
    ///
    /// [`BlockPool::layer_code_views`]: crate::kv::BlockPool::layer_code_views
    pub kv_dequant_bytes_avoided: u64,
    /// Resident int4 outlier side-table entries (rows kept as exact
    /// f32 beside the nibble planes), summed over K and V across all
    /// live + cached pool blocks. Always 0 for other dtypes. These
    /// bytes sit outside the uniform `pool_block_bytes` charge, so the
    /// counter is the observability hook for the sparse plane's true
    /// footprint (`rows · d_model · 4` bytes).
    pub kv_outlier_rows: u64,
    /// Weight bytes the serving forwards actually streamed: packed
    /// codes + scales + sparse gather metadata for compressed planes,
    /// f32 for plain ones ([`Linear::weight_stream_bytes`] summed over
    /// layers × forward calls — deterministic analytic accounting, no
    /// hot-loop counters).
    ///
    /// [`Linear::weight_stream_bytes`]: crate::model::Linear::weight_stream_bytes
    pub weight_bytes_streamed: u64,
    /// Weight bytes those same forwards would have streamed serving
    /// every plane as dense f32, minus what they streamed — the traffic
    /// the packed quantized weight plane (`sdq::qmat`) and packed SpMM
    /// forms avoided.
    pub weight_bytes_avoided: u64,
    /// Requests accepted into the gateway admission queue (excludes
    /// rejections; includes requests later cancelled).
    pub requests_submitted: u64,
    /// Requests refused at the gateway door because the admission queue
    /// was at capacity (backpressure).
    pub requests_rejected: u64,
    /// Requests cancelled mid-flight (explicit cancel or client
    /// disconnect) at any stage: gateway queue, batcher queue, active,
    /// or swapped. Cancelled requests never produce a `Response`.
    pub requests_cancelled: u64,
    /// Tokens that had already been generated for requests that were
    /// then cancelled — work thrown away at the client's request.
    pub tokens_cancelled: u64,
    /// Pool blocks released by cancelling *active* sequences (frozen
    /// prefix blocks stay cached and shareable; a swapped sequence's
    /// blocks went back at suspend time, so it frees none here).
    pub cancel_freed_blocks: u64,
    /// Peak gateway admission-queue depth (requests accepted but not
    /// yet admitted into the scheduler).
    pub queue_depth_peak: u64,
    /// Client-observed time-to-first-token: gateway submit → first
    /// streamed token. Unlike [`Self::ttft`] (scheduler enqueue →
    /// first token) this includes gateway queue wait, so it is the
    /// number an SLO would be written against.
    pub stream_ttft: Histogram,
    /// Client-observed gap between consecutive streamed tokens. Tokens
    /// that land in the same scheduling round (e.g. an accepted
    /// speculative burst) arrive together and record ~0 gaps — that is
    /// the latency the client actually sees, not an artifact.
    pub inter_token: Histogram,
    /// Per-priority-class fairness counters, indexed by
    /// `gateway::Priority as usize` (0 = interactive, 1 = standard,
    /// 2 = batch).
    pub class_submitted: [u64; PRIORITY_CLASSES],
    /// Requests per class admitted out of the gateway queue into the
    /// scheduler (denominator for the mean queue wait).
    pub class_admitted: [u64; PRIORITY_CLASSES],
    pub class_completed: [u64; PRIORITY_CLASSES],
    pub class_cancelled: [u64; PRIORITY_CLASSES],
    /// Tokens streamed per class (includes partial output of cancelled
    /// requests — bytes the client actually received).
    pub class_tokens: [u64; PRIORITY_CLASSES],
    /// Σ gateway-queue wait (submit → scheduler admission) per class.
    pub class_queue_wait: [Duration; PRIORITY_CLASSES],
    pub ttft: Histogram,
    pub total_latency: Histogram,
    /// Wall time the engine spent serving (for throughput).
    pub serve_time: Duration,
    /// Wall time spent inside decode batches (for decode throughput).
    pub decode_time: Duration,
}

impl Metrics {
    /// End-to-end generation throughput.
    pub fn tokens_per_second(&self) -> f64 {
        if self.serve_time.is_zero() {
            return f64::NAN;
        }
        self.tokens_generated as f64 / self.serve_time.as_secs_f64()
    }

    /// Decode-phase throughput (tokens **emitted** per second of decode
    /// wall time; excludes prefill). Speculative rounds emit more than
    /// one token per sequence, which is exactly what this should
    /// measure.
    pub fn decode_tokens_per_second(&self) -> f64 {
        if self.decode_time.is_zero() {
            return f64::NAN;
        }
        self.tokens_decoded as f64 / self.decode_time.as_secs_f64()
    }

    /// Record one decode GEMM batch of `width` sequences (each emitting
    /// one token; speculative extras are added via
    /// [`Self::record_spec`]).
    pub fn record_decode_batch(&mut self, width: usize) {
        self.decode_batches += 1;
        self.decode_batched_tokens += width as u64;
        self.tokens_decoded += width as u64;
        self.decode_width_max = self.decode_width_max.max(width as u64);
    }

    /// Record one sequence's speculative verify outcome: `drafted`
    /// proposed tokens of which `accepted` matched greedy-exactly.
    /// `extra_emitted` is how many emitted tokens no decode batch has
    /// counted yet: the fused verifier's accepted tokens ride a single
    /// width-counted batch (pass `accepted`); the stepwise verifier
    /// feeds every kept token through its own width-counted sub-batch
    /// (pass `0`).
    pub fn record_spec(&mut self, drafted: usize, accepted: usize, extra_emitted: usize) {
        debug_assert!(accepted <= drafted && extra_emitted <= accepted);
        self.spec_drafted += drafted as u64;
        self.spec_accepted += accepted as u64;
        self.tokens_decoded += extra_emitted as u64;
    }

    /// Fraction of drafted tokens the verify pass accepted. `0.0` when
    /// nothing was drafted yet (speculation off or all abstained) —
    /// deliberately not NaN, for the same JSON-validity reason as
    /// [`Self::prefix_hit_rate`].
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_drafted as f64
    }

    /// Mean tokens emitted per decode round across the whole batch
    /// (> batch width once speculation accepts drafts; `0.0` before any
    /// round ran — never NaN).
    pub fn tokens_per_round(&self) -> f64 {
        if self.decode_rounds == 0 {
            return 0.0;
        }
        self.tokens_decoded as f64 / self.decode_rounds as f64
    }

    /// Preemptions per decode round — how often KV pressure actually
    /// forced a swap-out. `0.0` before any round ran (never NaN: this
    /// rides `BENCH_serving.json` as a number, same contract as
    /// [`Self::prefix_hit_rate`]).
    pub fn preemption_rate(&self) -> f64 {
        if self.decode_rounds == 0 {
            return 0.0;
        }
        self.preemptions as f64 / self.decode_rounds as f64
    }

    /// Mean tokens the re-prefill fallback recomputed per resume — the
    /// cost of LRU eviction hitting swapped sequences (0 when every
    /// resume re-attached or re-installed). `0.0` before any resume —
    /// never NaN, same JSON-validity contract as the other rates.
    pub fn resume_reprefill_rate(&self) -> f64 {
        if self.resumes == 0 {
            return 0.0;
        }
        self.resume_reprefill_tokens as f64 / self.resumes as f64
    }

    /// Fraction of preemptions whose snapshot went to the disk tier.
    /// `0.0` before any preemption — never NaN, same
    /// `BENCH_serving.json` contract as [`Self::prefix_hit_rate`].
    pub fn spill_rate(&self) -> f64 {
        if self.preemptions == 0 {
            return 0.0;
        }
        self.spills as f64 / self.preemptions as f64
    }

    /// Spill codec compression ratio: framed bytes over raw bytes for
    /// every code slab that went through the wire codec (`1.0` ≈
    /// incompressible, lower is better). `0.0` before any spill —
    /// deliberately not `1.0` or NaN: the cold value must be exactly
    /// 0.0 for the JSON-emitted-rate contract.
    pub fn spill_codec_ratio(&self) -> f64 {
        if self.codec_raw_bytes == 0 {
            return 0.0;
        }
        self.codec_encoded_bytes as f64 / self.codec_raw_bytes as f64
    }

    /// Mean wall time of one disk restore, in milliseconds. `0.0`
    /// before any restore — never NaN.
    pub fn restore_mean_ms(&self) -> f64 {
        if self.restores == 0 {
            return 0.0;
        }
        self.restore_time.as_secs_f64() * 1e3 / self.restores as f64
    }

    /// Fraction of would-be KV dequant traffic served in the quantized
    /// domain instead: `avoided / (staged + avoided)`. `1.0` when every
    /// quantized read went through [`crate::kv::qattn`]; `0.0` both for
    /// f32 pools (nothing to avoid) and before any read — deliberately
    /// not NaN, same `BENCH_serving.json` contract as
    /// [`Self::prefix_hit_rate`].
    pub fn kv_dequant_avoided_rate(&self) -> f64 {
        let total = self.kv_dequant_bytes + self.kv_dequant_bytes_avoided;
        if total == 0 {
            return 0.0;
        }
        self.kv_dequant_bytes_avoided as f64 / total as f64
    }

    /// Fraction of would-be dense f32 weight traffic the packed planes
    /// avoided: `avoided / (streamed + avoided)`. ≈0.73 for an
    /// all-int8-plane model (~3.76× fewer bytes), `0.0` both for
    /// uncompressed models (nothing avoided) and before any forward —
    /// deliberately not NaN, same `BENCH_serving.json` contract as
    /// [`Self::prefix_hit_rate`].
    pub fn weight_stream_avoided_rate(&self) -> f64 {
        let total = self.weight_bytes_streamed + self.weight_bytes_avoided;
        if total == 0 {
            return 0.0;
        }
        self.weight_bytes_avoided as f64 / total as f64
    }

    /// Fraction of accepted requests that were cancelled mid-flight.
    /// `0.0` before any request was submitted — deliberately not NaN,
    /// same JSON-validity contract as [`Self::prefix_hit_rate`] (this
    /// rides the gateway `/metrics` snapshot and `BENCH_latency.json`).
    pub fn cancellation_rate(&self) -> f64 {
        if self.requests_submitted == 0 {
            return 0.0;
        }
        self.requests_cancelled as f64 / self.requests_submitted as f64
    }

    /// Fraction of arriving requests turned away by backpressure:
    /// `rejected / (submitted + rejected)`. `0.0` cold — never NaN.
    pub fn rejection_rate(&self) -> f64 {
        let arrived = self.requests_submitted + self.requests_rejected;
        if arrived == 0 {
            return 0.0;
        }
        self.requests_rejected as f64 / arrived as f64
    }

    /// Mean gateway-queue wait for priority class `c`, in milliseconds.
    /// `0.0` while the class has no admissions — never NaN (emitted as
    /// a JSON number in the gateway `/metrics` snapshot).
    pub fn class_mean_queue_wait_ms(&self, c: usize) -> f64 {
        if self.class_admitted[c] == 0 {
            return 0.0;
        }
        self.class_queue_wait[c].as_secs_f64() * 1e3 / self.class_admitted[c] as f64
    }

    /// Record one forward pass's weight traffic (precomputed per-model
    /// constants from [`Model::weight_stream_bytes`]).
    ///
    /// [`Model::weight_stream_bytes`]: crate::model::Model::weight_stream_bytes
    pub fn record_weight_stream(&mut self, streamed: u64, avoided: u64) {
        self.weight_bytes_streamed += streamed;
        self.weight_bytes_avoided += avoided;
    }

    /// Mean decode GEMM row width (weight-stream amortization factor).
    pub fn mean_decode_width(&self) -> f64 {
        if self.decode_batches == 0 {
            return f64::NAN;
        }
        self.decode_batched_tokens as f64 / self.decode_batches as f64
    }

    /// Record one fused prefill batch of `width` prompts.
    pub fn record_prefill_batch(&mut self, width: usize) {
        self.prefill_batches += 1;
        self.prefill_batched_seqs += width as u64;
        self.prefill_width_max = self.prefill_width_max.max(width as u64);
    }

    /// Mean prompts per prefill forward (admission-burst amortization).
    pub fn mean_prefill_width(&self) -> f64 {
        if self.prefill_batches == 0 {
            return f64::NAN;
        }
        self.prefill_batched_seqs as f64 / self.prefill_batches as f64
    }

    /// Fraction of prompt tokens served from cached prefix blocks.
    /// `0.0` before any prompt was seen — deliberately not NaN, because
    /// this rate is emitted into `BENCH_serving.json` and NaN is not
    /// representable in JSON.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_prompt_tokens == 0 {
            return 0.0;
        }
        self.prefix_shared_tokens as f64 / self.prefix_prompt_tokens as f64
    }

    /// Fold the pool's cumulative counters and current utilization into
    /// the serving metrics (called once per scheduling round).
    pub fn sync_pool(&mut self, stats: &crate::kv::PoolStats, utilization: f64) {
        self.prefix_shared_tokens = stats.shared_tokens;
        self.prefix_prompt_tokens = stats.prompt_tokens;
        self.kv_evictions = stats.evictions;
        self.kv_cow_copies = stats.cow_copies;
        self.kv_dedup_merges = stats.dedup_merges;
        if utilization.is_finite() {
            self.pool_utilization_peak = self.pool_utilization_peak.max(utilization);
        }
    }

    /// Decode-batch occupancy: mean batch width as a fraction of the
    /// policy's `max_active` slots.
    pub fn decode_occupancy(&self, max_active: usize) -> f64 {
        if max_active == 0 {
            return f64::NAN;
        }
        self.mean_decode_width() / max_active as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} tokens={} tput={:.1} tok/s decode={:.1} tok/s \
             width_mean={:.2} width_max={} prefill_width_mean={:.2} \
             kv_peak={:.1}KiB pool_util_peak={:.2} prefix_hit={:.2} \
             dequant={:.1}KiB dequant_avoided={:.1}KiB outlier_rows={} \
             w_streamed={:.1}KiB w_avoided={:.1}KiB \
             evictions={} preempt={} resumes={} swap={:.1}KiB reprefill={} \
             spills={} spilled={:.1}KiB restores={} drops={} codec={:.2} \
             migr_out={} migr_in={} \
             spec={} accept={:.2} tok/round={:.2} \
             submitted={} cancelled={} rejected={} q_peak={} \
             ttft_mean={:.1}ms ttft_p99={:.1}ms total_mean={:.1}ms",
            self.requests_completed,
            self.tokens_generated,
            self.tokens_per_second(),
            self.decode_tokens_per_second(),
            self.mean_decode_width(),
            self.decode_width_max,
            self.mean_prefill_width(),
            self.kv_bytes_peak as f64 / 1024.0,
            self.pool_utilization_peak,
            self.prefix_hit_rate(),
            self.kv_dequant_bytes as f64 / 1024.0,
            self.kv_dequant_bytes_avoided as f64 / 1024.0,
            self.kv_outlier_rows,
            self.weight_bytes_streamed as f64 / 1024.0,
            self.weight_bytes_avoided as f64 / 1024.0,
            self.kv_evictions,
            self.preemptions,
            self.resumes,
            self.swap_bytes as f64 / 1024.0,
            self.resume_reprefill_tokens,
            self.spills,
            self.spilled_bytes as f64 / 1024.0,
            self.restores,
            self.reprefill_drops,
            self.spill_codec_ratio(),
            self.migrations_out,
            self.migrations_in,
            if self.spec_drafter.is_empty() { "off" } else { self.spec_drafter.as_str() },
            self.spec_acceptance_rate(),
            self.tokens_per_round(),
            self.requests_submitted,
            self.requests_cancelled,
            self.requests_rejected,
            self.queue_depth_peak,
            self.ttft.mean().as_secs_f64() * 1e3,
            self.ttft.quantile(0.99).as_secs_f64() * 1e3,
            self.total_latency.mean().as_secs_f64() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let mut h = Histogram::default();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(10));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
    }

    #[test]
    fn throughput_math() {
        let mut m = Metrics::default();
        m.tokens_generated = 100;
        m.serve_time = Duration::from_secs(2);
        assert!((m.tokens_per_second() - 50.0).abs() < 1e-9);
        assert!(m.summary().contains("tokens=100"));
    }

    #[test]
    fn prefill_and_pool_stats() {
        let mut m = Metrics::default();
        assert!(m.mean_prefill_width().is_nan());
        assert_eq!(m.prefix_hit_rate(), 0.0, "cold hit rate is 0.0, never NaN");
        m.record_prefill_batch(4);
        m.record_prefill_batch(2);
        assert_eq!(m.prefill_batches, 2);
        assert_eq!(m.prefill_width_max, 4);
        assert!((m.mean_prefill_width() - 3.0).abs() < 1e-9);
        let stats = crate::kv::PoolStats {
            shared_tokens: 16,
            prompt_tokens: 64,
            evictions: 3,
            cow_copies: 1,
            dedup_merges: 2,
        };
        m.sync_pool(&stats, 0.5);
        m.sync_pool(&stats, 0.25);
        assert!((m.prefix_hit_rate() - 0.25).abs() < 1e-9);
        assert_eq!(m.kv_evictions, 3);
        assert_eq!(m.kv_cow_copies, 1);
        assert_eq!(m.kv_dedup_merges, 2);
        assert!((m.pool_utilization_peak - 0.5).abs() < 1e-9, "peak must not regress");
        assert!(m.summary().contains("prefix_hit=0.25"));
    }

    #[test]
    fn decode_width_stats() {
        let mut m = Metrics::default();
        assert!(m.mean_decode_width().is_nan());
        m.record_decode_batch(4);
        m.record_decode_batch(8);
        m.record_decode_batch(6);
        assert_eq!(m.decode_batches, 3);
        assert_eq!(m.decode_batched_tokens, 18);
        assert_eq!(m.decode_width_max, 8);
        assert!((m.mean_decode_width() - 6.0).abs() < 1e-9);
        assert!((m.decode_occupancy(8) - 0.75).abs() < 1e-9);
        m.decode_time = Duration::from_secs(2);
        assert!((m.decode_tokens_per_second() - 9.0).abs() < 1e-9);
    }

    #[test]
    fn cold_metrics_emit_parseable_json() {
        // Regression: prefix_hit_rate used to be NaN before any prompt
        // was seen, and `NaN` is not valid JSON — a fresh engine's
        // metrics record must round-trip through the JSON writer/parser.
        // The spec rates are the same class of bug: they must be 0.0
        // (not NaN) while speculation is off or has never drafted.
        use crate::util::json::Json;
        let m = Metrics::default();
        let j = Json::obj(vec![
            ("prefix_hit_rate", Json::Num(m.prefix_hit_rate())),
            ("spec_acceptance_rate", Json::Num(m.spec_acceptance_rate())),
            ("tokens_per_round", Json::Num(m.tokens_per_round())),
            ("tokens_generated", Json::from(m.tokens_generated as usize)),
        ]);
        let text = j.to_string();
        let parsed = Json::parse(&text).expect("cold metrics JSON must parse");
        assert_eq!(parsed.get("prefix_hit_rate").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(parsed.get("spec_acceptance_rate").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(parsed.get("tokens_per_round").and_then(|v| v.as_f64()), Some(0.0));
    }

    /// Every rate helper whose value is emitted into JSON as a
    /// **number**, evaluated on `m`. New rate fields belong in this
    /// table — the cold-NaN bug has now been fixed three times
    /// (`prefix_hit_rate` in PR 3, the spec rates in PR 4, and guarded
    /// for the preemption rates in PR 5), and this single list is what
    /// keeps a fourth from shipping.
    fn json_rate_table(m: &Metrics) -> Vec<(&'static str, f64)> {
        vec![
            ("prefix_hit_rate", m.prefix_hit_rate()),
            ("spec_acceptance_rate", m.spec_acceptance_rate()),
            ("tokens_per_round", m.tokens_per_round()),
            ("preemption_rate", m.preemption_rate()),
            ("resume_reprefill_rate", m.resume_reprefill_rate()),
            ("spill_rate", m.spill_rate()),
            ("spill_codec_ratio", m.spill_codec_ratio()),
            ("restore_mean_ms", m.restore_mean_ms()),
            ("pool_utilization_peak", m.pool_utilization_peak),
            ("kv_dequant_avoided_rate", m.kv_dequant_avoided_rate()),
            ("weight_stream_avoided_rate", m.weight_stream_avoided_rate()),
            ("cancellation_rate", m.cancellation_rate()),
            ("rejection_rate", m.rejection_rate()),
            ("queue_wait_ms_interactive", m.class_mean_queue_wait_ms(0)),
            ("queue_wait_ms_standard", m.class_mean_queue_wait_ms(1)),
            ("queue_wait_ms_batch", m.class_mean_queue_wait_ms(2)),
        ]
    }

    #[test]
    fn cold_rates_are_finite_and_json_roundtrip() {
        // Regression (table-driven): a freshly-constructed Metrics must
        // yield a finite value — 0.0, not NaN — from every JSON-emitted
        // rate helper, and the whole record must survive a JSON
        // write/parse roundtrip (NaN is not representable in JSON).
        use crate::util::json::Json;
        let m = Metrics::default();
        let rates = json_rate_table(&m);
        for (name, v) in &rates {
            assert!(v.is_finite(), "{name}: cold value {v} is not finite");
            assert_eq!(*v, 0.0, "{name}: cold value must be exactly 0.0");
        }
        let j = Json::obj(rates.iter().map(|(n, v)| (*n, Json::Num(*v))).collect());
        let text = j.to_string();
        let parsed = Json::parse(&text).expect("cold metrics JSON must parse");
        for (name, _) in &rates {
            assert_eq!(
                parsed.get(name).and_then(|v| v.as_f64()),
                Some(0.0),
                "{name}: did not roundtrip through JSON"
            );
        }
    }

    #[test]
    fn dequant_counters_and_rate() {
        let mut m = Metrics::default();
        assert_eq!(m.kv_dequant_avoided_rate(), 0.0, "cold rate is 0.0, never NaN");
        // Quantized-domain rounds only: everything avoided.
        m.kv_dequant_bytes_avoided = 4096;
        assert!((m.kv_dequant_avoided_rate() - 1.0).abs() < 1e-9);
        // A scratch-route fill (e.g. the property test's reference arm)
        // shifts the ratio.
        m.kv_dequant_bytes = 4096;
        assert!((m.kv_dequant_avoided_rate() - 0.5).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("dequant=4.0KiB"), "summary must surface dequant traffic: {s}");
        assert!(s.contains("dequant_avoided=4.0KiB"));
    }

    #[test]
    fn weight_stream_counters_and_rate() {
        let mut m = Metrics::default();
        assert_eq!(m.weight_stream_avoided_rate(), 0.0, "cold rate is 0.0, never NaN");
        // Two forwards of an int8-plane model: ~3.76× fewer bytes each.
        m.record_weight_stream(1088, 3008);
        m.record_weight_stream(1088, 3008);
        assert_eq!(m.weight_bytes_streamed, 2176);
        assert_eq!(m.weight_bytes_avoided, 6016);
        assert!((m.weight_stream_avoided_rate() - 6016.0 / 8192.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("w_streamed=2.1KiB"), "summary must surface weight traffic: {s}");
        assert!(s.contains("w_avoided=5.9KiB"));
    }

    #[test]
    fn preemption_counters_and_rates() {
        let mut m = Metrics::default();
        assert_eq!(m.preemption_rate(), 0.0, "cold rate is 0.0, never NaN");
        assert_eq!(m.resume_reprefill_rate(), 0.0);
        m.decode_rounds = 8;
        m.preemptions = 2;
        m.resumes = 2;
        m.swap_bytes = 4096;
        m.resume_reprefill_tokens = 10;
        assert!((m.preemption_rate() - 0.25).abs() < 1e-9);
        assert!((m.resume_reprefill_rate() - 5.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("preempt=2"), "summary must surface preemptions: {s}");
        assert!(s.contains("resumes=2"));
        assert!(s.contains("swap=4.0KiB"));
        assert!(s.contains("reprefill=10"));
    }

    #[test]
    fn spill_counters_and_rates() {
        let mut m = Metrics::default();
        assert_eq!(m.spill_rate(), 0.0, "cold rate is 0.0, never NaN");
        assert_eq!(m.spill_codec_ratio(), 0.0, "cold ratio is 0.0, not 1.0 or NaN");
        assert_eq!(m.restore_mean_ms(), 0.0);
        m.preemptions = 8;
        m.spills = 2;
        m.spilled_bytes = 3072;
        m.restores = 2;
        m.restored_bytes = 3072;
        m.restore_time = Duration::from_millis(4);
        m.reprefill_drops = 1;
        m.codec_raw_bytes = 4096;
        m.codec_encoded_bytes = 1024;
        m.migrations_out = 1;
        m.migrations_in = 1;
        assert!((m.spill_rate() - 0.25).abs() < 1e-9);
        assert!((m.spill_codec_ratio() - 0.25).abs() < 1e-9);
        assert!((m.restore_mean_ms() - 2.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("spills=2"), "summary must surface the spill tier: {s}");
        assert!(s.contains("spilled=3.0KiB"));
        assert!(s.contains("restores=2"));
        assert!(s.contains("drops=1"));
        assert!(s.contains("codec=0.25"));
        assert!(s.contains("migr_out=1"));
        assert!(s.contains("migr_in=1"));
    }

    #[test]
    fn gateway_counters_and_rates() {
        let mut m = Metrics::default();
        assert_eq!(m.cancellation_rate(), 0.0, "cold rate is 0.0, never NaN");
        assert_eq!(m.rejection_rate(), 0.0);
        assert_eq!(m.class_mean_queue_wait_ms(0), 0.0);
        m.requests_submitted = 8;
        m.requests_cancelled = 2;
        m.requests_rejected = 2;
        m.queue_depth_peak = 5;
        m.class_admitted[1] = 4;
        m.class_queue_wait[1] = Duration::from_millis(20);
        assert!((m.cancellation_rate() - 0.25).abs() < 1e-9);
        assert!((m.rejection_rate() - 0.2).abs() < 1e-9, "2 of 10 arrivals rejected");
        assert!((m.class_mean_queue_wait_ms(1) - 5.0).abs() < 1e-9);
        m.stream_ttft.record(Duration::from_millis(3));
        m.inter_token.record(Duration::from_millis(1));
        assert_eq!(m.stream_ttft.count(), 1);
        assert_eq!(m.inter_token.count(), 1);
        let s = m.summary();
        assert!(s.contains("submitted=8"), "summary must surface gateway traffic: {s}");
        assert!(s.contains("cancelled=2"));
        assert!(s.contains("rejected=2"));
        assert!(s.contains("q_peak=5"));
    }

    #[test]
    fn spec_counters_and_rates() {
        let mut m = Metrics::default();
        assert_eq!(m.spec_acceptance_rate(), 0.0, "cold rate is 0.0, never NaN");
        assert_eq!(m.tokens_per_round(), 0.0);
        // One fused round, width 3: one sequence accepted 2 of 3 drafts,
        // one accepted 0 of 2, one didn't draft.
        m.record_decode_batch(3);
        m.decode_rounds += 1;
        m.record_spec(3, 2, 2);
        m.record_spec(2, 0, 0);
        assert_eq!(m.spec_drafted, 5);
        assert_eq!(m.spec_accepted, 2);
        assert_eq!(m.tokens_decoded, 5, "3 batch tokens + 2 accepted extras");
        assert!((m.spec_acceptance_rate() - 0.4).abs() < 1e-9);
        assert!((m.tokens_per_round() - 5.0).abs() < 1e-9);
        // Stepwise accounting: sub-batches carry the emitted tokens, so
        // record_spec adds none.
        m.record_decode_batch(2);
        m.record_spec(2, 1, 0);
        assert_eq!(m.tokens_decoded, 7);
        m.decode_time = Duration::from_secs(7);
        assert!((m.decode_tokens_per_second() - 1.0).abs() < 1e-9);
        assert!(m.summary().contains("accept=0.43"));
    }
}
