//! Thread-safe serving engine handle.
//!
//! Owns the model on a dedicated worker thread; callers submit requests
//! over a channel and receive responses over another. `run_batch` is the
//! synchronous convenience used by examples and benches.
//!
//! All scheduler state — including sequences swapped out by preemptive
//! scheduling (`BatchPolicy::preempt`) — lives on the worker thread;
//! `has_work` counts the swapped queue, so the engine keeps driving
//! rounds until every suspended sequence has resumed and retired
//! (shutdown cannot strand swapped work).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::batcher::{BatchPolicy, Batcher};
use super::request::{Request, Response};
use super::scheduler::Scheduler;
use crate::model::Model;
use crate::spec::SpecPolicy;

enum Msg {
    Submit(Request),
    Shutdown,
}

/// A running engine: submit requests, receive responses.
pub struct Engine {
    tx: Sender<Msg>,
    rx: Receiver<Response>,
    worker: Option<JoinHandle<super::metrics::Metrics>>,
}

impl Engine {
    /// Start the engine on its own worker thread.
    pub fn start(model: Model, policy: BatchPolicy) -> Self {
        Self::start_with_spec(model, policy, None)
    }

    /// Start the engine with an optional speculative-decode policy (the
    /// drafter moves onto the worker thread with the model). Greedy
    /// output is bit-identical with speculation on or off.
    pub fn start_with_spec(
        model: Model,
        policy: BatchPolicy,
        spec: Option<SpecPolicy>,
    ) -> Self {
        let (tx, req_rx) = channel::<Msg>();
        let (resp_tx, rx) = channel::<Response>();
        let worker = std::thread::spawn(move || {
            let mut sched = Scheduler::with_spec(&model, policy, spec);
            let mut batcher = Batcher::new();
            let mut shutdown = false;
            loop {
                // Drain incoming messages; block only when idle.
                if sched.has_work(&batcher) {
                    while let Ok(msg) = req_rx.try_recv() {
                        match msg {
                            Msg::Submit(r) => batcher.enqueue(r),
                            Msg::Shutdown => shutdown = true,
                        }
                    }
                } else {
                    if shutdown {
                        break;
                    }
                    match req_rx.recv() {
                        Ok(Msg::Submit(r)) => batcher.enqueue(r),
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                }
                for resp in sched.round(&mut batcher) {
                    let _ = resp_tx.send(resp);
                }
            }
            sched.metrics
        });
        Engine { tx, rx, worker: Some(worker) }
    }

    /// Submit a request (non-blocking).
    pub fn submit(&self, req: Request) {
        let _ = self.tx.send(Msg::Submit(req));
    }

    /// Receive the next completed response (blocking).
    pub fn recv(&self) -> Option<Response> {
        self.rx.recv().ok()
    }

    /// Shut down and return final metrics.
    pub fn shutdown(mut self) -> super::metrics::Metrics {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker.take().map(|w| w.join().expect("engine worker")).unwrap_or_default()
    }

    /// Synchronous batch helper: submit all, wait for all, shut down.
    /// Returns responses (request order not guaranteed) plus metrics.
    pub fn run_batch(
        model: Model,
        policy: BatchPolicy,
        requests: Vec<Request>,
    ) -> (Vec<Response>, super::metrics::Metrics) {
        Self::run_batch_spec(model, policy, None, requests)
    }

    /// [`Self::run_batch`] with a speculative-decode policy.
    pub fn run_batch_spec(
        model: Model,
        policy: BatchPolicy,
        spec: Option<SpecPolicy>,
        requests: Vec<Request>,
    ) -> (Vec<Response>, super::metrics::Metrics) {
        let n = requests.len();
        let engine = Engine::start_with_spec(model, policy, spec);
        for r in requests {
            engine.submit(r);
        }
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match engine.recv() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        let metrics = engine.shutdown();
        (out, metrics)
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;
    use crate::model::Arch;

    #[test]
    fn run_batch_completes_all() {
        let model = tiny_model(Arch::Gpt, 1);
        let reqs: Vec<Request> =
            (0..5).map(|i| Request::new(i, vec![(65 + i) as u8; 3], 4)).collect();
        let (resps, metrics) = Engine::run_batch(model, BatchPolicy::default(), reqs);
        assert_eq!(resps.len(), 5);
        assert_eq!(metrics.requests_completed, 5);
        assert!(metrics.tokens_per_second() > 0.0);
        let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn run_batch_spec_matches_plain_and_reports() {
        use crate::spec::SpecPolicy;
        let model = tiny_model(Arch::Gpt, 3);
        let reqs = || -> Vec<Request> {
            (0..4).map(|i| Request::new(i, vec![(65 + i) as u8; 4], 6)).collect()
        };
        let (mut plain, _) = Engine::run_batch(model.clone(), BatchPolicy::default(), reqs());
        let (mut spec, metrics) = Engine::run_batch_spec(
            model,
            BatchPolicy::default(),
            Some(SpecPolicy::ngram(3)),
            reqs(),
        );
        plain.sort_by_key(|r| r.id);
        spec.sort_by_key(|r| r.id);
        let toks = |v: &[Response]| v.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>();
        assert_eq!(toks(&spec), toks(&plain), "spec engine must not change output");
        assert_eq!(metrics.spec_drafter, "ngram");
        assert!(metrics.spec_acceptance_rate() >= 0.0);
        assert!(metrics.tokens_per_round() >= 1.0);
    }

    #[test]
    fn run_batch_preemptive_matches_plain_and_drains() {
        // End-to-end through the threaded engine: an oversubscribed
        // preemptive policy must complete every request with greedy
        // output bit-identical to the unconstrained engine, stranding
        // nothing in the swapped queue at shutdown.
        use crate::model::generate::KvCache;
        let model = tiny_model(Arch::Llama, 9);
        let blk = KvCache::bytes_for_tokens(&model.cfg, 1);
        let reqs = || -> Vec<Request> {
            (0..6).map(|i| Request::new(i, vec![(65 + i) as u8; 4], 22)).collect()
        };
        let (mut plain, _) = Engine::run_batch(model.clone(), BatchPolicy::default(), reqs());
        let tight = BatchPolicy { kv_budget_bytes: 3 * blk, preempt: true, ..Default::default() };
        let (mut got, metrics) = Engine::run_batch(model, tight, reqs());
        plain.sort_by_key(|r| r.id);
        got.sort_by_key(|r| r.id);
        super::super::request::assert_bit_identical("engine preempt", &got, &plain);
        assert_eq!(metrics.requests_completed, 6);
        assert!(metrics.preemptions > 0, "tight pool must preempt");
        assert_eq!(metrics.resumes, metrics.preemptions, "no swapped sequence left behind");
    }

    #[test]
    fn streaming_submit_recv() {
        let model = tiny_model(Arch::Llama, 2);
        let engine = Engine::start(model, BatchPolicy::default());
        engine.submit(Request::new(42, b"hello".to_vec(), 3));
        let r = engine.recv().expect("response");
        assert_eq!(r.id, 42);
        assert_eq!(r.tokens.len(), 3);
        let m = engine.shutdown();
        assert_eq!(m.requests_completed, 1);
    }
}
