"""Format quantizer tests: grids, RNE, and agreement with the spec."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import formats


FP4_GRID = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def test_fp4_grid_fixed_points():
    for g in FP4_GRID:
        assert float(formats.quantize(jnp.float32(g), "fp4")) == g
        assert float(formats.quantize(jnp.float32(-g), "fp4")) == -g


def test_fp4_rounding_and_clamp():
    q = lambda x: float(formats.quantize(jnp.float32(x), "fp4"))
    assert q(2.5) == 2.0  # tie → even mantissa
    assert q(5.0) == 4.0
    assert q(7.0) == 6.0
    assert q(100.0) == 6.0
    assert q(0.2) == 0.0
    assert q(0.3) == 0.5


def test_int_grids():
    q8 = lambda x: float(formats.quantize(jnp.float32(x), "int8"))
    assert q8(127.7) == 127.0
    assert q8(-200.0) == -127.0
    assert q8(2.5) == 2.0  # RNE
    assert q8(3.5) == 4.0


def test_e4m3_max():
    q = lambda x: float(formats.quantize(jnp.float32(x), "fp8-e4m3"))
    assert q(448.0) == 448.0
    assert q(1000.0) == 448.0
    assert q(1.05) == 1.0
    assert q(1.07) == 1.125


@pytest.mark.parametrize("fmt", ["fp4", "fp8-e4m3", "fp8-e5m2", "int4", "int8"])
@given(x=st.floats(-1000, 1000, allow_nan=False, width=32))
@settings(max_examples=200, deadline=None)
def test_idempotent(fmt, x):
    q1 = formats.quantize(jnp.float32(x), fmt)
    q2 = formats.quantize(q1, fmt)
    assert float(q1) == float(q2)


@pytest.mark.parametrize("fmt", ["fp4", "fp8-e4m3", "int4", "int8"])
@given(x=st.floats(-100, 100, allow_nan=False, width=32))
@settings(max_examples=200, deadline=None)
def test_bounded_by_max(fmt, x):
    q = float(formats.quantize(jnp.float32(x), fmt))
    assert abs(q) <= formats.MAX_VALUE[fmt]
    # sign preserved (or zero)
    assert q == 0.0 or np.sign(q) == np.sign(x)


def test_vectorized_matches_scalar():
    xs = np.linspace(-8, 8, 257).astype(np.float32)
    v = np.asarray(formats.quantize(jnp.asarray(xs), "fp4"))
    s = np.array([float(formats.quantize(jnp.float32(x), "fp4")) for x in xs])
    np.testing.assert_array_equal(v, s)
