//! Admission queue + batch-formation policy.
//!
//! Continuous batching with a KV-memory budget: new requests are
//! admitted into the active set whenever (a) an active slot is free and
//! (b) *actual* KV residency plus this request's projected growth stays
//! under the budget. The projection is per request (prompt length plus
//! decode budget, chunk-aligned), not a fixed worst-case constant —
//! caches grow on demand, so short requests no longer reserve
//! `max_seq × d_model` phantom bytes. Waiting requests queue FIFO. The
//! policy mirrors vLLM's admission control at the granularity this
//! engine needs.

use std::collections::VecDeque;

use super::request::{InFlight, Request};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max concurrently-active sequences (decode round width).
    pub max_active: usize,
    /// KV-cache memory budget in bytes across active sequences
    /// (actual residency + projected growth of admitted requests).
    pub kv_budget_bytes: usize,
    /// Max prompts prefilled per scheduling round (prefill burst limit —
    /// keeps decode latency bounded while the queue drains).
    pub max_prefill_per_round: usize,
    /// Decode all active sequences in one fused ragged batch per round
    /// (`Model::decode_step`). `false` falls back to the per-sequence
    /// baseline (one batch-1 `forward_cached` per sequence) — kept as an
    /// A/B lever for `benches/serving.rs`.
    pub batched_decode: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_active: 8,
            kv_budget_bytes: 512 << 20,
            max_prefill_per_round: 4,
            batched_decode: true,
        }
    }
}

/// FIFO admission queue.
#[derive(Debug, Default)]
pub struct Batcher {
    waiting: VecDeque<InFlight>,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn enqueue(&mut self, req: Request) {
        self.waiting.push_back(InFlight::new(req));
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Admit up to the policy limits given the current active set size
    /// and the KV bytes already charged against the budget (each active
    /// sequence's actual residency or reserved projection, whichever is
    /// larger). `kv_cost` projects the eventual KV residency of a
    /// waiting request (prompt + decode budget, chunk-aligned);
    /// admission stops at the first request whose projection would
    /// break the budget (FIFO — no starvation of large requests by
    /// skipping ahead).
    pub fn admit(
        &mut self,
        policy: &BatchPolicy,
        active: usize,
        kv_in_use: usize,
        kv_cost: impl Fn(&Request) -> usize,
    ) -> Vec<InFlight> {
        let mut out = Vec::new();
        let mut kv = kv_in_use;
        while out.len() < policy.max_prefill_per_round && active + out.len() < policy.max_active
        {
            let cost = match self.waiting.front() {
                Some(f) => kv_cost(&f.req),
                None => break,
            };
            if kv + cost > policy.kv_budget_bytes {
                break;
            }
            kv += cost;
            out.push(self.waiting.pop_front().expect("peeked"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1u8; 4], 8)
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let admitted = b.admit(&BatchPolicy::default(), 0, 0, |_| 1);
        let ids: Vec<u64> = admitted.iter().map(|f| f.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]); // max_prefill_per_round = 4
        assert_eq!(b.waiting(), 1);
    }

    #[test]
    fn respects_max_active() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let policy = BatchPolicy { max_active: 3, ..Default::default() };
        let admitted = b.admit(&policy, 2, 0, |_| 1);
        assert_eq!(admitted.len(), 1);
    }

    #[test]
    fn respects_kv_budget() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let policy = BatchPolicy { kv_budget_bytes: 100, ..Default::default() };
        // 60 bytes in use, 30 projected per request → only one more fits.
        let admitted = b.admit(&policy, 0, 60, |_| 30);
        assert_eq!(admitted.len(), 1);
    }

    #[test]
    fn budget_uses_per_request_projection() {
        let mut b = Batcher::new();
        // Alternating decode budgets → alternating projections.
        for i in 0..4 {
            b.enqueue(Request::new(i, vec![1u8; 4], if i % 2 == 0 { 8 } else { 64 }));
        }
        let policy = BatchPolicy { kv_budget_bytes: 100, ..Default::default() };
        // Costs: 20, 70, 20, 70 → FIFO admits 20 + 70 = 90, then stops:
        // the third request's 20 would push residency to 110 > 100.
        let admitted =
            b.admit(&policy, 0, 0, |r| if r.max_new_tokens == 8 { 20 } else { 70 });
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.waiting(), 2);
    }

    #[test]
    fn empty_queue() {
        let mut b = Batcher::new();
        assert!(b.admit(&BatchPolicy::default(), 0, 0, |_| 1).is_empty());
    }
}
