//! Quantized-domain attention equivalence properties (PR 6's tentpole
//! claim, tested end-to-end against the paged pool).
//!
//! The sharp claim: [`paged_attention`] over raw code segments
//! ([`BlockPool::layer_code_views`] → [`KvSegs::Quant`], decoded in
//! register by `kv::qattn`) is **bit-for-bit identical** to the same
//! kernel over scratch-dequantized fp32 segments
//! ([`BlockPool::layer_views`] → [`KvSegs::F32`]) — for int8,
//! fp8-e4m3 AND int4-outlier (dense nibble plane + exact f32
//! side-table rows), with and without RoPE, under a randomized pool
//! mutation history that hits every hazard the quantized store has:
//!
//! * **random block boundaries** — 4-token blocks and ragged extends,
//!   so views constantly cut mid-block;
//! * **amax growth** — write magnitudes climb across rounds, forcing
//!   the open block to requantize already-staged rows;
//! * **COW forks** — [`BlockPool::fork`] then diverging extends, so
//!   code segments are read through shared and privately-copied blocks;
//! * **mid-block truncation** — [`BlockPool::truncate`] to a non-block
//!   boundary then re-extend, so stale quantized tails sit past live
//!   rows inside the same block;
//! * **suspend/resume** — [`BlockPool::suspend`] then immediate
//!   [`BlockPool::resume`], so reads go through snapshot-owned bytes
//!   reinstalled in fresh slots.
//!
//! Riding along: a loose divergence sanity bound for the quantized
//! routes against an fp32-pool reference (the *storage* error — both
//! quantized routes being bit-equal, either stands in for both), and
//! the scratch-reuse property — warm [`BlockPool::layer_views`] rounds
//! of a fixed shape perform zero allocations
//! ([`KvScratch::alloc_events`]).

use sdq::kv::{BlockPool, BlockTable, KvDtype, KvScratch};
use sdq::model::forward::{paged_attention, KvSegs, SeqKv};
use sdq::model::{Arch, ModelConfig};
use sdq::tensor::Matrix;
use sdq::util::rng::Rng;

fn tiny_cfg(dtype: KvDtype) -> ModelConfig {
    ModelConfig {
        name: "qattn-test".into(),
        arch: Arch::Gpt,
        d_model: 16,
        n_layer: 2,
        n_head: 2,
        d_ff: 16,
        vocab: 256,
        max_seq: 256,
        eps: 1e-5,
        rope_theta: 10000.0,
        kv_dtype: dtype,
    }
}

fn rand_matrix(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
    Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.range_f32(-1.0, 1.0)).collect())
}

/// Stage, write and commit `n` fresh rows of magnitude `mag` on `tb`.
fn extend(cfg: &ModelConfig, pool: &mut BlockPool, tb: &mut BlockTable, rng: &mut Rng, n: usize, mag: f32) {
    let (d, base) = (cfg.d_model, tb.len());
    pool.prepare_tokens(tb, n);
    for j in 0..n {
        for li in 0..cfg.n_layer {
            let k: Vec<f32> = (0..d).map(|_| rng.range_f32(-mag, mag)).collect();
            let v: Vec<f32> = (0..d).map(|_| rng.range_f32(-mag, mag)).collect();
            pool.write_row(tb, li, base + j, &k, &v);
        }
    }
    let toks: Vec<u8> = (0..n).map(|_| rng.below(250) as u8).collect();
    pool.commit(tb, &toks);
}

/// Per-sequence `(q_row0, n_new, past)` for a random ragged decode step
/// over the current committed lengths.
fn decode_meta(uptos: &[usize], rng: &mut Rng) -> Vec<(usize, usize, usize)> {
    let mut q_row0 = 0;
    uptos
        .iter()
        .map(|&u| {
            let nn = if u >= 2 && rng.below(2) == 1 { 2 } else { 1 };
            let m = (q_row0, nn, u - nn);
            q_row0 += nn;
            m
        })
        .collect()
}

/// Run both routes over identical pool state and assert bit equality.
fn assert_routes_bit_identical(
    cfg: &ModelConfig,
    pool: &BlockPool,
    tables: &[&BlockTable],
    rng: &mut Rng,
    scratch: &mut KvScratch,
) {
    let (nh, dh) = (cfg.n_head, cfg.d_model / cfg.n_head);
    let bt = pool.block_tokens();
    let uptos: Vec<usize> = tables.iter().map(|t| t.len()).collect();
    let meta = decode_meta(&uptos, rng);
    let q_rows = meta.iter().map(|&(_, nn, _)| nn).sum::<usize>();
    let q = rand_matrix(q_rows, cfg.d_model, rng);
    for li in 0..cfg.n_layer {
        for rope in [None, Some(cfg.rope_theta)] {
            let views = pool.layer_views(tables, li, &uptos, scratch);
            let seqs: Vec<SeqKv> = views
                .into_iter()
                .zip(&meta)
                .map(|((kk, vv), &(q0, nn, past))| SeqKv {
                    q_row0: q0,
                    n_new: nn,
                    past,
                    segs: KvSegs::F32 { k: kk, v: vv },
                    seg_tokens: bt,
                })
                .collect();
            let via_scratch = paged_attention(&q, &seqs, nh, dh, rope);
            drop(seqs);
            let codes = pool.layer_code_views(tables, li, &uptos);
            let seqs: Vec<SeqKv> = codes
                .into_iter()
                .zip(&meta)
                .map(|((kk, vv), &(q0, nn, past))| SeqKv {
                    q_row0: q0,
                    n_new: nn,
                    past,
                    segs: KvSegs::Quant { dtype: pool.dtype(), k: kk, v: vv },
                    seg_tokens: bt,
                })
                .collect();
            let via_qdomain = paged_attention(&q, &seqs, nh, dh, rope);
            for (i, (a, b)) in via_scratch.data.iter().zip(&via_qdomain.data).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "layer {li} rope {rope:?} elem {i}: scratch {a} != qdomain {b} ({})",
                    pool.dtype().tag()
                );
            }
        }
    }
}

#[test]
fn quantized_domain_attention_bit_identical_under_churn() {
    for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3, KvDtype::Int4Outlier] {
        for seed in 0..4u64 {
            let cfg = tiny_cfg(dtype);
            // 4-token blocks: every extend crosses boundaries quickly.
            let mut pool = BlockPool::with_params(&cfg, 1 << 22, 4, dtype);
            let mut rng = Rng::seed_from_u64(100 * seed + 7);
            let mut scratch = KvScratch::new();
            let mut tables: Vec<BlockTable> = Vec::new();
            for _ in 0..2 {
                let mut tb = BlockTable::new(cfg.max_seq);
                let n = 2 + rng.below(7) as usize;
                extend(&cfg, &mut pool, &mut tb, &mut rng, n, 0.3);
                tables.push(tb);
            }
            for round in 0..8 {
                // Climbing magnitude: later writes raise the open
                // block's amax and force requantization of its
                // already-staged rows.
                let mag = 0.3 + 0.6 * round as f32;
                let ti = rng.below(tables.len());
                match rng.below(5) {
                    0 | 1 => {
                        let n = 1 + rng.below(9) as usize;
                        extend(&cfg, &mut pool, &mut tables[ti], &mut rng, n, mag);
                    }
                    2 => {
                        // Truncate to a mid-block length, then write
                        // fresh rows over the stale quantized tail.
                        let len = tables[ti].len();
                        if len >= 3 {
                            let new_len = 1 + rng.below(len - 1);
                            pool.truncate(&mut tables[ti], new_len);
                        }
                        let n = 1 + rng.below(5) as usize;
                        extend(&cfg, &mut pool, &mut tables[ti], &mut rng, n, mag);
                    }
                    3 => {
                        // Fork, then diverge both sides: the shared
                        // open block goes through copy-on-write.
                        if tables.len() < 4 {
                            let mut f = pool.fork(&tables[ti]);
                            let n = 1 + rng.below(5) as usize;
                            extend(&cfg, &mut pool, &mut f, &mut rng, n, mag);
                            tables.push(f);
                        }
                        let n = 1 + rng.below(5) as usize;
                        extend(&cfg, &mut pool, &mut tables[ti], &mut rng, n, mag);
                    }
                    _ => {
                        // Swap out / swap in: quantized snapshots own
                        // the exact codes, scales (and int4 outlier
                        // tables), so the resumed table must keep both
                        // read routes bit-identical with zero
                        // re-prefill.
                        let t = tables.remove(ti);
                        let snap = pool.suspend(t);
                        let (t2, ready) = pool.resume(&snap);
                        assert_eq!(ready, t2.len(), "quantized resume must be exact");
                        tables.insert(ti, t2);
                    }
                }
                let tb_refs: Vec<&BlockTable> = tables.iter().collect();
                assert_routes_bit_identical(&cfg, &pool, &tb_refs, &mut rng, &mut scratch);
            }
        }
    }
}

/// Loose divergence sanity bound against an fp32-pool reference fed the
/// identical rows. The sharp equivalence claim is the bit-identity test
/// above — qdomain ≡ scratch — so this pins only the *storage* error of
/// the quantized pool itself, with deliberately generous bounds (int8:
/// ~1/254 per-element error, softmax-amplified; fp8-e4m3: ~2^-4
/// relative, likewise amplified).
#[test]
fn quantized_routes_track_f32_reference() {
    for (dtype, bound) in
        [(KvDtype::Int8, 0.1f32), (KvDtype::Fp8E4M3, 0.75f32), (KvDtype::Int4Outlier, 1.5f32)]
    {
        let cfgq = tiny_cfg(dtype);
        let cfgf = tiny_cfg(KvDtype::F32);
        let mut pq = BlockPool::with_params(&cfgq, 1 << 22, 4, dtype);
        let mut pf = BlockPool::with_params(&cfgf, 1 << 22, 4, KvDtype::F32);
        let mut tq = BlockTable::new(cfgq.max_seq);
        let mut tf = BlockTable::new(cfgf.max_seq);
        let mut rng = Rng::seed_from_u64(23);
        let (d, tokens) = (cfgq.d_model, 20usize);
        pq.prepare_tokens(&mut tq, tokens);
        pf.prepare_tokens(&mut tf, tokens);
        for pos in 0..tokens {
            for li in 0..cfgq.n_layer {
                let k: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                pq.write_row(&tq, li, pos, &k, &v);
                pf.write_row(&tf, li, pos, &k, &v);
            }
        }
        let toks: Vec<u8> = (0..tokens as u8).collect();
        pq.commit(&mut tq, &toks);
        pf.commit(&mut tf, &toks);
        let (nh, dh) = (cfgq.n_head, cfgq.d_model / cfgq.n_head);
        let bt = pq.block_tokens();
        let uptos = [tokens];
        let q = rand_matrix(1, d, &mut rng);
        let mut scratch = KvScratch::new();
        for rope in [None, Some(cfgq.rope_theta)] {
            let mk_seq = |kk, vv| SeqKv {
                q_row0: 0,
                n_new: 1,
                past: tokens - 1,
                segs: KvSegs::Quant { dtype, k: kk, v: vv },
                seg_tokens: bt,
            };
            let codes = pq.layer_code_views(&[&tq], 0, &uptos);
            let (kk, vv) = codes.into_iter().next().unwrap();
            let out_q = paged_attention(&q, &[mk_seq(kk, vv)], nh, dh, rope);
            let views = pf.layer_views(&[&tf], 0, &uptos, &mut scratch);
            let (kk, vv) = views.into_iter().next().unwrap();
            let seq = SeqKv {
                q_row0: 0,
                n_new: 1,
                past: tokens - 1,
                segs: KvSegs::F32 { k: kk, v: vv },
                seg_tokens: bt,
            };
            let out_f = paged_attention(&q, &[seq], nh, dh, rope);
            let worst = out_q
                .data
                .iter()
                .zip(&out_f.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                worst.is_finite() && worst < bound,
                "{} rope {rope:?}: divergence {worst} exceeds {bound}",
                dtype.tag()
            );
        }
    }
}

/// Scratch-capacity reuse (the scheduler holds one [`KvScratch`] for
/// its whole lifetime): after a cold round sizes the arena, repeated
/// `layer_views` rounds of the same shape must not allocate. Growing a
/// sequence may allocate again (buffers regrow once), after which the
/// new shape is warm too.
#[test]
fn layer_views_warm_rounds_do_not_allocate() {
    let cfg = tiny_cfg(KvDtype::Int8);
    let mut pool = BlockPool::with_params(&cfg, 1 << 22, 4, KvDtype::Int8);
    let mut rng = Rng::seed_from_u64(5);
    let mut tables: Vec<BlockTable> = Vec::new();
    for n in [7usize, 11] {
        let mut tb = BlockTable::new(cfg.max_seq);
        extend(&cfg, &mut pool, &mut tb, &mut rng, n, 1.0);
        tables.push(tb);
    }
    let tb_refs: Vec<&BlockTable> = tables.iter().collect();
    let uptos: Vec<usize> = tb_refs.iter().map(|t| t.len()).collect();
    let mut scratch = KvScratch::new();
    for li in 0..cfg.n_layer {
        let _ = pool.layer_views(&tb_refs, li, &uptos, &mut scratch);
    }
    let warm = scratch.alloc_events();
    assert!(warm > 0, "cold round must have sized the arena");
    for _ in 0..10 {
        for li in 0..cfg.n_layer {
            let _ = pool.layer_views(&tb_refs, li, &uptos, &mut scratch);
        }
    }
    assert_eq!(scratch.alloc_events(), warm, "warm rounds must not allocate");
    // Grow one sequence: the next round may regrow buffers (bounded),
    // and the new shape is immediately warm after that.
    drop(tb_refs);
    extend(&cfg, &mut pool, &mut tables[0], &mut rng, 16, 1.0);
    let tb_refs: Vec<&BlockTable> = tables.iter().collect();
    let uptos: Vec<usize> = tb_refs.iter().map(|t| t.len()).collect();
    for li in 0..cfg.n_layer {
        let _ = pool.layer_views(&tb_refs, li, &uptos, &mut scratch);
    }
    let regrown = scratch.alloc_events();
    for _ in 0..10 {
        for li in 0..cfg.n_layer {
            let _ = pool.layer_views(&tb_refs, li, &uptos, &mut scratch);
        }
    }
    assert_eq!(scratch.alloc_events(), regrown, "grown shape must be warm after one round");
}
