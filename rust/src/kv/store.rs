//! Block storage backends: fp32 or quantized (fp8-e4m3 / int8 /
//! dense-and-sparse int4) with per-block, per-layer K/V scales.
//!
//! A [`KvStore`] holds one block's K and V rows for every layer. The
//! `F32` variant is the exact baseline (rows stored verbatim). The `Q8`
//! variant stores one byte per element plus, per layer and per side (K
//! or V), a single `amax` — the running max-abs over the rows written so
//! far. The effective scale is `amax / code_max` (127 for int8, 448 for
//! fp8-e4m3), so every committed row decodes as `code · scale`.
//!
//! Rows arrive append-only (the pool's staged-write discipline). When a
//! new row raises `amax`, the rows already in the slab are requantized
//! onto the new scale (decode with the old scale, re-encode with the
//! new). A slab never holds more than `KV_BLOCK_TOKENS` rows, so the
//! rescale is a bounded, block-local walk — and because rows are always
//! written in order, the final codes are a pure function of the row
//! values, which keeps freeze-time dedup exact: identical token chains
//! produce bit-identical quantized blocks.
//!
//! The `Q4` variant ([`KvDtype::Int4Outlier`]) is SDQ's dense-and-sparse
//! decomposition applied to the KV cache (SqueezeLLM / SpQR style):
//! a dense plane of packed two's-complement **nibble** codes (two
//! elements per byte, the `sdq::qmat` packing convention) on the same
//! running-amax scale, plus a small sorted **outlier side-table** of
//! rows kept as exact f32. A row goes to the side-table when encoding
//! it on the current block grid would leave a residual above
//! [`OUTLIER_THRESH`]·amax — which is exactly the row that would
//! otherwise force a catastrophic rescale of its neighbours — capped at
//! [`outlier_cap`] rows per (layer, side) slab. Outlier rows store zero
//! nibbles in the dense plane (rescales keep them zero), never touch
//! `amax`, and decode exactly; the outlier decision is a pure function
//! of the write history, so dedup stays exact for int4 blocks too.

use crate::formats::NumFormat;

/// Storage dtype for KV blocks (the `kv_dtype` knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum KvDtype {
    /// Exact fp32 rows (the baseline; zero-copy reads).
    #[default]
    F32,
    /// OCP fp8-e4m3 codes with per-block-per-layer f32 scales.
    Fp8E4M3,
    /// Symmetric int8 codes with per-block-per-layer f32 scales.
    Int8,
    /// Dense-and-sparse int4: packed two's-complement nibble codes on
    /// per-block-per-layer f32 scales, plus a capped exact-f32 outlier
    /// row side-table per (layer, side) slab.
    Int4Outlier,
}

impl KvDtype {
    /// Packed payload bytes of one stored K/V row of `d` elements
    /// (int4 packs two codes per byte; a row is byte-padded so rows
    /// stay byte-addressable).
    pub fn row_bytes(self, d: usize) -> usize {
        match self {
            KvDtype::F32 => 4 * d,
            KvDtype::Fp8E4M3 | KvDtype::Int8 => d,
            KvDtype::Int4Outlier => d.div_ceil(2),
        }
    }

    /// Scale metadata bytes per (layer, K/V side) per block: one f32
    /// `amax` for quantized stores, nothing for fp32.
    pub fn scale_bytes(self) -> usize {
        match self {
            KvDtype::F32 => 0,
            KvDtype::Fp8E4M3 | KvDtype::Int8 | KvDtype::Int4Outlier => 4,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Fp8E4M3 => "fp8-e4m3",
            KvDtype::Int8 => "int8",
            KvDtype::Int4Outlier => "int4",
        }
    }

    /// Parse the CLI/JSON spelling (accepts the same aliases as
    /// [`crate::formats::NumFormat`] where they overlap).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" | "fp32" => Ok(KvDtype::F32),
            "fp8" | "fp8-e4m3" | "fp8e4m3" => Ok(KvDtype::Fp8E4M3),
            "int8" => Ok(KvDtype::Int8),
            "int4" | "int4-outlier" => Ok(KvDtype::Int4Outlier),
            _ => anyhow::bail!("unknown kv dtype: {s} (expected f32 | fp8-e4m3 | int8 | int4)"),
        }
    }

    /// Largest code magnitude of the storage grid — the scale anchor
    /// (`scale = amax / code_max`).
    pub(crate) fn code_max(self) -> f32 {
        match self {
            KvDtype::F32 => unreachable!("f32 blocks are not scaled"),
            KvDtype::Fp8E4M3 => 448.0,
            KvDtype::Int8 => 127.0,
            KvDtype::Int4Outlier => 7.0,
        }
    }
}

/// A row joins the int4 outlier side-table when quantizing it on the
/// current block grid leaves a max-abs residual above this fraction of
/// the block's `amax`. In-range rows land within half a grid step
/// (`amax/14 ≈ 0.07·amax`), so only rows that would blow past the grid
/// — the ones that would otherwise force a coarse rescale of their
/// neighbours — qualify.
pub(crate) const OUTLIER_THRESH: f32 = 0.25;

/// Outlier side-table capacity per (layer, K/V side) slab: ~1/16 of the
/// block's rows, at least one (a 16-token block keeps exactly one
/// exact-f32 escape hatch per slab).
pub(crate) fn outlier_cap(block_tokens: usize) -> usize {
    (block_tokens / 16).max(1)
}

/// Sign-extended int4 code at element index `idx` of a packed nibble
/// row (`qmat.rs` convention: element `i` lives in byte `i/2`, low
/// nibble for even `i`).
#[inline]
pub(crate) fn nib_at(bytes: &[u8], idx: usize) -> i8 {
    let n = (bytes[idx / 2] >> (4 * (idx % 2))) & 0x0f;
    ((n << 4) as i8) >> 4
}

/// Store an int4 code at element index `idx`, preserving its byte's
/// other nibble.
#[inline]
fn nib_set(bytes: &mut [u8], idx: usize, code: i8) {
    let shift = 4 * (idx % 2);
    let b = &mut bytes[idx / 2];
    *b = (*b & !(0x0f << shift)) | (((code as u8) & 0x0f) << shift);
}

/// Encode one element onto the int4 grid under `scale` (`amax / 7`).
#[inline]
fn enc_i4(scale: f32, x: f32) -> i8 {
    if scale == 0.0 {
        return 0;
    }
    (x / scale).round_ties_even().clamp(-7.0, 7.0) as i8
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.tag())
    }
}

/// Encode an (already scale-normalized) value to an fp8-e4m3 byte:
/// sign(1) · exponent(4, bias 7) · mantissa(3), round-to-nearest-even,
/// clamped to ±448. The NaN patterns (`0x7f`/`0xff`) are never produced.
pub fn fp8_e4m3_encode(x: f32) -> u8 {
    // Snap onto the grid first (RNE, clamp) so the bit extraction below
    // is exact: an on-grid value has at most 3 significant mantissa bits.
    let q = NumFormat::Fp8E4M3.quantize(if x.is_nan() { 0.0 } else { x });
    let sign = if q.is_sign_negative() { 0x80u8 } else { 0 };
    let a = q.abs();
    if a == 0.0 {
        return sign;
    }
    let bits = a.to_bits();
    let e = ((bits >> 23) & 0xff) as i32 - 127;
    if e < -6 {
        // Subnormal: a = m · 2⁻⁹ with m ∈ 1..=7 exactly on-grid.
        sign | (a * 512.0) as u8
    } else {
        let mant = ((bits >> 20) & 0x7) as u8;
        sign | (((e + 7) as u8) << 3) | mant
    }
}

/// Decode an fp8-e4m3 byte back to f32 (exact).
pub fn fp8_e4m3_decode(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0xf) as i32;
    let m = (b & 0x7) as f32;
    if e == 0 {
        sign * m * (1.0 / 512.0) // subnormal: m · 2⁻⁹
    } else {
        sign * (1.0 + m / 8.0) * (2.0f32).powi(e - 7)
    }
}

/// Encode one element under `scale` (`amax / code_max`).
#[inline]
fn enc(dtype: KvDtype, scale: f32, x: f32) -> u8 {
    if scale == 0.0 {
        return 0;
    }
    match dtype {
        KvDtype::F32 => unreachable!("f32 rows are stored verbatim"),
        KvDtype::Int8 => (x / scale).round_ties_even().clamp(-127.0, 127.0) as i8 as u8,
        KvDtype::Fp8E4M3 => fp8_e4m3_encode(x / scale),
        KvDtype::Int4Outlier => unreachable!("int4 rows go through the nibble codec"),
    }
}

/// Decode one element under `scale`.
#[inline]
fn dec(dtype: KvDtype, scale: f32, b: u8) -> f32 {
    match dtype {
        KvDtype::F32 => unreachable!("f32 rows are stored verbatim"),
        KvDtype::Int8 => (b as i8) as f32 * scale,
        KvDtype::Fp8E4M3 => fp8_e4m3_decode(b) * scale,
        KvDtype::Int4Outlier => unreachable!("int4 rows go through the nibble codec"),
    }
}

/// One block's K/V payload for all layers (layer-major slabs of
/// `block_tokens × d`, exactly like the fp32 layout it generalizes).
/// `Clone` is the speculative-decode checkpoint primitive: a clone of a
/// partial tail block (codes *and* scales) is a bit-exact snapshot that
/// [`super::BlockPool::rollback`] can re-install after rejected drafts.
/// `PartialEq` compares payload bytes and scales exactly — the guard a
/// preemption resume uses before re-attaching an indexed block in place
/// of its swapped-out copy (quantized codes must match bit-for-bit or
/// the resume installs its own snapshot bytes instead).
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum KvStore {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Q8 {
        dtype: KvDtype,
        k: Vec<u8>,
        v: Vec<u8>,
        /// Per-layer running max-abs of the K rows written so far
        /// (`scale = amax / code_max`).
        k_amax: Vec<f32>,
        /// Per-layer running max-abs of the V rows.
        v_amax: Vec<f32>,
    },
    /// Dense-and-sparse int4: packed nibble slabs (`block_tokens ×
    /// d.div_ceil(2)` bytes per layer per side) + per-layer sorted
    /// outlier side-tables of `(row, exact f32 row)` entries. Outlier
    /// rows keep zero nibbles in the dense plane and are excluded from
    /// the `amax` running max.
    Q4 {
        k: Vec<u8>,
        v: Vec<u8>,
        k_amax: Vec<f32>,
        v_amax: Vec<f32>,
        /// Per-layer K outlier tables, sorted by row index.
        k_out: Vec<Vec<(u16, Vec<f32>)>>,
        /// Per-layer V outlier tables, sorted by row index.
        v_out: Vec<Vec<(u16, Vec<f32>)>>,
    },
}

impl KvStore {
    pub fn new(dtype: KvDtype, n_layer: usize, block_tokens: usize, d: usize) -> Self {
        let n = n_layer * block_tokens * d;
        match dtype {
            KvDtype::F32 => KvStore::F32 { k: vec![0.0; n], v: vec![0.0; n] },
            KvDtype::Int4Outlier => {
                let nb = n_layer * block_tokens * d.div_ceil(2);
                KvStore::Q4 {
                    k: vec![0; nb],
                    v: vec![0; nb],
                    k_amax: vec![0.0; n_layer],
                    v_amax: vec![0.0; n_layer],
                    k_out: vec![Vec::new(); n_layer],
                    v_out: vec![Vec::new(); n_layer],
                }
            }
            _ => KvStore::Q8 {
                dtype,
                k: vec![0; n],
                v: vec![0; n],
                k_amax: vec![0.0; n_layer],
                v_amax: vec![0.0; n_layer],
            },
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match self {
            KvStore::F32 { .. } => KvDtype::F32,
            KvStore::Q8 { dtype, .. } => *dtype,
            KvStore::Q4 { .. } => KvDtype::Int4Outlier,
        }
    }

    /// Reset per-slot state on (re)allocation. Quantized scales MUST be
    /// cleared: a stale `amax` from the slot's previous tenant would
    /// change the codes new rows quantize to, breaking the determinism
    /// freeze-time dedup relies on. Int4 outlier tables likewise — a
    /// stale entry would shadow the new tenant's dense rows. Codes/rows
    /// need no clearing — reads never pass the written row count, and
    /// int4 writes zero a row's packed bytes before setting nibbles.
    pub fn reset(&mut self) {
        match self {
            KvStore::F32 { .. } => {}
            KvStore::Q8 { k_amax, v_amax, .. } => {
                k_amax.fill(0.0);
                v_amax.fill(0.0);
            }
            KvStore::Q4 { k_amax, v_amax, k_out, v_out, .. } => {
                k_amax.fill(0.0);
                v_amax.fill(0.0);
                for t in k_out.iter_mut().chain(v_out.iter_mut()) {
                    t.clear();
                }
            }
        }
    }

    /// Stage the K/V row for layer `li` at block-local row index `row`.
    /// Quantized stores grow the layer's scale first if this row raises
    /// `amax`, requantizing the rows already in the slab.
    pub fn write_row(
        &mut self,
        li: usize,
        row: usize,
        bt: usize,
        d: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let base = li * bt * d + row * d;
        match self {
            KvStore::F32 { k, v } => {
                k[base..base + d].copy_from_slice(k_row);
                v[base..base + d].copy_from_slice(v_row);
            }
            KvStore::Q8 { dtype, k, v, k_amax, v_amax } => {
                let slab = li * bt * d;
                write_side(*dtype, &mut k[slab..slab + bt * d], &mut k_amax[li], row, d, k_row);
                write_side(*dtype, &mut v[slab..slab + bt * d], &mut v_amax[li], row, d, v_row);
            }
            KvStore::Q4 { k, v, k_amax, v_amax, k_out, v_out } => {
                let stride = d.div_ceil(2);
                let slab = li * bt * stride;
                let cap = outlier_cap(bt);
                write_side_q4(
                    &mut k[slab..slab + bt * stride],
                    &mut k_amax[li],
                    &mut k_out[li],
                    row,
                    d,
                    cap,
                    k_row,
                );
                write_side_q4(
                    &mut v[slab..slab + bt * stride],
                    &mut v_amax[li],
                    &mut v_out[li],
                    row,
                    d,
                    cap,
                    v_row,
                );
            }
        }
    }

    /// Copy the first `rows` rows of every layer from `src` (the
    /// copy-on-write path). Scales come along verbatim: the source's
    /// `amax` covers exactly its committed rows, so the copy decodes
    /// bit-identically.
    pub fn copy_rows_from(
        &mut self,
        src: &KvStore,
        rows: usize,
        n_layer: usize,
        bt: usize,
        d: usize,
    ) {
        match (self, src) {
            (KvStore::F32 { k, v }, KvStore::F32 { k: sk, v: sv }) => {
                for li in 0..n_layer {
                    let base = li * bt * d;
                    k[base..base + rows * d].copy_from_slice(&sk[base..base + rows * d]);
                    v[base..base + rows * d].copy_from_slice(&sv[base..base + rows * d]);
                }
            }
            (
                KvStore::Q8 { dtype, k, v, k_amax, v_amax },
                KvStore::Q8 { dtype: sd, k: sk, v: sv, k_amax: ska, v_amax: sva },
            ) => {
                debug_assert_eq!(dtype, sd, "pool blocks share one dtype");
                for li in 0..n_layer {
                    let base = li * bt * d;
                    k[base..base + rows * d].copy_from_slice(&sk[base..base + rows * d]);
                    v[base..base + rows * d].copy_from_slice(&sv[base..base + rows * d]);
                }
                k_amax.copy_from_slice(ska);
                v_amax.copy_from_slice(sva);
            }
            (
                KvStore::Q4 { k, v, k_amax, v_amax, k_out, v_out },
                KvStore::Q4 { k: sk, v: sv, k_amax: ska, v_amax: sva, k_out: sko, v_out: svo },
            ) => {
                let stride = d.div_ceil(2);
                for li in 0..n_layer {
                    let base = li * bt * stride;
                    k[base..base + rows * stride]
                        .copy_from_slice(&sk[base..base + rows * stride]);
                    v[base..base + rows * stride]
                        .copy_from_slice(&sv[base..base + rows * stride]);
                }
                k_amax.copy_from_slice(ska);
                v_amax.copy_from_slice(sva);
                // Side-tables come along too, filtered to the copied
                // rows (entries are sorted, so the filter keeps order).
                for li in 0..n_layer {
                    k_out[li].clear();
                    k_out[li]
                        .extend(sko[li].iter().filter(|(r, _)| (*r as usize) < rows).cloned());
                    v_out[li].clear();
                    v_out[li]
                        .extend(svo[li].iter().filter(|(r, _)| (*r as usize) < rows).cloned());
                }
            }
            _ => unreachable!("pool blocks share one dtype"),
        }
    }

    /// Borrowed fp32 row slices for layer `li` (`rows × d`). F32 stores
    /// only — the zero-copy fast path.
    pub fn f32_slices(&self, li: usize, rows: usize, bt: usize, d: usize) -> (&[f32], &[f32]) {
        match self {
            KvStore::F32 { k, v } => {
                let base = li * bt * d;
                (&k[base..base + rows * d], &v[base..base + rows * d])
            }
            _ => unreachable!("quantized blocks dequantize via scratch"),
        }
    }

    /// Borrowed *code* slices for layer `li` (`rows × d` raw bytes each)
    /// plus the layer's effective K and V scales — the quantized-domain
    /// read path ([`super::qattn`]): attention decodes elements in
    /// register (`code · scale`, the exact op [`Self::dequant_into`]
    /// applies) instead of staging an fp32 copy in scratch. Q8 stores
    /// only.
    pub fn code_slices(
        &self,
        li: usize,
        rows: usize,
        bt: usize,
        d: usize,
    ) -> (&[u8], &[u8], f32, f32) {
        match self {
            KvStore::Q8 { dtype, k, v, k_amax, v_amax } => {
                let base = li * bt * d;
                let ks = k_amax[li] / dtype.code_max();
                let vs = v_amax[li] / dtype.code_max();
                (&k[base..base + rows * d], &v[base..base + rows * d], ks, vs)
            }
            _ => unreachable!("code_slices is the one-byte-per-element (Q8) view"),
        }
    }

    /// Build the quantized-domain K and V segment views for layer `li`
    /// covering the first `rows` rows — the dtype-generic source behind
    /// [`super::BlockPool::layer_code_views`]. Q8 stores hand out byte
    /// segments; Q4 stores hand out nibble segments carrying their
    /// outlier side-tables.
    pub fn quant_segs(
        &self,
        li: usize,
        rows: usize,
        bt: usize,
        d: usize,
    ) -> (super::qattn::QuantSeg<'_>, super::qattn::QuantSeg<'_>) {
        use super::qattn::QuantSeg;
        match self {
            KvStore::F32 { .. } => unreachable!("f32 blocks read zero-copy via f32_slices"),
            KvStore::Q8 { dtype, k, v, k_amax, v_amax } => {
                let base = li * bt * d;
                (
                    QuantSeg::Byte {
                        codes: &k[base..base + rows * d],
                        scale: k_amax[li] / dtype.code_max(),
                    },
                    QuantSeg::Byte {
                        codes: &v[base..base + rows * d],
                        scale: v_amax[li] / dtype.code_max(),
                    },
                )
            }
            KvStore::Q4 { k, v, k_amax, v_amax, k_out, v_out } => {
                let stride = d.div_ceil(2);
                let base = li * bt * stride;
                (
                    QuantSeg::Nibble {
                        codes: &k[base..base + rows * stride],
                        scale: k_amax[li] / KvDtype::Int4Outlier.code_max(),
                        outliers: &k_out[li],
                    },
                    QuantSeg::Nibble {
                        codes: &v[base..base + rows * stride],
                        scale: v_amax[li] / KvDtype::Int4Outlier.code_max(),
                        outliers: &v_out[li],
                    },
                )
            }
        }
    }

    /// Dequantize the first `rows` rows of layer `li` into `k_out` /
    /// `v_out` (each `rows × d`).
    pub fn dequant_into(
        &self,
        li: usize,
        rows: usize,
        bt: usize,
        d: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        debug_assert_eq!(k_out.len(), rows * d);
        debug_assert_eq!(v_out.len(), rows * d);
        match self {
            KvStore::F32 { k, v } => {
                let base = li * bt * d;
                k_out.copy_from_slice(&k[base..base + rows * d]);
                v_out.copy_from_slice(&v[base..base + rows * d]);
            }
            KvStore::Q8 { dtype, k, v, k_amax, v_amax } => {
                let base = li * bt * d;
                let ks = k_amax[li] / dtype.code_max();
                let vs = v_amax[li] / dtype.code_max();
                for (o, b) in k_out.iter_mut().zip(&k[base..base + rows * d]) {
                    *o = dec(*dtype, ks, *b);
                }
                for (o, b) in v_out.iter_mut().zip(&v[base..base + rows * d]) {
                    *o = dec(*dtype, vs, *b);
                }
            }
            KvStore::Q4 { k, v, k_amax, v_amax, k_out: ko, v_out: vo } => {
                let stride = d.div_ceil(2);
                let base = li * bt * stride;
                let ks = k_amax[li] / KvDtype::Int4Outlier.code_max();
                let vs = v_amax[li] / KvDtype::Int4Outlier.code_max();
                dequant_side_q4(&k[base..], &ko[li], rows, d, stride, ks, k_out);
                dequant_side_q4(&v[base..], &vo[li], rows, d, stride, vs, v_out);
            }
        }
    }
}

/// Decode `rows` dense-and-sparse int4 rows: outlier rows copy their
/// exact f32 entry, dense rows decode `fl(code · scale)` per element —
/// the identical op [`super::qattn`]'s nibble kernels apply in register,
/// which is what pins the scratch and quantized-domain attention routes
/// bit-equal for int4.
fn dequant_side_q4(
    slab: &[u8],
    table: &[(u16, Vec<f32>)],
    rows: usize,
    d: usize,
    stride: usize,
    scale: f32,
    dst: &mut [f32],
) {
    for r in 0..rows {
        let dst_row = &mut dst[r * d..(r + 1) * d];
        if let Ok(i) = table.binary_search_by_key(&(r as u16), |(row, _)| *row) {
            dst_row.copy_from_slice(&table[i].1);
        } else {
            let rb = &slab[r * stride..(r + 1) * stride];
            for (j, o) in dst_row.iter_mut().enumerate() {
                *o = nib_at(rb, j) as f32 * scale;
            }
        }
    }
}

/// Append one row to a quantized layer slab, growing the scale (and
/// requantizing the `row` prior rows) when the new row's max-abs
/// exceeds the running `amax`.
fn write_side(dtype: KvDtype, slab: &mut [u8], amax: &mut f32, row: usize, d: usize, vals: &[f32]) {
    debug_assert_eq!(vals.len(), d);
    let m = vals.iter().fold(0.0f32, |a, x| a.max(x.abs()));
    if m > *amax {
        let old_scale = *amax / dtype.code_max();
        *amax = m;
        let new_scale = m / dtype.code_max();
        if old_scale > 0.0 {
            for b in slab[..row * d].iter_mut() {
                *b = enc(dtype, new_scale, dec(dtype, old_scale, *b));
            }
        }
    }
    let s = *amax / dtype.code_max();
    for (c, x) in slab[row * d..(row + 1) * d].iter_mut().zip(vals) {
        *c = enc(dtype, s, *x);
    }
}

/// Append one row to a dense-and-sparse int4 layer slab (`bt × stride`
/// packed bytes + a sorted outlier side-table). Decision order — a pure
/// function of the write history, so identical histories still yield
/// identical blocks:
///
/// 1. Drop any stale side-table entry for `row` (speculative rollback
///    re-stages rows in place).
/// 2. If the block grid is live (`amax > 0`), the table has room, and
///    encoding the row on the **current** grid leaves a residual above
///    `OUTLIER_THRESH · amax`, the row goes to the side-table exact:
///    zero nibbles in the dense plane, `amax` untouched. This is
///    precisely the row that would otherwise force a coarse rescale of
///    every neighbour.
/// 3. Otherwise the row is dense: grow `amax`/requantize prior rows as
///    the byte path does (outlier rows hold zero codes, and zero decodes
///    and re-encodes to zero, so rescales leave them zero), then encode.
fn write_side_q4(
    slab: &mut [u8],
    amax: &mut f32,
    table: &mut Vec<(u16, Vec<f32>)>,
    row: usize,
    d: usize,
    cap: usize,
    vals: &[f32],
) {
    debug_assert_eq!(vals.len(), d);
    let stride = d.div_ceil(2);
    if let Some(i) = table.iter().position(|(r, _)| *r as usize == row) {
        table.remove(i);
    }
    if *amax > 0.0 && table.len() < cap {
        let s = *amax / 7.0;
        let res = vals.iter().fold(0.0f32, |a, &x| a.max((x - enc_i4(s, x) as f32 * s).abs()));
        if res > OUTLIER_THRESH * *amax {
            let i = table.partition_point(|(r, _)| (*r as usize) < row);
            table.insert(i, (row as u16, vals.to_vec()));
            slab[row * stride..(row + 1) * stride].fill(0);
            return;
        }
    }
    let m = vals.iter().fold(0.0f32, |a, x| a.max(x.abs()));
    if m > *amax {
        let old_scale = *amax / 7.0;
        *amax = m;
        let new_scale = m / 7.0;
        if old_scale > 0.0 {
            for r in 0..row {
                let rb = r * stride;
                for j in 0..d {
                    let x = nib_at(&slab[rb..rb + stride], j) as f32 * old_scale;
                    nib_set(&mut slab[rb..rb + stride], j, enc_i4(new_scale, x));
                }
            }
        }
    }
    let s = *amax / 7.0;
    let rb = row * stride;
    slab[rb..rb + stride].fill(0);
    for (j, &x) in vals.iter().enumerate() {
        nib_set(&mut slab[rb..rb + stride], j, enc_i4(s, x));
    }
}

/// Reusable dequantization arena for [`super::BlockPool::layer_views`]:
/// owns the fp32 buffers quantized blocks decode into, so attention can
/// keep borrowing plain `&[f32]` segments whatever the pool dtype. The
/// buffers persist across calls (cleared, not freed) — one scratch per
/// forward pass amortizes the allocations across layers.
#[derive(Debug, Default)]
pub struct KvScratch {
    bufs: Vec<Vec<f32>>,
    used: usize,
    /// Heap-allocation events (new buffer pushed, or an existing buffer
    /// regrown past its capacity). A warm scratch reused across rounds
    /// of the same shape must not advance this — the no-per-round-
    /// allocation tests pin that.
    allocs: u64,
}

impl KvScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocation events so far (see the field doc). Monotonic; never
    /// reset so tests can difference across rounds.
    pub fn alloc_events(&self) -> u64 {
        self.allocs
    }

    pub(crate) fn reset(&mut self) {
        self.used = 0;
    }

    /// Claim a buffer of `len` floats; returns its index. Contents are
    /// unspecified (recycled buffers keep stale data) — the fill phase
    /// in [`super::BlockPool::layer_views`] overwrites every row before
    /// any view is taken, so re-zeroing here would only double the
    /// memory writes of the dequant hot path.
    pub(crate) fn take(&mut self, len: usize) -> usize {
        if self.used == self.bufs.len() {
            self.bufs.push(Vec::with_capacity(len));
            self.allocs += 1;
        }
        let i = self.used;
        self.used += 1;
        let b = &mut self.bufs[i];
        if b.capacity() < len {
            self.allocs += 1;
        }
        b.resize(len, 0.0);
        i
    }

    pub(crate) fn buf(&self, i: usize) -> &[f32] {
        &self.bufs[i]
    }

    /// Two distinct buffers mutably at once (`i < j` — `take` hands out
    /// ascending indices, so a K/V pair always satisfies this).
    pub(crate) fn bufs_pair_mut(&mut self, i: usize, j: usize) -> (&mut [f32], &mut [f32]) {
        assert!(i < j, "pair indices must be distinct and ascending");
        let (a, b) = self.bufs.split_at_mut(j);
        (&mut a[i], &mut b[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp8_codec_roundtrips_every_byte() {
        // Every non-NaN byte decodes to a finite on-grid value and
        // re-encodes to itself (modulo -0 → +0).
        for b in 0..=255u8 {
            if b & 0x7f == 0x7f {
                continue; // OCP NaN patterns — never produced
            }
            let x = fp8_e4m3_decode(b);
            assert!(x.is_finite() && x.abs() <= 448.0, "byte {b:#04x} → {x}");
            let back = fp8_e4m3_encode(x);
            if b == 0x80 {
                assert!(back == 0x80 || back == 0, "-0 may normalize");
            } else {
                assert_eq!(back, b, "byte {b:#04x} → {x} → {back:#04x}");
            }
        }
    }

    #[test]
    fn fp8_encode_matches_grid_quantizer() {
        // decode(encode(x)) must equal NumFormat::Fp8E4M3.quantize(x):
        // the byte codec and the eval-path quantizer share one grid.
        let mut x = -500.0f32;
        while x < 500.0 {
            let via_codec = fp8_e4m3_decode(fp8_e4m3_encode(x));
            let via_grid = NumFormat::Fp8E4M3.quantize(x);
            assert_eq!(via_codec, via_grid, "x = {x}");
            x += 0.173;
        }
    }

    #[test]
    fn int8_write_read_roundtrip_is_tight() {
        let (bt, d) = (4, 8);
        let mut s = KvStore::new(KvDtype::Int8, 1, bt, d);
        let row: Vec<f32> = (0..d).map(|i| (i as f32 - 3.5) * 0.25).collect();
        s.write_row(0, 0, bt, d, &row, &row);
        let mut k = vec![0.0; d];
        let mut v = vec![0.0; d];
        s.dequant_into(0, 1, bt, d, &mut k, &mut v);
        let amax = row.iter().fold(0.0f32, |a, x| a.max(x.abs()));
        for (got, want) in k.iter().zip(&row) {
            assert!((got - want).abs() <= amax / 254.0 + 1e-7, "{got} vs {want}");
        }
        assert_eq!(k, v);
    }

    #[test]
    fn growing_amax_requantizes_prior_rows() {
        let (bt, d) = (4, 4);
        let mut s = KvStore::new(KvDtype::Int8, 1, bt, d);
        s.write_row(0, 0, bt, d, &[0.1, -0.2, 0.3, 0.05], &[0.0; 4]);
        // Second row is 100× larger: row 0 must survive the rescale.
        s.write_row(0, 1, bt, d, &[30.0, -10.0, 5.0, 1.0], &[0.0; 4]);
        let mut k = vec![0.0; 2 * d];
        let mut v = vec![0.0; 2 * d];
        s.dequant_into(0, 2, bt, d, &mut k, &mut v);
        // Row 0 is now on a 30/127 ≈ 0.24 grid: coarse but centered.
        for (got, want) in k[..d].iter().zip(&[0.1, -0.2, 0.3, 0.05]) {
            assert!((got - want).abs() <= 30.0 / 127.0, "{got} vs {want}");
        }
        for (got, want) in k[d..].iter().zip(&[30.0, -10.0, 5.0, 1.0]) {
            assert!((got - want).abs() <= 30.0 / 254.0 + 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn reset_clears_scales_for_slot_reuse() {
        let (bt, d) = (2, 2);
        let mut s = KvStore::new(KvDtype::Fp8E4M3, 1, bt, d);
        s.write_row(0, 0, bt, d, &[100.0, -100.0], &[7.0, 7.0]);
        s.reset();
        s.write_row(0, 0, bt, d, &[0.01, 0.02], &[0.01, 0.02]);
        let mut k = vec![0.0; d];
        let mut v = vec![0.0; d];
        s.dequant_into(0, 1, bt, d, &mut k, &mut v);
        // Under the stale 100.0 scale these would collapse to ~0 codes;
        // after reset they round-trip within fp8 relative error.
        assert!((k[0] - 0.01).abs() < 0.01 * 0.07, "stale scale survived reset: {}", k[0]);
        assert!((k[1] - 0.02).abs() < 0.02 * 0.07);
    }

    #[test]
    fn scratch_reuses_capacity_across_rounds() {
        let mut s = KvScratch::new();
        // Cold round: allocations expected.
        s.reset();
        assert_eq!(s.take(64), 0);
        assert_eq!(s.take(128), 1);
        assert!(s.alloc_events() > 0);
        let warm = s.alloc_events();
        // Warm rounds of the same shape: zero new allocations.
        for _ in 0..10 {
            s.reset();
            s.take(64);
            s.take(128);
        }
        assert_eq!(s.alloc_events(), warm, "warm rounds must not allocate");
        // Growing a buffer past capacity is an allocation event again.
        s.reset();
        s.take(256);
        assert!(s.alloc_events() > warm);
    }

    #[test]
    fn code_slices_match_dequant_into() {
        let (bt, d) = (4, 8);
        let mut s = KvStore::new(KvDtype::Int8, 2, bt, d);
        for r in 0..3 {
            let row: Vec<f32> = (0..d).map(|i| ((r * d + i) as f32).sin() * 2.0).collect();
            for li in 0..2 {
                s.write_row(li, r, bt, d, &row, &row);
            }
        }
        for li in 0..2 {
            let (kc, vc, ks, vs) = s.code_slices(li, 3, bt, d);
            let mut k = vec![0.0; 3 * d];
            let mut v = vec![0.0; 3 * d];
            s.dequant_into(li, 3, bt, d, &mut k, &mut v);
            for (i, (&b, &want)) in kc.iter().zip(&k).enumerate() {
                assert_eq!((b as i8) as f32 * ks, want, "k elem {i}");
            }
            for (i, (&b, &want)) in vc.iter().zip(&v).enumerate() {
                assert_eq!((b as i8) as f32 * vs, want, "v elem {i}");
            }
        }
    }

    #[test]
    fn identical_write_histories_produce_identical_bytes() {
        // The determinism freeze-time dedup depends on: same rows in the
        // same order ⇒ same codes and scales, even across rescales.
        let (bt, d) = (4, 8);
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..d).map(|i| ((r * d + i) as f32).sin() * (r as f32 + 0.1)).collect())
            .collect();
        let mut a = KvStore::new(KvDtype::Int8, 2, bt, d);
        let mut b = KvStore::new(KvDtype::Int8, 2, bt, d);
        for (r, row) in rows.iter().enumerate() {
            for li in 0..2 {
                a.write_row(li, r, bt, d, row, row);
                b.write_row(li, r, bt, d, row, row);
            }
        }
        match (&a, &b) {
            (
                KvStore::Q8 { k, v, k_amax, v_amax, .. },
                KvStore::Q8 { k: k2, v: v2, k_amax: ka2, v_amax: va2, .. },
            ) => {
                assert_eq!(k, k2);
                assert_eq!(v, v2);
                assert_eq!(k_amax, ka2);
                assert_eq!(v_amax, va2);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn int4_in_range_rows_roundtrip_within_grid_step() {
        let (bt, d) = (4, 8);
        let mut s = KvStore::new(KvDtype::Int4Outlier, 1, bt, d);
        let row: Vec<f32> = (0..d).map(|i| (i as f32 - 3.5) * 0.25).collect();
        s.write_row(0, 0, bt, d, &row, &row);
        let mut k = vec![0.0; d];
        let mut v = vec![0.0; d];
        s.dequant_into(0, 1, bt, d, &mut k, &mut v);
        let amax = row.iter().fold(0.0f32, |a, x| a.max(x.abs()));
        for (got, want) in k.iter().zip(&row) {
            // Half an int4 grid step.
            assert!((got - want).abs() <= amax / 14.0 + 1e-6, "{got} vs {want}");
        }
        assert_eq!(k, v);
    }

    #[test]
    fn int4_outlier_row_is_exact_and_leaves_amax_alone() {
        let (bt, d) = (4, 4);
        let mut s = KvStore::new(KvDtype::Int4Outlier, 1, bt, d);
        s.write_row(0, 0, bt, d, &[0.1, -0.2, 0.3, 0.05], &[0.1; 4]);
        // 100× the running amax: residual on the current grid blows the
        // threshold, so the row must land in the side-table exact while
        // row 0's codes (and the 0.3 amax) stay untouched.
        let spike = [30.0, -10.0, 5.0, 1.0];
        s.write_row(0, 1, bt, d, &spike, &[0.1; 4]);
        match &s {
            KvStore::Q4 { k_amax, k_out, v_out, .. } => {
                assert_eq!(k_amax[0], 0.3, "outlier must not grow amax");
                assert_eq!(k_out[0].len(), 1);
                assert_eq!(k_out[0][0].0, 1);
                assert_eq!(k_out[0][0].1, spike.to_vec());
                assert!(v_out[0].is_empty(), "in-range V rows stay dense");
            }
            _ => unreachable!(),
        }
        let mut k = vec![0.0; 2 * d];
        let mut v = vec![0.0; 2 * d];
        s.dequant_into(0, 2, bt, d, &mut k, &mut v);
        assert_eq!(&k[d..], &spike, "outlier decodes exactly");
        // Row 0 kept its fine 0.3/7 grid instead of a 30/7 one.
        for (got, want) in k[..d].iter().zip(&[0.1, -0.2, 0.3, 0.05]) {
            assert!((got - want).abs() <= 0.3 / 14.0 + 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn int4_outlier_cap_forces_dense_rescale_when_full() {
        let (bt, d) = (4, 4);
        assert_eq!(outlier_cap(bt), 1);
        let mut s = KvStore::new(KvDtype::Int4Outlier, 1, bt, d);
        s.write_row(0, 0, bt, d, &[0.2, -0.1, 0.15, 0.05], &[0.0; 4]);
        s.write_row(0, 1, bt, d, &[40.0, 1.0, -2.0, 0.5], &[0.0; 4]); // → side-table
        // Cap is full: this spike must take the dense path and grow amax.
        s.write_row(0, 2, bt, d, &[70.0, -7.0, 3.5, 0.0], &[0.0; 4]);
        match &s {
            KvStore::Q4 { k_amax, k_out, .. } => {
                assert_eq!(k_out[0].len(), 1);
                assert_eq!(k_amax[0], 70.0);
            }
            _ => unreachable!(),
        }
        let mut k = vec![0.0; 3 * d];
        let mut v = vec![0.0; 3 * d];
        s.dequant_into(0, 3, bt, d, &mut k, &mut v);
        assert_eq!(&k[d..2 * d], &[40.0, 1.0, -2.0, 0.5], "side-table survives rescale");
        for (got, want) in k[2 * d..].iter().zip(&[70.0, -7.0, 3.5, 0.0]) {
            assert!((got - want).abs() <= 70.0 / 14.0 + 1e-4, "{got} vs {want}");
        }
    }

    #[test]
    fn int4_identical_write_histories_produce_identical_blocks() {
        let (bt, d) = (4, 8);
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| {
                (0..d)
                    .map(|i| {
                        let base = ((r * d + i) as f32).sin() * (r as f32 + 0.1);
                        // Make row 2 an outlier in both replicas.
                        if r == 2 { base * 50.0 } else { base }
                    })
                    .collect()
            })
            .collect();
        let mut a = KvStore::new(KvDtype::Int4Outlier, 2, bt, d);
        let mut b = KvStore::new(KvDtype::Int4Outlier, 2, bt, d);
        for (r, row) in rows.iter().enumerate() {
            for li in 0..2 {
                a.write_row(li, r, bt, d, row, row);
                b.write_row(li, r, bt, d, row, row);
            }
        }
        assert_eq!(a, b, "dedup needs int4 codes + side-tables to be history-pure");
        match &a {
            KvStore::Q4 { k_out, .. } => assert_eq!(k_out[0].len(), 1, "spike row went sparse"),
            _ => unreachable!(),
        }
    }

    #[test]
    fn int4_reset_clears_scales_and_side_tables() {
        let (bt, d) = (4, 4);
        let mut s = KvStore::new(KvDtype::Int4Outlier, 1, bt, d);
        s.write_row(0, 0, bt, d, &[0.1; 4], &[0.1; 4]);
        s.write_row(0, 1, bt, d, &[90.0, 0.0, 0.0, 0.0], &[0.1; 4]);
        s.reset();
        match &s {
            KvStore::Q4 { k_amax, k_out, .. } => {
                assert_eq!(k_amax[0], 0.0);
                assert!(k_out[0].is_empty(), "stale side-table would shadow the next tenant");
            }
            _ => unreachable!(),
        }
        s.write_row(0, 0, bt, d, &[0.01, 0.02, -0.03, 0.0], &[0.0; 4]);
        let mut k = vec![0.0; d];
        let mut v = vec![0.0; d];
        s.dequant_into(0, 1, bt, d, &mut k, &mut v);
        assert!((k[2] + 0.03).abs() <= 0.03 / 14.0 + 1e-7, "fresh grid after reset: {}", k[2]);
    }

    #[test]
    fn int4_rewriting_a_row_drops_its_stale_outlier_entry() {
        // Speculative rollback re-stages rows in place: an outlier that
        // becomes in-range on rewrite must leave the side-table.
        let (bt, d) = (4, 4);
        let mut s = KvStore::new(KvDtype::Int4Outlier, 1, bt, d);
        s.write_row(0, 0, bt, d, &[0.2, -0.1, 0.05, 0.0], &[0.0; 4]);
        s.write_row(0, 1, bt, d, &[50.0, 0.0, 0.0, 0.0], &[0.0; 4]);
        s.write_row(0, 1, bt, d, &[0.1, 0.1, -0.1, 0.1], &[0.0; 4]);
        match &s {
            KvStore::Q4 { k_out, .. } => assert!(k_out[0].is_empty()),
            _ => unreachable!(),
        }
        let mut k = vec![0.0; 2 * d];
        let mut v = vec![0.0; 2 * d];
        s.dequant_into(0, 2, bt, d, &mut k, &mut v);
        for (got, want) in k[d..].iter().zip(&[0.1, 0.1, -0.1, 0.1]) {
            assert!((got - want).abs() <= 0.2 / 14.0 + 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn int4_odd_width_pads_rows_to_bytes() {
        let (bt, d) = (2, 5); // stride 3, last nibble unused
        assert_eq!(KvDtype::Int4Outlier.row_bytes(d), 3);
        let mut s = KvStore::new(KvDtype::Int4Outlier, 1, bt, d);
        let r0: Vec<f32> = vec![0.7, -0.7, 0.3, -0.1, 0.5];
        let r1: Vec<f32> = vec![-0.2, 0.6, -0.6, 0.4, 0.0];
        s.write_row(0, 0, bt, d, &r0, &r0);
        s.write_row(0, 1, bt, d, &r1, &r1);
        let mut k = vec![0.0; 2 * d];
        let mut v = vec![0.0; 2 * d];
        s.dequant_into(0, 2, bt, d, &mut k, &mut v);
        for (got, want) in k.iter().zip(r0.iter().chain(&r1)) {
            assert!((got - want).abs() <= 0.7 / 14.0 + 1e-6, "{got} vs {want}");
        }
    }
}
