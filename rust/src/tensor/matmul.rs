//! Blocked, parallel GEMM kernels.
//!
//! Convention used throughout the crate: activations are `[tokens, in]`,
//! weights are `[out, in]` (row-major, like the paper's weight matrices
//! with N:M blocks along the *input* / dot-product dimension), and
//! `matmul(a, w)` computes `c[t, o] = Σ_k a[t, k] · w[o, k]`, i.e.
//! `A · Wᵀ`. Both operands are then walked along contiguous rows, which
//! autovectorizes well and keeps the N:M block direction identical to
//! the reduction direction — exactly the layout a structured-sparse
//! tensor core consumes.
//!
//! # Quantized weight planes (`gemm_panel_q`, §Perf iteration 10)
//!
//! [`matmul_q_into`] runs the same GEBP schedule against a packed
//! quantized weight plane (codes + per-(row, K-group) scales) instead
//! of a dense f32 `Matrix`, via the [`WeightPlane`] trait: per K-block,
//! the `≤ KB` weights of one output row are decoded `code · scale` into
//! an L1-resident stack buffer and fed to the identical 32-lane
//! [`dot`], so DRAM sees only the packed bytes. This is the CPU mirror
//! of the scale-folding schedule in the AOT Pallas kernel
//! (`python/compile/kernels/sdq_matmul.py::_dequant_tile`): there a
//! `[bn, bk]` codes tile is reshaped to `[bn, bk/qvec, qvec]` and
//! multiplied by `scales[..., None]` in VMEM before the MXU pass; here
//! the same per-Q-vector scale is applied to each ≤`qvec`-element code
//! group as the K-block is decoded into registers/L1, then the dense
//! micro-kernel runs unchanged.
//!
//! Bit-identity discipline (the contract `kv::qattn` and
//! `sdq::PackedNm::row_dot_q8` established): a [`WeightPlane`] decoder
//! must reproduce the dequantize path's per-element op order *exactly*
//! — for the VS-Quant plane that is `s = vec_scale * chan_scale` then
//! `w = code * s`, groups walked in ascending k — so `matmul_q_into`
//! equals dequantize-then-[`matmul_into`] to the bit on every tile
//! shape (the K-blocks accumulate in ascending-k order regardless of
//! how rows/columns were sliced, exactly as in the f32 panel).

use super::Matrix;
use crate::util::par::{par_chunks_mut, par_col_blocks, COL_BLOCK, TILE_ROWS};

/// Tunable K-blocking for the inner dot products; 256 f32 = 1 KiB per row
/// slice, keeps A and W panels resident in L1/L2.
const KB: usize = 256;

/// Token rows per register tile (shared with the N:M SpMM — see
/// [`TILE_ROWS`] for the GEBP rationale).
const TB: usize = TILE_ROWS;

/// Output-column block for the ragged column-parallel schedule (see
/// [`COL_BLOCK`]).
const CB: usize = COL_BLOCK;

/// `c = a · wᵀ` into a fresh matrix. `a: [m, k]`, `w: [n, k]` → `c: [m, n]`.
pub fn matmul(a: &Matrix, w: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, w.rows);
    matmul_into(a, w, &mut c);
    c
}

/// The GEBP micro-panel both parallel schedules call into: accumulate
/// `out[t, o-o0] += Σ_k a[t0+t, k] · w[o, k]` for activation rows
/// `t0..t0+rows` and output columns `o0..o1`, K-blocked (`KB`) so the A
/// slices stay L1-hot and the 32-lane [`dot`] is reused as the register
/// kernel. Inside each K-block the o loop walks `CB`-wide chunks (the W
/// panel that fits L2) with `t` innermost, so every W row loaded from
/// cache is dotted against all `rows` activation rows before moving on.
///
/// Numerics: per output element the K-blocks accumulate in ascending-k
/// order regardless of how the caller sliced rows/columns, so the row-
/// and column-parallel schedules (and any tile shape) produce
/// bit-identical results. `out` is row-major with stride `out_stride`
/// and must be pre-initialized (zeroed or carrying bias).
#[inline]
fn gemm_panel(
    a: &Matrix,
    w: &Matrix,
    t0: usize,
    rows: usize,
    o0: usize,
    o1: usize,
    out: &mut [f32],
    out_stride: usize,
) {
    let k = a.cols;
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + KB).min(k);
        let mut ob = o0;
        while ob < o1 {
            let oe = (ob + CB).min(o1);
            for o in ob..oe {
                let w_blk = &w.data[o * k + k0..o * k + kend];
                for t in 0..rows {
                    let a_blk = &a.data[(t0 + t) * k + k0..(t0 + t) * k + kend];
                    out[t * out_stride + (o - o0)] += dot(a_blk, w_blk);
                }
            }
            ob = oe;
        }
        k0 = kend;
    }
}

/// `c = a · wᵀ` into a caller-provided buffer (hot path: no allocation).
pub fn matmul_into(a: &Matrix, w: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, w.cols, "inner dimensions must match");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, w.rows);
    let n = w.rows;
    let rows = a.rows;
    // Ragged decode batches: a handful of activation rows against a wide
    // W. One row tile would leave all but one core idle, so
    // `par_col_blocks` splits the output columns across workers instead
    // (crossover predicate lives there). Numerics are identical to the
    // row-tiled path: both run the same `gemm_panel`.
    let c_data = &mut c.data;
    let ran = par_col_blocks(
        rows,
        n,
        TB,
        CB,
        |o0, o1| {
            let mut part = vec![0.0f32; rows * (o1 - o0)];
            gemm_panel(a, w, 0, rows, o0, o1, &mut part, o1 - o0);
            part
        },
        |o0, o1, part| {
            let bw = o1 - o0;
            for t in 0..rows {
                c_data[t * n + o0..t * n + o1].copy_from_slice(&part[t * bw..(t + 1) * bw]);
            }
        },
    );
    if ran {
        return;
    }
    // Parallelize over TB-row tiles of the output; each tile is one
    // full-width panel call.
    par_chunks_mut(c_data, TB * n, |tile, c_tile| {
        c_tile.fill(0.0);
        let rows = c_tile.len() / n;
        gemm_panel(a, w, tile * TB, rows, 0, n, c_tile, n);
    });
}

/// A packed quantized weight operand for [`matmul_q_into`]: logically a
/// `[n, k]` row-major f32 matrix, physically codes + scales that are
/// decoded one (output-row, K-block) span at a time.
///
/// Contract: `decode_row_block(o, k0, kend, dst)` must write into
/// `dst[..kend - k0]` **exactly** the f32 values a full dequantization
/// of the plane would hold at `w[o, k0..kend]` — same op order, same
/// intermediate products — so the fused GEMM stays bit-identical to
/// dequantize-then-[`matmul_into`]. Callers never pass spans wider than
/// `KB` (= 256) elements.
pub trait WeightPlane: Sync {
    /// Reduction (K) dimension — must equal `a.cols`.
    fn k(&self) -> usize;
    /// Output (N) dimension — number of weight rows.
    fn n(&self) -> usize;
    /// Decode `w[o, k0..kend]` into `dst[..kend - k0]`.
    fn decode_row_block(&self, o: usize, k0: usize, kend: usize, dst: &mut [f32]);
}

/// Every dense `Matrix` is trivially a weight plane (borrow-decode);
/// property tests use this to pin the `_q` schedule against the f32 one.
impl WeightPlane for Matrix {
    fn k(&self) -> usize {
        self.cols
    }

    fn n(&self) -> usize {
        self.rows
    }

    fn decode_row_block(&self, o: usize, k0: usize, kend: usize, dst: &mut [f32]) {
        dst.copy_from_slice(&self.data[o * self.cols + k0..o * self.cols + kend]);
    }
}

/// [`gemm_panel`] over a packed [`WeightPlane`]: identical KB/CB/TB
/// loop structure, but each W row's K-block is decoded `code · scale`
/// into a `KB`-float stack buffer (L1-resident — DRAM traffic is the
/// packed codes + scales only) immediately before the same 32-lane
/// [`dot`]. Decoding whole K-blocks (not single elements) keeps the
/// register kernel untouched, which is what makes bit-identity to the
/// dequantized path structural rather than a numerics argument.
#[inline]
fn gemm_panel_q<W: WeightPlane + ?Sized>(
    a: &Matrix,
    w: &W,
    t0: usize,
    rows: usize,
    o0: usize,
    o1: usize,
    out: &mut [f32],
    out_stride: usize,
) {
    let k = a.cols;
    let mut wbuf = [0.0f32; KB];
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + KB).min(k);
        let wlen = kend - k0;
        let mut ob = o0;
        while ob < o1 {
            let oe = (ob + CB).min(o1);
            for o in ob..oe {
                w.decode_row_block(o, k0, kend, &mut wbuf[..wlen]);
                let w_blk = &wbuf[..wlen];
                for t in 0..rows {
                    let a_blk = &a.data[(t0 + t) * k + k0..(t0 + t) * k + kend];
                    out[t * out_stride + (o - o0)] += dot(a_blk, w_blk);
                }
            }
            ob = oe;
        }
        k0 = kend;
    }
}

/// `c = a · wᵀ` against a packed quantized weight plane, fully
/// overwriting `c`. Same two parallel schedules as [`matmul_into`]
/// (column-parallel for small ragged decode batches via
/// `par_col_blocks`, TB-row tiles otherwise), both driving
/// [`gemm_panel_q`] — bit-identical to dequantizing `w` and calling
/// [`matmul_into`].
pub fn matmul_q_into<W: WeightPlane + ?Sized>(a: &Matrix, w: &W, c: &mut Matrix) {
    assert_eq!(a.cols, w.k(), "inner dimensions must match");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, w.n());
    let n = w.n();
    let rows = a.rows;
    let c_data = &mut c.data;
    let ran = par_col_blocks(
        rows,
        n,
        TB,
        CB,
        |o0, o1| {
            let mut part = vec![0.0f32; rows * (o1 - o0)];
            gemm_panel_q(a, w, 0, rows, o0, o1, &mut part, o1 - o0);
            part
        },
        |o0, o1, part| {
            let bw = o1 - o0;
            for t in 0..rows {
                c_data[t * n + o0..t * n + o1].copy_from_slice(&part[t * bw..(t + 1) * bw]);
            }
        },
    );
    if ran {
        return;
    }
    par_chunks_mut(c_data, TB * n, |tile, c_tile| {
        c_tile.fill(0.0);
        let rows = c_tile.len() / n;
        gemm_panel_q(a, w, tile * TB, rows, 0, n, c_tile, n);
    });
}

/// `c = a · wᵀ + bias` (bias broadcast over rows).
pub fn matmul_bias_into(a: &Matrix, w: &Matrix, bias: &[f32], c: &mut Matrix) {
    matmul_into(a, w, c);
    assert_eq!(bias.len(), c.cols);
    for r in 0..c.rows {
        for (c_el, b) in c.row_mut(r).iter_mut().zip(bias) {
            *c_el += *b;
        }
    }
}

/// `c = a · b` with **no** transpose: `a: [m, k]`, `b: [k, n]` → `c: [m, n]`.
///
/// The attention score·V product is exactly this shape (scores
/// `[seq, kv]` times V `[kv, dh]`), so this kernel lets attention drop
/// the per-head `v.transpose()` allocation it previously needed to feed
/// [`matmul`]'s `A · Wᵀ` convention.
pub fn matmul_nn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_nn_into(a, b, &mut c);
    c
}

/// [`matmul_nn`] into a caller-provided buffer (fully overwritten).
///
/// Row-major axpy formulation: each B row streams once per A row and
/// accumulates into the C row with unit stride (autovectorizes). Rows of
/// A that are exactly zero (masked attention scores) are skipped.
pub fn matmul_nn_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "inner dimensions must match");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let n = b.cols;
    c.data.fill(0.0);
    for t in 0..a.rows {
        let crow = &mut c.data[t * n..(t + 1) * n];
        for (r, &av) in a.row(t).iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[r * n..(r + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Unrolled dot product over equal-length slices; 32 independent
/// accumulators so LLVM emits two zmm FMA chains on AVX-512 (hides the
/// 4-cycle FMA latency; §Perf iteration 6).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    const W: usize = 32;
    let mut acc = [0.0f32; W];
    let chunks = n / W;
    for i in 0..chunks {
        let xi = &x[i * W..i * W + W];
        let yi = &y[i * W..i * W + W];
        for l in 0..W {
            acc[l] += xi[l] * yi[l];
        }
    }
    // Pairwise tree reduction keeps f32 error comparable to the 8-wide
    // version.
    let mut width = W / 2;
    while width > 0 {
        for l in 0..width {
            acc[l] += acc[l + width];
        }
        width /= 2;
    }
    let mut s = acc[0];
    for i in chunks * W..n {
        s += x[i] * y[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, w: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, w.rows);
        for t in 0..a.rows {
            for o in 0..w.rows {
                let mut s = 0.0;
                for kk in 0..a.cols {
                    s += a.at(t, kk) * w.at(o, kk);
                }
                *c.at_mut(t, o) = s;
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let w = Matrix::from_vec(4, 3, (0..12).map(|i| i as f32 * 0.5).collect());
        let c = matmul(&a, &w);
        let r = naive(&a, &w);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn matches_naive_odd_sizes() {
        // Exercises the K-block remainder and the dot() tail loop.
        let mut seed = 1u64;
        let mut next = move || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((seed >> 33) as f32 / 2.0f32.powi(31)) - 0.5
        };
        let a = Matrix::from_vec(5, 259, (0..5 * 259).map(|_| next()).collect());
        let w = Matrix::from_vec(7, 259, (0..7 * 259).map(|_| next()).collect());
        let c = matmul(&a, &w);
        let r = naive(&a, &w);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn small_batch_column_path_matches_naive() {
        // 4 rows × wide W triggers the column-parallel path (when
        // threads > 1); numerics must match the row-tiled path exactly.
        let mut seed = 3u64;
        let mut next = move || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((seed >> 33) as f32 / 2.0f32.powi(31)) - 0.5
        };
        let a = Matrix::from_vec(4, 300, (0..4 * 300).map(|_| next()).collect());
        let w = Matrix::from_vec(200, 300, (0..200 * 300).map(|_| next()).collect());
        let c = matmul(&a, &w);
        let r = naive(&a, &w);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    fn naive_nn(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for t in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for r in 0..a.cols {
                    s += a.at(t, r) * b.at(r, j);
                }
                *c.at_mut(t, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_nn_matches_naive() {
        let mut seed = 9u64;
        let mut next = move || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((seed >> 33) as f32 / 2.0f32.powi(31)) - 0.5
        };
        let a = Matrix::from_vec(5, 17, (0..5 * 17).map(|_| next()).collect());
        let b = Matrix::from_vec(17, 9, (0..17 * 9).map(|_| next()).collect());
        let c = matmul_nn(&a, &b);
        let r = naive_nn(&a, &b);
        for (x, y) in c.data.iter().zip(&r.data) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_nn_equals_transposed_matmul() {
        // The identity the attention rewrite relies on:
        // matmul_nn(s, v) == matmul(s, v.transpose()).
        let s = Matrix::from_vec(2, 3, vec![0.5, 0.0, 0.5, 1.0, 0.0, 0.0]);
        let v = Matrix::from_vec(3, 4, (0..12).map(|i| i as f32 * 0.25).collect());
        let a = matmul_nn(&s, &v);
        let b = matmul(&s, &v.transpose());
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_q_over_dense_plane_is_bit_identical() {
        // A dense Matrix is itself a WeightPlane (copy-decode), so the
        // _q schedule must reproduce matmul_into *to the bit* across
        // shapes that exercise 1-row decode, the column-parallel
        // crossover, TB straddling and the K-block remainder.
        let mut seed = 11u64;
        let mut next = move || {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((seed >> 33) as f32 / 2.0f32.powi(31)) - 0.5
        };
        for (m, k, n) in [(1, 300, 200), (4, 259, 140), (17, 64, 33), (33, 512, 130)] {
            let a = Matrix::from_vec(m, k, (0..m * k).map(|_| next()).collect());
            let w = Matrix::from_vec(n, k, (0..n * k).map(|_| next()).collect());
            let mut c_f32 = Matrix::zeros(m, n);
            matmul_into(&a, &w, &mut c_f32);
            let mut c_q = Matrix::zeros(m, n);
            matmul_q_into(&a, &w, &mut c_q);
            for (x, y) in c_q.data.iter().zip(&c_f32.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "{m}x{k}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn bias_is_broadcast() {
        let a = Matrix::from_vec(2, 2, vec![1., 0., 0., 1.]);
        let w = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let mut c = Matrix::zeros(2, 2);
        matmul_bias_into(&a, &w, &[10.0, 20.0], &mut c);
        assert_eq!(c.data, vec![11., 23., 12., 24.]);
    }
}
