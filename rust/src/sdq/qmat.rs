//! Packed quantized weight plane: the serving-time storage for the
//! dense residual of an SDQ layer (and for quant-only layers).
//!
//! [`crate::sdq::pipeline::compress_layer`] historically *fake*-
//! quantized weights — snapped to the target grid but stored as f32
//! [`Matrix`] — so the decode hot path streamed 4 bytes per weight and
//! the paper's memory win existed only on paper. [`QuantMat`] stores
//! the real thing:
//!
//! * **codes** — one `i8` per element for int5..int8, or two
//!   sign-magnitude / two's-complement nibbles per byte for
//!   fp4-e2m1 / int2..int4 ([`NumFormat::packed_code_bits`]);
//! * **per-(row, Q-vector) scales** — the VS-Quant first level, stored
//!   as real fp8-e4m3 *bytes* when every ratio round-trips the
//!   [`crate::kv::fp8_e4m3_encode`] codec exactly (it always does when
//!   `scale_fmt = fp8-e4m3`, the default: quantized ratios already live
//!   on that grid), f32 otherwise;
//! * **per-row f32 channel scales** — the VS-Quant second level.
//!
//! At int8 that is `cols + cols/qvec + 4` bytes per row against
//! `4·cols` dense — ~3.76× fewer bytes streamed per decode round
//! (fp4 ≈ 6.9×) — and [`Metrics`](crate::coordinator::metrics::Metrics)
//! accounts it via [`QuantMat::packed_bytes`].
//!
//! # Bit-identity
//!
//! `QuantMat` implements [`WeightPlane`], so
//! [`crate::tensor::matmul_q_into`] can fuse the dequant into the GEMM
//! micro-tile. The decode replays
//! [`QuantizedTensor::dequantize`]'s per-element op order exactly —
//! `s = vec_scale · chan_scale` (one multiply, per Q-vector group),
//! then `w = code · s`, groups walked in ascending k — so the fused
//! route equals dequantize-then-`matmul_into` **to the bit**
//! (`tests/qmat.rs` pins it across ragged tile shapes). Construction is
//! from the [`QuantizedTensor`] the pipeline already produces: codes
//! are exact small integers / fp4 grid points, so the i8 / nibble
//! round-trip is lossless by construction (checked in debug builds).
//!
//! One deliberate asymmetry: an integer code of `-0.0` (RNE of a small
//! negative value) decodes as `+0.0` from the i8 plane. The product
//! `code · s` then differs only in zero sign, which IEEE-754 addition
//! absorbs (`+0.0 + -0.0 = +0.0`, and an accumulator that starts at
//! `+0.0` can never become `-0.0`), so GEMM outputs remain
//! bit-identical. The fp4 nibble is sign-magnitude and preserves even
//! `-0.0`.

use crate::formats::{NumFormat, FP4_GRID};
use crate::kv::{fp8_e4m3_decode, fp8_e4m3_encode};
use crate::tensor::{Matrix, WeightPlane};

use super::quantize::QuantizedTensor;

/// Physical code storage: one byte per code, or two nibbles per byte
/// with per-row stride `cols.div_ceil(2)` (rows never share a byte).
#[derive(Clone, Debug)]
enum CodePlane {
    /// int5..int8 codes, two's complement, stride `cols`.
    I8(Vec<i8>),
    /// fp4-e2m1 (sign-magnitude: bit 3 sign, bits 0..2 index into
    /// [`FP4_GRID`]) or int2..int4 (two's-complement nibble). Element
    /// `i` of a row lives in byte `i / 2`: low nibble for even `i`,
    /// high for odd.
    Nibble(Vec<u8>),
}

/// First-level (per-row, per-Q-vector) scale storage.
#[derive(Clone, Debug)]
enum ScalePlane {
    /// Real fp8-e4m3 bytes; decode is the exact [`fp8_e4m3_decode`].
    Fp8(Vec<u8>),
    /// Fallback when some ratio is not fp8-e4m3-exact (non-default
    /// `scale_fmt`, or underflow below the e4m3 subnormal floor).
    F32(Vec<f32>),
}

/// A packed quantized `[rows, cols]` weight matrix (VS-Quant two-level
/// scaling), logically equal to `QuantizedTensor::dequantize()` of the
/// tensor it was built from — see the module docs for the layout and
/// the bit-identity contract.
#[derive(Clone, Debug)]
pub struct QuantMat {
    fmt: NumFormat,
    rows: usize,
    cols: usize,
    qvec: usize,
    codes: CodePlane,
    vec_scales: ScalePlane,
    chan_scales: Vec<f32>,
}

impl QuantMat {
    /// Pack a [`QuantizedTensor`], or `None` when its value format has
    /// no packed representation ([`NumFormat::packed_code_bits`]).
    pub fn try_from_tensor(qt: &QuantizedTensor) -> Option<QuantMat> {
        let bits = qt.cfg.fmt.packed_code_bits()?;
        let (rows, cols) = (qt.rows, qt.cols);
        let codes = match (bits, qt.cfg.fmt) {
            (4, NumFormat::Fp4E2M1) => {
                let stride = cols.div_ceil(2);
                let mut nib = vec![0u8; rows * stride];
                for r in 0..rows {
                    for i in 0..cols {
                        let c = qt.codes[r * cols + i];
                        let n = fp4_encode_nibble(c);
                        debug_assert_eq!(
                            fp4_decode_nibble(n).to_bits(),
                            c.to_bits(),
                            "fp4 code {c} not nibble-exact"
                        );
                        nib[r * stride + i / 2] |= n << (4 * (i % 2));
                    }
                }
                CodePlane::Nibble(nib)
            }
            (4, _) => {
                let stride = cols.div_ceil(2);
                let mut nib = vec![0u8; rows * stride];
                for r in 0..rows {
                    for i in 0..cols {
                        let c = qt.codes[r * cols + i];
                        debug_assert!((-8.0..=7.0).contains(&c), "int4 code {c} out of range");
                        let n = (c as i8 as u8) & 0x0f;
                        nib[r * stride + i / 2] |= n << (4 * (i % 2));
                    }
                }
                CodePlane::Nibble(nib)
            }
            _ => {
                let mut i8s = vec![0i8; rows * cols];
                for (dst, c) in i8s.iter_mut().zip(&qt.codes) {
                    debug_assert!((-128.0..=127.0).contains(c), "int8 code {c} out of range");
                    *dst = *c as i8;
                }
                CodePlane::I8(i8s)
            }
        };
        // Scales go to 1-byte fp8-e4m3 only when *every* ratio survives
        // the codec bit-exactly — anything less would break the
        // bit-identity contract for a 3-byte-per-row saving.
        let exact = qt.vec_scales.iter().all(|s| {
            fp8_e4m3_decode(fp8_e4m3_encode(*s)).to_bits() == s.to_bits()
        });
        let vec_scales = if exact {
            ScalePlane::Fp8(qt.vec_scales.iter().map(|s| fp8_e4m3_encode(*s)).collect())
        } else {
            ScalePlane::F32(qt.vec_scales.clone())
        };
        Some(QuantMat {
            fmt: qt.cfg.fmt,
            rows,
            cols,
            qvec: qt.cfg.qvec,
            codes,
            vec_scales,
            chan_scales: qt.chan_scales.clone(),
        })
    }

    /// Value format of the codes.
    pub fn fmt(&self) -> NumFormat {
        self.fmt
    }

    /// Output rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Input (reduction) columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Q-vector (scale group) size along the reduction dimension.
    pub fn qvec(&self) -> usize {
        self.qvec
    }

    /// Whether the first-level scales are stored as 1-byte fp8-e4m3.
    pub fn scales_are_fp8(&self) -> bool {
        matches!(self.vec_scales, ScalePlane::Fp8(_))
    }

    /// Q-vectors per row.
    fn qvecs_per_row(&self) -> usize {
        self.cols.div_ceil(self.qvec)
    }

    /// Actual bytes of packed storage (codes + vec scales + channel
    /// scales) — what one full weight stream through the fused GEMM
    /// reads from memory, and what honest weight-size accounting
    /// reports.
    pub fn packed_bytes(&self) -> usize {
        let code_bytes = match &self.codes {
            CodePlane::I8(v) => v.len(),
            CodePlane::Nibble(v) => v.len(),
        };
        let scale_bytes = match &self.vec_scales {
            ScalePlane::Fp8(v) => v.len(),
            ScalePlane::F32(v) => 4 * v.len(),
        };
        code_bytes + scale_bytes + 4 * self.chan_scales.len()
    }

    /// First-level scale for (row, Q-vector) — exactly the f32 the
    /// source tensor held (fp8 plane: the byte decodes back to it).
    #[inline]
    fn vec_scale(&self, r: usize, q: usize) -> f32 {
        let idx = r * self.qvecs_per_row() + q;
        match &self.vec_scales {
            ScalePlane::Fp8(v) => fp8_e4m3_decode(v[idx]),
            ScalePlane::F32(v) => v[idx],
        }
    }

    /// Code `w[r, i]` as the f32 the source tensor's `codes` held
    /// (up to integer zero sign — see module docs).
    #[inline]
    fn code(&self, r: usize, i: usize) -> f32 {
        match &self.codes {
            CodePlane::I8(v) => v[r * self.cols + i] as f32,
            CodePlane::Nibble(v) => {
                let stride = self.cols.div_ceil(2);
                let byte = v[r * stride + i / 2];
                let n = (byte >> (4 * (i % 2))) & 0x0f;
                if self.fmt == NumFormat::Fp4E2M1 {
                    fp4_decode_nibble(n)
                } else {
                    // sign-extend the two's-complement nibble
                    (((n << 4) as i8) >> 4) as f32
                }
            }
        }
    }

    /// Dequantize to a dense matrix (eval paths, tests). Same op order
    /// as [`QuantizedTensor::dequantize`].
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row = out.row_mut(r);
            self.decode_row_span(r, 0, self.cols, row);
        }
        out
    }

    /// Decode `w[r, k0..kend]` into `dst[..kend - k0]` — the
    /// [`WeightPlane`] workhorse. Walks Q-vector groups in ascending k,
    /// computing `s = vec_scale · chan_scale` once per group and
    /// `w = code · s` per element: the dequant path's exact op order.
    #[inline]
    fn decode_row_span(&self, r: usize, k0: usize, kend: usize, dst: &mut [f32]) {
        let chan = self.chan_scales[r];
        let mut i = k0;
        let mut d = 0;
        while i < kend {
            let q = i / self.qvec;
            let gend = ((q + 1) * self.qvec).min(kend);
            let s = self.vec_scale(r, q) * chan;
            for ii in i..gend {
                dst[d] = self.code(r, ii) * s;
                d += 1;
            }
            i = gend;
        }
    }
}

impl WeightPlane for QuantMat {
    fn k(&self) -> usize {
        self.cols
    }

    fn n(&self) -> usize {
        self.rows
    }

    fn decode_row_block(&self, o: usize, k0: usize, kend: usize, dst: &mut [f32]) {
        self.decode_row_span(o, k0, kend, dst);
    }
}

/// Encode an fp4-e2m1 grid value to a sign-magnitude nibble. The value
/// must be a grid point (codes out of the quantizer always are).
#[inline]
fn fp4_encode_nibble(c: f32) -> u8 {
    let sign = if c.is_sign_negative() { 8u8 } else { 0 };
    let a = c.abs();
    // 8-entry grid: a comparison scan is exact and branch-predictable.
    let mut m = 0u8;
    for (i, g) in FP4_GRID.iter().enumerate() {
        if a == *g {
            m = i as u8;
            break;
        }
    }
    debug_assert!(FP4_GRID.contains(&a), "fp4 code {c} off-grid");
    sign | m
}

/// Decode a sign-magnitude fp4 nibble back to its f32 grid value
/// (preserves `-0.0`, keeping the nibble round-trip fully lossless).
#[inline]
fn fp4_decode_nibble(n: u8) -> f32 {
    let v = FP4_GRID[(n & 7) as usize];
    if n & 8 != 0 {
        -v
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdq::quantize::{quantize_tensor, VsQuantCfg};
    use crate::util::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from_u64(seed);
        Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.range_f32(-2.0, 2.0)).collect())
    }

    fn cfg(fmt: NumFormat, qvec: usize) -> VsQuantCfg {
        VsQuantCfg { fmt, qvec, scale_fmt: NumFormat::Fp8E4M3 }
    }

    #[test]
    fn fp4_nibble_codec_roundtrips_the_whole_grid() {
        for g in FP4_GRID {
            for v in [g, -g] {
                let n = fp4_encode_nibble(v);
                assert!(n < 16);
                assert_eq!(fp4_decode_nibble(n).to_bits(), v.to_bits(), "{v}");
            }
        }
    }

    #[test]
    fn dequantize_matches_source_tensor() {
        for fmt in [NumFormat::Int(8), NumFormat::Int(4), NumFormat::Fp4E2M1] {
            // K deliberately not a multiple of qvec (ragged last group).
            let w = rand_matrix(9, 53, 7);
            let qt = quantize_tensor(&w, cfg(fmt, 16));
            let qm = QuantMat::try_from_tensor(&qt).unwrap();
            let a = qm.dequantize();
            let b = qt.dequantize();
            for (x, y) in a.data.iter().zip(&b.data) {
                // `==` not to_bits: an integer code of -0.0 decodes +0.0
                // from the i8 plane (harmless for GEMM — module docs).
                assert_eq!(*x, *y, "{fmt}");
            }
        }
    }

    #[test]
    fn unpackable_formats_return_none() {
        let w = rand_matrix(4, 32, 9);
        for fmt in [NumFormat::Fp8E4M3, NumFormat::Fp16, NumFormat::Fp32] {
            let qt = quantize_tensor(&w, cfg(fmt, 16));
            assert!(QuantMat::try_from_tensor(&qt).is_none(), "{fmt}");
        }
    }

    #[test]
    fn default_scale_fmt_packs_scales_to_one_byte() {
        let w = rand_matrix(8, 64, 11);
        let qm =
            QuantMat::try_from_tensor(&quantize_tensor(&w, cfg(NumFormat::Int(8), 16))).unwrap();
        assert!(qm.scales_are_fp8());
        // int8: 1 B/code + 1 B per 16-element group + 4 B/row.
        assert_eq!(qm.packed_bytes(), 8 * 64 + 8 * 4 + 8 * 4);
        let dense = 4 * 8 * 64;
        assert!(dense as f64 / qm.packed_bytes() as f64 > 3.5);
    }

    #[test]
    fn nibble_plane_halves_code_bytes_and_handles_odd_cols() {
        let w = rand_matrix(5, 33, 13);
        let qm =
            QuantMat::try_from_tensor(&quantize_tensor(&w, cfg(NumFormat::Fp4E2M1, 16))).unwrap();
        // 33 cols → 17 bytes/row of codes, 3 scale bytes, 4 B channel.
        assert_eq!(qm.packed_bytes(), 5 * (17 + 3 + 4));
        assert!(qm.scales_are_fp8());
    }

    #[test]
    fn non_e4m3_scale_fmt_falls_back_to_f32_scales_exactly() {
        let w = rand_matrix(6, 48, 17);
        let qt = quantize_tensor(
            &w,
            VsQuantCfg { fmt: NumFormat::Int(4), qvec: 16, scale_fmt: NumFormat::Fp32 },
        );
        let qm = QuantMat::try_from_tensor(&qt).unwrap();
        // Raw fp32 ratios are generally not on the e4m3 grid → F32 plane,
        // and the dequantized view still matches bit-for-bit.
        let a = qm.dequantize();
        let b = qt.dequantize();
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(*x, *y);
        }
    }

    #[test]
    fn fused_gemm_is_bit_identical_to_dequantized_gemm() {
        use crate::tensor::{matmul_into, matmul_q_into};
        // Ragged shapes: 1-row decode, TB straddling (rows > 16),
        // K not a multiple of qvec, K crossing the KB=256 boundary.
        for (t, k, n, fmt) in [
            (1usize, 300usize, 96usize, NumFormat::Int(8)),
            (17, 72, 40, NumFormat::Fp4E2M1),
            (4, 53, 33, NumFormat::Int(4)),
        ] {
            let x = rand_matrix(t, k, 19 + t as u64);
            let w = rand_matrix(n, k, 23 + k as u64);
            let qt = quantize_tensor(&w, cfg(fmt, 16));
            let qm = QuantMat::try_from_tensor(&qt).unwrap();
            let deq = qt.dequantize();
            let mut c_ref = Matrix::zeros(t, n);
            matmul_into(&x, &deq, &mut c_ref);
            let mut c_q = Matrix::zeros(t, n);
            matmul_q_into(&x, &qm, &mut c_q);
            for (a, b) in c_q.data.iter().zip(&c_ref.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{fmt} {t}x{k}x{n}");
            }
        }
    }
}
