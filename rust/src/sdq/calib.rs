//! Calibration pipeline: per-layer input-activation statistics.
//!
//! Wanda and the product-based decomposition metric need per-input-column
//! activation norms ‖X_j‖₂; SparseGPT needs the Gram/Hessian `XᵀX`. Both
//! are accumulated streamingly while running the model over a calibration
//! set (§5 Stage 1: "if using calibration data is allowed").

use std::collections::HashMap;

use super::linalg::SquareMat;
use crate::tensor::Matrix;

/// Streaming statistics for one linear layer's *input* activations.
#[derive(Clone, Debug)]
pub struct LayerStats {
    pub in_features: usize,
    /// Σ_t x²_{t,j} per input column (f64 accumulation).
    pub col_sq_sum: Vec<f64>,
    /// Gram matrix XᵀX (only when Hessian collection is enabled).
    pub gram: Option<SquareMat>,
    /// Tokens accumulated.
    pub tokens: usize,
}

impl LayerStats {
    fn new(in_features: usize, with_gram: bool) -> Self {
        LayerStats {
            in_features,
            col_sq_sum: vec![0.0; in_features],
            gram: with_gram.then(|| SquareMat::zeros(in_features)),
            tokens: 0,
        }
    }

    /// Accumulate a `[tokens, in_features]` activation batch.
    pub fn update(&mut self, x: &Matrix) {
        assert_eq!(x.cols, self.in_features);
        for t in 0..x.rows {
            let row = x.row(t);
            for (j, v) in row.iter().enumerate() {
                self.col_sq_sum[j] += (*v as f64) * (*v as f64);
            }
        }
        if let Some(g) = &mut self.gram {
            let d = self.in_features;
            for t in 0..x.rows {
                let row = x.row(t);
                // Symmetric rank-1 update; upper triangle only, mirrored.
                for i in 0..d {
                    let xi = row[i] as f64;
                    if xi == 0.0 {
                        continue;
                    }
                    let gi = &mut g.data[i * d..(i + 1) * d];
                    for (j, gj) in gi.iter_mut().enumerate().skip(i) {
                        *gj += xi * row[j] as f64;
                    }
                }
            }
        }
        self.tokens += x.rows;
    }

    /// ‖X_j‖₂ per column (the Wanda norm).
    pub fn col_norms(&self) -> Vec<f32> {
        self.col_sq_sum.iter().map(|s| (s.sqrt()) as f32).collect()
    }

    /// Finalized symmetric Gram matrix (mirrors the upper triangle down).
    pub fn finalized_gram(&self) -> Option<SquareMat> {
        let g = self.gram.as_ref()?;
        let d = self.in_features;
        let mut out = g.clone();
        for i in 0..d {
            for j in 0..i {
                out.data[i * d + j] = g.data[j * d + i];
            }
        }
        Some(out)
    }
}

/// Calibration statistics for every linear layer of a model, keyed by a
/// stable layer name (e.g. `block3.attn.q`).
#[derive(Clone, Debug, Default)]
pub struct CalibStats {
    pub layers: HashMap<String, LayerStats>,
    /// Whether Gram matrices are being collected.
    pub with_gram: bool,
}

impl CalibStats {
    /// New collector; `with_gram` enables Hessian accumulation (needed by
    /// SparseGPT; costs O(d²) memory per layer).
    pub fn new(with_gram: bool) -> Self {
        CalibStats { layers: HashMap::new(), with_gram }
    }

    /// Record a batch of input activations for `layer`.
    pub fn observe(&mut self, layer: &str, x: &Matrix) {
        let with_gram = self.with_gram;
        self.layers
            .entry(layer.to_string())
            .or_insert_with(|| LayerStats::new(x.cols, with_gram))
            .update(x);
    }

    /// Look up a layer's stats.
    pub fn get(&self, layer: &str) -> Option<&LayerStats> {
        self.layers.get(layer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn col_norms_accumulate_across_batches() {
        let mut st = CalibStats::new(false);
        st.observe("l", &Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 1.0]));
        st.observe("l", &Matrix::from_vec(1, 2, vec![0.0, 2.0]));
        let n = st.get("l").unwrap().col_norms();
        assert!((n[0] - 5.0).abs() < 1e-6); // sqrt(9+16)
        assert!((n[1] - (5.0f32).sqrt()).abs() < 1e-6); // sqrt(1+4)
        assert_eq!(st.get("l").unwrap().tokens, 3);
    }

    #[test]
    fn gram_matches_xtx() {
        let mut st = CalibStats::new(true);
        let x = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        st.observe("l", &x);
        let g = st.get("l").unwrap().finalized_gram().unwrap();
        // XᵀX = [[35, 44], [44, 56]]
        assert_eq!(g.at(0, 0), 35.0);
        assert_eq!(g.at(0, 1), 44.0);
        assert_eq!(g.at(1, 0), 44.0);
        assert_eq!(g.at(1, 1), 56.0);
    }

    #[test]
    fn no_gram_when_disabled() {
        let mut st = CalibStats::new(false);
        st.observe("l", &Matrix::zeros(1, 4));
        assert!(st.get("l").unwrap().finalized_gram().is_none());
    }
}
