"""L1 Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Includes hypothesis sweeps over shapes, Q-vector sizes and formats, as
mandated for the kernel layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels import sdq_matmul as K


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _sdq_operands(rng, o, k, qvec, n_out=1, m=8):
    w = _rand(rng, o, k)
    wo, wi = ref.decompose_local_outliers(w, n_out, m)
    woc, wos = ref.quantize_weight_codes(wo, "int8", qvec)
    wic, wis = ref.quantize_weight_codes(wi, "fp4", qvec)
    return woc, wos, wic, wis


def test_sdq_matmul_matches_ref_basic():
    rng = np.random.default_rng(0)
    t, k, o, qv = 64, 256, 128, 16
    x = _rand(rng, t, k)
    ops = _sdq_operands(rng, o, k, qv)
    y_ref = ref.sdq_matmul_ref(x, *ops, qvec=qv)
    y_ker = K.sdq_matmul(x, *ops, qvec=qv)
    np.testing.assert_allclose(y_ker, y_ref, atol=2e-4, rtol=1e-4)


@given(
    t=st.sampled_from([8, 16, 48, 64]),
    k=st.sampled_from([64, 128, 192, 256]),
    o=st.sampled_from([16, 64, 96]),
    qvec=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=20, deadline=None)
def test_sdq_matmul_shape_sweep(t, k, o, qvec, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, t, k)
    ops = _sdq_operands(rng, o, k, qvec)
    y_ref = ref.sdq_matmul_ref(x, *ops, qvec=qvec)
    y_ker = K.sdq_matmul(x, *ops, qvec=qvec)
    np.testing.assert_allclose(y_ker, y_ref, atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("fmt", ["int8", "fp8-e4m3", "fp4", "int4"])
def test_dual_quant_matmul_formats(fmt):
    rng = np.random.default_rng(1)
    t, k, o, qv = 32, 128, 64, 16
    x = _rand(rng, t, k)
    w = _rand(rng, o, k)
    wc, ws = ref.quantize_weight_codes(w, fmt, qv)
    y_ref = ref.dual_quant_matmul_ref(x, wc, ws, qvec=qv, fmt=fmt)
    y_ker = K.dual_quant_matmul(x, wc, ws, qvec=qv, fmt=fmt)
    np.testing.assert_allclose(y_ker, y_ref, atol=2e-4, rtol=1e-4)


@given(
    n=st.sampled_from([1, 2, 4]),
    m=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=12, deadline=None)
def test_nm_spmm_matches_dense(n, m, seed):
    rng = np.random.default_rng(seed)
    t, k, o = 16, 128, 32
    w = _rand(rng, o, k)
    mask = ref.nm_mask(w, n, m)
    ws = jnp.where(mask, w, 0.0)
    vals, idx = K.pack_nm(ws, n, m)
    y = K.nm_spmm(vals, idx, x=_rand(rng, t, k), n=n, m=m, k=k)
    # recompute with same x — regenerate rng stream deterministically
    rng2 = np.random.default_rng(seed)
    _ = _rand(rng2, o, k)
    x = _rand(rng2, t, k)
    np.testing.assert_allclose(y, x @ ws.T, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("fmt,qvec", [("int8", 16), ("fp4", 16), ("int8", 32), ("fp4", 8)])
def test_act_quantize_kernel(fmt, qvec):
    rng = np.random.default_rng(3)
    x = _rand(rng, 32, 128)
    q_ref = ref.act_quant(x, fmt, qvec)
    q_ker = K.act_quantize(x, qvec=qvec, fmt=fmt)
    # Same math, but XLA fuses the scale multiply differently inside the
    # kernel → ≤1-ulp differences.
    np.testing.assert_allclose(np.asarray(q_ker), np.asarray(q_ref), atol=1e-6)


def test_decomposition_partition_properties():
    rng = np.random.default_rng(4)
    w = _rand(rng, 32, 64)
    wo, wi = ref.decompose_local_outliers(w, 2, 8)
    np.testing.assert_array_equal(np.asarray(wo + wi), np.asarray(w))
    # disjoint support
    assert not np.any((np.asarray(wo) != 0) & (np.asarray(wi) != 0))
    # outlier pattern: ≤2 nnz per 8-block
    g = (np.asarray(wo) != 0).reshape(32, 8, 8).sum(-1)
    assert g.max() <= 2
    # outliers are the block-max magnitudes
    assert np.abs(np.asarray(wo)).max() == np.abs(np.asarray(w)).max()


def test_sdq_reconstruction_beats_fp4_on_outliers():
    """The paper's core claim at tensor level: decompose-then-quantize
    reconstructs outlier-heavy weights better than plain fp4 VS-Quant."""
    rng = np.random.default_rng(5)
    w = np.array(_rand(rng, 64, 256))  # writable copy
    idx = rng.choice(w.size, size=w.size // 100, replace=False)
    w.flat[idx] *= 8.0  # inject ~1% outliers
    w = jnp.asarray(w)

    fp4_only = ref.weight_fake_quant(w, "fp4", 16)
    wo, wi = ref.decompose_local_outliers(w, 1, 8)
    sdq = ref.weight_fake_quant(wo, "int8", 16) + ref.weight_fake_quant(wi, "fp4", 16)

    err_fp4 = float(jnp.mean((fp4_only - w) ** 2))
    err_sdq = float(jnp.mean((sdq - w) ** 2))
    assert err_sdq < err_fp4, f"sdq {err_sdq} should beat fp4 {err_fp4}"


def test_weight_fake_quant_scale_formats():
    """Fig. 11 direction: ufp8-e6m2 scales hurt vs fp8-e4m3."""
    rng = np.random.default_rng(6)
    w = _rand(rng, 64, 256)
    a = ref.weight_fake_quant(w, "fp4", 16, scale_fmt="fp8-e4m3")
    b = ref.weight_fake_quant(w, "fp4", 16, scale_fmt="ufp8-e6m2")
    err_a = float(jnp.mean((a - w) ** 2))
    err_b = float(jnp.mean((b - w) ** 2))
    assert err_a < err_b
