//! KV-cached incremental decoding (the serving path).
//!
//! Two entry points share one attention substrate
//! ([`Model::attention_kv`], which borrows K/V straight from the cache —
//! no per-token copies):
//!
//! * [`Model::forward_cached`] — one sequence, any number of new tokens
//!   (prefill and single-stream decode);
//! * [`Model::decode_step`] — the ragged-batched decode hot path: one
//!   fused GEMM per linear layer per round across every active
//!   sequence, then per-sequence attention against heterogeneous KV
//!   prefixes.
//!
//! Both produce bit-identical logits per sequence: the GEMM kernels,
//! activation quantizers and norms are all row-independent, so stacking
//! activations only changes *when* weights stream, not what each row
//! computes.

use crate::util::rng::Rng;

use super::forward::{KvSegs, SeqKv};
use super::ops::*;
use super::{Arch, Model, ModelConfig};
use crate::data::embed;
use crate::tensor::{matmul, Matrix};

/// Tokens per KV-cache allocation chunk. Caches grow on demand in
/// `KV_CHUNK_TOKENS`-token steps instead of reserving `max_seq` rows up
/// front, so thousands of short requests only pay for the prefix they
/// actually hold and [`KvCache::bytes`] reports true residency.
pub const KV_CHUNK_TOKENS: usize = 16;

/// Per-request KV cache: one flat K and one flat V buffer per layer
/// (`len` rows of `d` floats valid), grown chunk-on-demand. K is stored
/// pre-RoPE; rotation is applied at attention time from absolute
/// positions (keeps the cache layout format-agnostic).
#[derive(Clone, Debug)]
pub struct KvCache {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Committed token count. Rows staged beyond `len` exist only while
    /// a forward step is in flight (each layer appends before attention,
    /// the step commits at the end).
    pub len: usize,
    max_seq: usize,
    d: usize,
}

impl KvCache {
    pub fn new(model: &Model) -> Self {
        KvCache {
            k: vec![Vec::new(); model.cfg.n_layer],
            v: vec![Vec::new(); model.cfg.n_layer],
            len: 0,
            max_seq: model.cfg.max_seq,
            d: model.cfg.d_model,
        }
    }

    /// Remaining capacity in tokens.
    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    /// Actual resident bytes — allocated chunks only, **not** a
    /// `max_seq` worst case. The coordinator's admission control budgets
    /// against this.
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|b| b.capacity() * 4).sum()
    }

    /// Bytes a cache will have resident once it holds `tokens` tokens —
    /// the coordinator's projected-growth estimate. Mirrors the actual
    /// growth policy (chunk-quantized geometric doubling), so a cache's
    /// [`Self::bytes`] never exceeds the projection for its final
    /// length.
    pub fn bytes_for_tokens(cfg: &ModelConfig, tokens: usize) -> usize {
        let chunks = tokens.div_ceil(KV_CHUNK_TOKENS).max(1).next_power_of_two();
        cfg.n_layer * 2 * chunks * KV_CHUNK_TOKENS * cfg.d_model * 4
    }

    /// Valid K rows for layer `li`, flat `[rows * d]` (committed plus
    /// any rows staged by the in-flight step). Borrow this — never copy.
    pub fn k_rows(&self, li: usize) -> &[f32] {
        &self.k[li]
    }

    /// Valid V rows for layer `li` (see [`Self::k_rows`]).
    pub fn v_rows(&self, li: usize) -> &[f32] {
        &self.v[li]
    }

    /// Stage one K/V row for layer `li`, growing chunk-wise.
    fn append_row(&mut self, li: usize, k_row: &[f32], v_row: &[f32]) {
        Self::push_chunked(&mut self.k[li], k_row, self.d);
        Self::push_chunked(&mut self.v[li], v_row, self.d);
    }

    fn push_chunked(buf: &mut Vec<f32>, row: &[f32], d: usize) {
        debug_assert_eq!(row.len(), d);
        if buf.len() + d > buf.capacity() {
            // Geometric growth rounded to whole chunks: amortized O(1)
            // copying (a fixed chunk increment would memcpy the whole
            // buffer at every boundary) while `bytes()` stays
            // chunk-quantized.
            let chunk = KV_CHUNK_TOKENS * d;
            let want = (buf.capacity() * 2).max(buf.len() + d);
            let aligned = want.div_ceil(chunk) * chunk;
            buf.reserve_exact(aligned - buf.len());
        }
        buf.extend_from_slice(row);
    }
}

/// The greedy token for row `row` of `logits`: first-index argmax
/// (strict `>`, ties keep the lowest token id). This is **the** greedy
/// rule — [`Model::sample_row`] at temperature 0, the drafters, and the
/// speculative acceptance engine all share it, so "greedy-exact match"
/// means one thing everywhere.
pub fn greedy_row(logits: &Matrix, row: usize) -> u8 {
    let row = logits.row(row);
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, v) in row.iter().enumerate() {
        if *v > bv {
            bv = *v;
            best = i;
        }
    }
    best as u8
}

/// Invert the cumulative distribution of the (unnormalized) mass
/// vector `probs` at `u ∈ [0, Σprobs]`.
///
/// Float rounding can leave `u > 0` after the full scan — e.g. `u`
/// drawn exactly at the sum while the running subtraction rounds low —
/// so the fallback is the **last index with nonzero mass**: the token
/// an exact CDF inversion would assign that boundary to, never an
/// arbitrary out-of-distribution constant.
fn pick_from_probs(probs: &[f32], mut u: f32) -> u8 {
    let mut last = 0usize;
    for (i, p) in probs.iter().enumerate() {
        if *p > 0.0 {
            u -= p;
            if u <= 0.0 {
                return i as u8;
            }
            last = i;
        }
    }
    last as u8
}

impl Model {
    /// Process `tokens` (one sequence) on top of `cache`, appending to
    /// it. Returns logits `[tokens.len(), vocab]`.
    ///
    /// This is the one-sequence special case of [`Self::decode_step`]'s
    /// machinery (same attention substrate, same cache layout) that also
    /// handles multi-token prefill.
    pub fn forward_cached(&self, tokens: &[u8], cache: &mut KvCache) -> Matrix {
        let n = tokens.len();
        let past = cache.len;
        assert!(past + n <= self.cfg.max_seq, "KV cache overflow");
        let d = self.cfg.d_model;
        let mut x = embed(tokens, &self.tok_emb);
        if let Some(pe) = &self.pos_emb {
            for i in 0..n {
                let row = x.row_mut(i);
                for (v, p) in row.iter_mut().zip(pe.row(past + i)) {
                    *v += *p;
                }
            }
        }
        for (li, blk) in self.blocks.iter().enumerate() {
            let mut h = x.clone();
            self.norm1(blk, &mut h);
            let mut q = Matrix::zeros(n, d);
            let mut k_new = Matrix::zeros(n, d);
            let mut v_new = Matrix::zeros(n, d);
            blk.q.lin.forward_into(&h, &mut q);
            blk.k.lin.forward_into(&h, &mut k_new);
            blk.v.lin.forward_into(&h, &mut v_new);
            for i in 0..n {
                cache.append_row(li, k_new.row(i), v_new.row(i));
            }
            // Attention borrows the cache prefix in place (one flat
            // segment — the paged pool passes one segment per block).
            let attn = {
                let seq = [SeqKv {
                    q_row0: 0,
                    n_new: n,
                    past,
                    segs: KvSegs::F32 {
                        k: vec![cache.k_rows(li)],
                        v: vec![cache.v_rows(li)],
                    },
                    seg_tokens: past + n,
                }];
                self.attention_kv(&q, &seq)
            };
            let mut o_out = Matrix::zeros(n, d);
            blk.o.lin.forward_into(&attn, &mut o_out);
            add_inplace(&mut x, &o_out);

            let mut h = x.clone();
            self.norm2(blk, &mut h);
            let mut a = Matrix::zeros(n, self.cfg.d_ff);
            blk.ff1.lin.forward_into(&h, &mut a);
            match self.cfg.arch {
                Arch::Gpt => map_inplace(&mut a, gelu),
                Arch::Llama => {
                    let ff3 = blk.ff3.as_ref().expect("llama gate");
                    let mut g = Matrix::zeros(h.rows, self.cfg.d_ff);
                    ff3.lin.forward_into(&h, &mut g);
                    map_inplace(&mut a, silu);
                    mul_inplace(&mut a, &g);
                }
            }
            let mut m_out = Matrix::zeros(n, d);
            blk.ff2.lin.forward_into(&a, &mut m_out);
            add_inplace(&mut x, &m_out);
        }
        cache.len += n;
        match self.cfg.arch {
            Arch::Gpt => layernorm(&mut x, &self.lnf_g, self.lnf_b.as_deref(), self.cfg.eps),
            Arch::Llama => rmsnorm(&mut x, &self.lnf_g, self.cfg.eps),
        }
        matmul(&x, &self.tok_emb)
    }

    /// Ragged-batched decode: advance **every** active sequence by one
    /// token in a single fused pass. `last_tokens[i]` is sequence `i`'s
    /// most recent token and `caches[i]` its KV cache — heterogeneous
    /// prefix lengths are expected. Each linear layer runs **one**
    /// `forward_into` over the stacked `[n_active, d]` activations, so
    /// the (compressed) weight stream is amortized across the whole
    /// batch instead of re-read once per sequence; attention then runs
    /// per `(sequence, head)` against each sequence's own prefix.
    ///
    /// Returns next-token logits `[n_active, vocab]` (row `i` for
    /// sequence `i`), bit-identical to what `forward_cached(&[tok], c)`
    /// would produce sequence by sequence.
    pub fn decode_step(&self, last_tokens: &[u8], caches: &mut [&mut KvCache]) -> Matrix {
        let n = last_tokens.len();
        assert_eq!(n, caches.len(), "one cache per sequence");
        assert!(n > 0, "decode_step needs at least one sequence");
        for c in caches.iter() {
            assert!(c.len < self.cfg.max_seq, "KV cache overflow");
        }
        let d = self.cfg.d_model;
        let mut x = embed(last_tokens, &self.tok_emb);
        if let Some(pe) = &self.pos_emb {
            for (i, c) in caches.iter().enumerate() {
                let row = x.row_mut(i);
                for (v, p) in row.iter_mut().zip(pe.row(c.len)) {
                    *v += *p;
                }
            }
        }
        for (li, blk) in self.blocks.iter().enumerate() {
            let mut h = x.clone();
            self.norm1(blk, &mut h);
            let mut q = Matrix::zeros(n, d);
            let mut k_new = Matrix::zeros(n, d);
            let mut v_new = Matrix::zeros(n, d);
            blk.q.lin.forward_into(&h, &mut q);
            blk.k.lin.forward_into(&h, &mut k_new);
            blk.v.lin.forward_into(&h, &mut v_new);
            for (i, c) in caches.iter_mut().enumerate() {
                c.append_row(li, k_new.row(i), v_new.row(i));
            }
            // Ragged attention: parallel over (sequence, head), each
            // sequence against its own borrowed prefix.
            let attn = {
                let seqs: Vec<SeqKv> = caches
                    .iter()
                    .enumerate()
                    .map(|(i, c)| SeqKv {
                        q_row0: i,
                        n_new: 1,
                        past: c.len,
                        segs: KvSegs::F32 {
                            k: vec![c.k_rows(li)],
                            v: vec![c.v_rows(li)],
                        },
                        seg_tokens: c.len + 1,
                    })
                    .collect();
                self.attention_kv(&q, &seqs)
            };
            let mut o_out = Matrix::zeros(n, d);
            blk.o.lin.forward_into(&attn, &mut o_out);
            add_inplace(&mut x, &o_out);

            let mut h = x.clone();
            self.norm2(blk, &mut h);
            let mut a = Matrix::zeros(n, self.cfg.d_ff);
            blk.ff1.lin.forward_into(&h, &mut a);
            match self.cfg.arch {
                Arch::Gpt => map_inplace(&mut a, gelu),
                Arch::Llama => {
                    let ff3 = blk.ff3.as_ref().expect("llama gate");
                    let mut g = Matrix::zeros(h.rows, self.cfg.d_ff);
                    ff3.lin.forward_into(&h, &mut g);
                    map_inplace(&mut a, silu);
                    mul_inplace(&mut a, &g);
                }
            }
            let mut m_out = Matrix::zeros(n, d);
            blk.ff2.lin.forward_into(&a, &mut m_out);
            add_inplace(&mut x, &m_out);
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        match self.cfg.arch {
            Arch::Gpt => layernorm(&mut x, &self.lnf_g, self.lnf_b.as_deref(), self.cfg.eps),
            Arch::Llama => rmsnorm(&mut x, &self.lnf_g, self.cfg.eps),
        }
        matmul(&x, &self.tok_emb)
    }

    /// Greedy / temperature sampling from row `row` of `logits` (the
    /// batched decode path samples one row per sequence).
    pub fn sample_row(&self, logits: &Matrix, row: usize, temperature: f32, rng: &mut Rng) -> u8 {
        if temperature <= 0.0 {
            return greedy_row(logits, row);
        }
        let row = logits.row(row);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, v| m.max(*v));
        let probs: Vec<f32> = row.iter().map(|v| ((v - max) / temperature).exp()).collect();
        let sum: f32 = probs.iter().sum();
        let u = rng.range_f32(0.0, sum);
        pick_from_probs(&probs, u)
    }

    /// Greedy / temperature sampling from the last row of `logits`.
    pub fn sample(&self, logits: &Matrix, temperature: f32, rng: &mut Rng) -> u8 {
        self.sample_row(logits, logits.rows - 1, temperature, rng)
    }

    /// Generate `max_new` tokens after `prompt` (batch = 1).
    pub fn generate(&self, prompt: &[u8], max_new: usize, temperature: f32, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut cache = KvCache::new(self);
        let budget = max_new.min(self.cfg.max_seq.saturating_sub(prompt.len()));
        let mut out = Vec::with_capacity(budget);
        let mut logits = self.forward_cached(prompt, &mut cache);
        for _ in 0..budget {
            let t = self.sample(&logits, temperature, &mut rng);
            out.push(t);
            if cache.remaining() == 0 {
                break;
            }
            logits = self.forward_cached(&[t], &mut cache);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::tiny_model;
    use super::super::Arch;
    use super::*;

    #[test]
    fn cached_matches_full_forward() {
        for arch in [Arch::Gpt, Arch::Llama] {
            let m = tiny_model(arch, 7);
            let tokens: Vec<u8> = (5..21).collect();
            let full = m.forward(&tokens, 1, 16, None);
            // Incremental: prefill 10, then 6 single steps.
            let mut cache = KvCache::new(&m);
            let mut last = m.forward_cached(&tokens[..10], &mut cache);
            for (i, t) in tokens[10..].iter().enumerate() {
                // check logits for position 9+i match the full pass
                let pos = 9 + i;
                let fr = full.row(pos);
                let cr = last.row(last.rows - 1);
                for (a, b) in fr.iter().zip(cr) {
                    assert!((a - b).abs() < 1e-3, "{arch:?} pos {pos}: {a} vs {b}");
                }
                last = m.forward_cached(&[*t], &mut cache);
            }
            assert_eq!(cache.len, 16);
        }
    }

    #[test]
    fn decode_step_matches_forward_cached() {
        for arch in [Arch::Gpt, Arch::Llama] {
            let m = tiny_model(arch, 11);
            let prompt: Vec<u8> = (1..9).collect();
            let mut c_ref = KvCache::new(&m);
            let mut c_bat = KvCache::new(&m);
            let l0 = m.forward_cached(&prompt, &mut c_ref);
            m.forward_cached(&prompt, &mut c_bat);
            let mut rng = Rng::seed_from_u64(0);
            let mut t = m.sample(&l0, 0.0, &mut rng);
            for _ in 0..4 {
                let a = m.forward_cached(&[t], &mut c_ref);
                let b = m.decode_step(&[t], &mut [&mut c_bat]);
                assert_eq!(a.row(0), b.row(0), "{arch:?}: decode_step diverged");
                t = m.sample(&a, 0.0, &mut rng);
            }
            assert_eq!(c_ref.len, c_bat.len);
        }
    }

    #[test]
    fn batched_ragged_decode_matches_sequential() {
        let m = tiny_model(Arch::Llama, 12);
        // Three sequences with ragged prefix lengths.
        let prompts: [&[u8]; 3] = [b"abcdef", b"xy", b"hello world"];
        let want: Vec<Vec<u8>> = prompts.iter().map(|p| m.generate(p, 5, 0.0, 0)).collect();
        // Batched: prefill each, then lockstep decode_step rounds.
        let mut caches: Vec<KvCache> = prompts.iter().map(|_| KvCache::new(&m)).collect();
        let mut rng = Rng::seed_from_u64(0);
        let mut last = Vec::new();
        for (p, c) in prompts.iter().zip(&mut caches) {
            let logits = m.forward_cached(p, c);
            last.push(m.sample(&logits, 0.0, &mut rng));
        }
        let mut outs: Vec<Vec<u8>> = last.iter().map(|t| vec![*t]).collect();
        for _ in 0..4 {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let logits = m.decode_step(&last, &mut refs);
            for i in 0..prompts.len() {
                let t = m.sample_row(&logits, i, 0.0, &mut rng);
                outs[i].push(t);
                last[i] = t;
            }
        }
        assert_eq!(outs, want, "greedy batched decode must be bit-identical");
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let m = tiny_model(Arch::Gpt, 8);
        let a = m.generate(b"hello ", 10, 0.0, 1);
        let b = m.generate(b"hello ", 10, 0.0, 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
    }

    #[test]
    fn generation_respects_max_seq() {
        let m = tiny_model(Arch::Llama, 9);
        let prompt = vec![1u8; 60];
        let out = m.generate(&prompt, 100, 0.5, 3);
        assert!(out.len() <= m.cfg.max_seq - 60);
    }

    #[test]
    fn cache_accounting() {
        let m = tiny_model(Arch::Gpt, 10);
        let mut cache = KvCache::new(&m);
        assert_eq!(cache.remaining(), 64);
        assert_eq!(cache.bytes(), 0, "empty cache must hold no memory");
        m.forward_cached(&[1, 2, 3], &mut cache);
        assert_eq!(cache.len, 3);
        // 3 tokens round up to one chunk per K/V buffer per layer — far
        // below the old eager max_seq × d reservation.
        let full = m.cfg.n_layer * 2 * m.cfg.max_seq * m.cfg.d_model * 4;
        assert!(cache.bytes() >= KvCache::bytes_for_tokens(&m.cfg, 3));
        assert!(cache.bytes() <= full / 2, "{} should be well under {full}", cache.bytes());
    }

    #[test]
    fn cache_grows_chunkwise() {
        let m = tiny_model(Arch::Llama, 13);
        let mut cache = KvCache::new(&m);
        let prompt = vec![7u8; KV_CHUNK_TOKENS];
        m.forward_cached(&prompt, &mut cache);
        let one_chunk = cache.bytes();
        m.forward_cached(&[1], &mut cache); // crosses into chunk 2
        assert!(cache.bytes() > one_chunk, "17th token must grow the cache");
        assert!(cache.bytes() >= KvCache::bytes_for_tokens(&m.cfg, KV_CHUNK_TOKENS + 1));
    }

    #[test]
    fn cdf_boundary_falls_back_to_last_supported_token() {
        // u drawn exactly at the sum (or overshooting it by rounding):
        // the running subtraction can leave u > 0 after the full scan.
        // The pick must be the last token with nonzero mass, never a
        // hardcoded out-of-distribution constant.
        let probs = vec![0.1f32, 0.2, 0.3, 0.0, 0.4, 0.0];
        let sum: f32 = probs.iter().sum();
        assert_eq!(pick_from_probs(&probs, sum), 4);
        assert_eq!(pick_from_probs(&probs, sum + 1e-3), 4, "forced fallthrough");
        // Interior draws are unaffected.
        assert_eq!(pick_from_probs(&probs, 0.0), 0);
        assert_eq!(pick_from_probs(&probs, 0.15), 1);
        // Tiny-temperature degenerate case: all mass on one token, the
        // boundary draw still lands on it.
        let degenerate = vec![0.0f32, 0.0, 1.0, 0.0];
        assert_eq!(pick_from_probs(&degenerate, 1.0), 2);
        assert_eq!(pick_from_probs(&degenerate, 1.0 + f32::EPSILON), 2);
    }
}
