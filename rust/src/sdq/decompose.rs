//! Stage 2 — N:M local outlier extraction (§4, §5 Stage 2).
//!
//! Splits a (possibly already sparsified) weight matrix into two tensors
//! that sum back to the original:
//!
//! * **outliers** — at most `N_o` per `M`-block, chosen by a metric
//!   (magnitude / weight·activation product / quantization error), kept
//!   in a higher-precision format;
//! * **inliers** — the remaining survivors, guaranteed `N_i:M`
//!   structured-sparse by construction.
//!
//! Both halves are N:M structured, so both run on structured-sparse
//! tensor cores — the paper's key idea versus unstructured global
//! outlier extraction (LLM.int8, SpQR, OWQ, SqueezeLLM).
//!
//! Also hosts the **Fig. 5 coverage analysis**: how many *global* (whole
//! tensor) or *semi-local* (per Q-vector) outliers an N:M local
//! extraction captures, as a function of the outlier ratio.

use anyhow::{anyhow, bail};

use super::calib::LayerStats;
use super::config::{DecompMetric, DecompOrder, DecomposeCfg};
use super::nm::NmPattern;
use crate::formats::NumFormat;
use crate::tensor::Matrix;
use crate::Result;

/// Result of the decomposition stage. `outliers + inliers == input`.
#[derive(Clone, Debug)]
pub struct Decomposed {
    pub outliers: Matrix,
    pub inliers: Matrix,
}

/// Decompose `w` per `cfg`. `stats` is required for the `Product` metric;
/// `qvec` feeds the `Error` metric (quantization-error saliency uses the
/// same Q-vector granularity the quantizer will use).
pub fn decompose(
    w: &Matrix,
    cfg: &DecomposeCfg,
    stats: Option<&LayerStats>,
    qvec: usize,
) -> Result<Decomposed> {
    let m = cfg.outlier_pattern.m;
    if cfg.inlier_pattern.m != m {
        bail!("outlier/inlier S-vector sizes differ");
    }
    if w.cols % m != 0 {
        bail!("in_features {} not a multiple of M={m}", w.cols);
    }
    let norms: Option<Vec<f32>> = match cfg.metric {
        DecompMetric::Product => {
            let st =
                stats.ok_or_else(|| anyhow!("product metric requires calibration stats"))?;
            if st.in_features != w.cols {
                bail!("calibration width mismatch");
            }
            Some(st.col_norms())
        }
        _ => None,
    };

    let mut outliers = Matrix::zeros(w.rows, w.cols);
    let mut inliers = Matrix::zeros(w.rows, w.cols);
    let n_out = cfg.outlier_pattern.n;

    let mut scores: Vec<f32> = vec![0.0; w.cols];
    for r in 0..w.rows {
        let row = w.row(r);
        score_row(row, cfg, norms.as_deref(), qvec, &mut scores);
        let out_row = outliers.row_mut(r);
        for (b, blk) in row.chunks(m).enumerate() {
            let base = b * m;
            // Rank surviving (non-zero) elements by the metric.
            let mut idx: Vec<usize> =
                (0..blk.len()).filter(|&i| blk[i] != 0.0).collect();
            idx.sort_by(|&a, &c| {
                let (sa, sc) = (scores[base + a], scores[base + c]);
                match cfg.order {
                    DecompOrder::Large => {
                        sc.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal)
                    }
                    DecompOrder::Small => {
                        sa.partial_cmp(&sc).unwrap_or(std::cmp::Ordering::Equal)
                    }
                }
                .then(a.cmp(&c))
            });
            for &i in idx.iter().take(n_out) {
                out_row[base + i] = blk[i];
            }
        }
        let in_row = inliers.row_mut(r);
        for i in 0..w.cols {
            if out_row[i] == 0.0 {
                in_row[i] = row[i];
            }
        }
    }

    debug_assert!(cfg.outlier_pattern.check(&outliers));
    debug_assert!(cfg.inlier_pattern.check(&inliers));
    Ok(Decomposed { outliers, inliers })
}

/// Fill `scores` with the per-element saliency for one row.
fn score_row(
    row: &[f32],
    cfg: &DecomposeCfg,
    norms: Option<&[f32]>,
    qvec: usize,
    scores: &mut [f32],
) {
    match cfg.metric {
        DecompMetric::Magnitude => {
            for (s, v) in scores.iter_mut().zip(row) {
                *s = v.abs();
            }
        }
        DecompMetric::Product => {
            let norms = norms.expect("checked by caller");
            for ((s, v), n) in scores.iter_mut().zip(row).zip(norms) {
                *s = v.abs() * n.max(1e-12);
            }
        }
        DecompMetric::Error => {
            // Saliency = the error this element would suffer if quantized
            // as an inlier at the Q-vector scale it will actually get.
            quant_error_scores(row, cfg.inlier_fmt, qvec, scores);
        }
    }
}

/// Per-element quantization error under per-Q-vector max-abs scaling.
fn quant_error_scores(row: &[f32], fmt: NumFormat, qvec: usize, scores: &mut [f32]) {
    for (q, blk) in row.chunks(qvec).enumerate() {
        let max_abs = blk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = if max_abs == 0.0 { 1.0 } else { max_abs / fmt.max_value() };
        for (i, v) in blk.iter().enumerate() {
            let deq = fmt.quantize(v / scale) * scale;
            scores[q * qvec + i] = (v - deq).abs();
        }
    }
}

/// Scope for the Fig. 5 coverage study.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OutlierScope {
    /// Top-⌊ε·numel⌋ elements of the whole tensor by |·|.
    Global,
    /// Top-⌊ε·qvec⌋ elements of each Q-vector by |·| (the outliers a
    /// per-vector scale factor actually needs to dodge).
    SemiLocal { qvec: usize },
}

/// Fraction of ε-ratio outliers (per `scope`) that an `extract` N:M
/// *local* extraction by magnitude captures (Fig. 5). Returns 1.0 when
/// the scope yields no outliers at this ratio.
pub fn coverage(w: &Matrix, extract: NmPattern, outlier_ratio: f64, scope: OutlierScope) -> f64 {
    assert!((0.0..=1.0).contains(&outlier_ratio));
    // Positions the local extraction captures: top-N of each M-block.
    let mut captured = vec![false; w.len()];
    for r in 0..w.rows {
        let row = w.row(r);
        for (b, blk) in row.chunks(extract.m).enumerate() {
            let base = r * w.cols + b * extract.m;
            let mut idx: Vec<usize> = (0..blk.len()).collect();
            idx.sort_by(|&a, &c| {
                blk[c].abs().partial_cmp(&blk[a].abs()).unwrap().then(a.cmp(&c))
            });
            for &i in idx.iter().take(extract.n) {
                captured[base + i] = true;
            }
        }
    }

    // Target outlier positions per scope.
    let mut targets: Vec<usize> = Vec::new();
    match scope {
        OutlierScope::Global => {
            let k = (outlier_ratio * w.len() as f64).floor() as usize;
            if k == 0 {
                return 1.0;
            }
            let mut idx: Vec<usize> = (0..w.len()).collect();
            idx.sort_by(|&a, &c| {
                w.data[c].abs().partial_cmp(&w.data[a].abs()).unwrap().then(a.cmp(&c))
            });
            targets.extend(&idx[..k]);
        }
        OutlierScope::SemiLocal { qvec } => {
            let k = (outlier_ratio * qvec as f64).floor() as usize;
            if k == 0 {
                return 1.0;
            }
            for r in 0..w.rows {
                let row = w.row(r);
                for (q, blk) in row.chunks(qvec).enumerate() {
                    let base = r * w.cols + q * qvec;
                    let mut idx: Vec<usize> = (0..blk.len()).collect();
                    idx.sort_by(|&a, &c| {
                        blk[c].abs().partial_cmp(&blk[a].abs()).unwrap().then(a.cmp(&c))
                    });
                    targets.extend(idx[..k.min(blk.len())].iter().map(|i| base + i));
                }
            }
        }
    }
    let hit = targets.iter().filter(|&&p| captured[p]).count();
    hit as f64 / targets.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdq::calib::CalibStats;
    use crate::sdq::config::{DecompMetric, DecompOrder, DecomposeCfg};
    use crate::util::rng::Rng;

    fn cfg(metric: DecompMetric, order: DecompOrder) -> DecomposeCfg {
        DecomposeCfg {
            outlier_pattern: NmPattern::new(1, 8),
            outlier_fmt: NumFormat::Int(8),
            inlier_pattern: NmPattern::new(7, 8),
            inlier_fmt: NumFormat::Fp4E2M1,
            metric,
            order,
        }
    }

    #[test]
    fn partition_sums_back() {
        let mut rng = Rng::seed_from_u64(3);
        let w = Matrix::from_vec(4, 32, (0..128).map(|_| rng.range_f32(-2.0, 2.0)).collect());
        let d = decompose(&w, &cfg(DecompMetric::Magnitude, DecompOrder::Large), None, 16)
            .unwrap();
        for i in 0..w.len() {
            assert_eq!(d.outliers.data[i] + d.inliers.data[i], w.data[i]);
            // Disjoint support
            assert!(d.outliers.data[i] == 0.0 || d.inliers.data[i] == 0.0);
        }
    }

    #[test]
    fn magnitude_large_takes_block_max() {
        let mut row = vec![0.1f32; 8];
        row[5] = -9.0;
        let w = Matrix::from_vec(1, 8, row);
        let d = decompose(&w, &cfg(DecompMetric::Magnitude, DecompOrder::Large), None, 8)
            .unwrap();
        assert_eq!(d.outliers.data[5], -9.0);
        assert_eq!(d.outliers.data.iter().filter(|v| **v != 0.0).count(), 1);
    }

    #[test]
    fn small_order_takes_block_min() {
        let w = Matrix::from_vec(1, 8, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let d =
            decompose(&w, &cfg(DecompMetric::Magnitude, DecompOrder::Small), None, 8).unwrap();
        assert_eq!(d.outliers.data[0], 1.0);
    }

    #[test]
    fn product_metric_uses_norms() {
        let w = Matrix::from_vec(1, 8, vec![0.1, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5]);
        let mut st = CalibStats::new(false);
        let mut act = vec![1.0f32; 8];
        act[0] = 1000.0; // column 0 has huge activations
        st.observe("l", &Matrix::from_vec(1, 8, act));
        let d = decompose(
            &w,
            &cfg(DecompMetric::Product, DecompOrder::Large),
            st.get("l"),
            8,
        )
        .unwrap();
        assert_eq!(d.outliers.data[0], 0.1);
    }

    #[test]
    fn error_metric_prefers_badly_quantized() {
        // A lone huge value inflates the Q-vector scale; its own error is
        // small but it must still rank as the outlier per error scoring?
        // No: the *error* metric picks the element with the largest
        // quantization error — typically the big value itself when the
        // grid is coarse. Verify scoring is finite and selection works.
        let w = Matrix::from_vec(1, 8, vec![0.3, 0.31, 0.29, 0.3, 12.0, 0.3, 0.28, 0.3]);
        let d =
            decompose(&w, &cfg(DecompMetric::Error, DecompOrder::Large), None, 8).unwrap();
        let nnz_out: Vec<usize> =
            (0..8).filter(|&i| d.outliers.data[i] != 0.0).collect();
        assert_eq!(nnz_out.len(), 1);
    }

    #[test]
    fn sparsified_input_keeps_inlier_pattern() {
        // 6:8 input, extract 1:8 → inliers must be 5:8… but the config
        // says inlier 7:8; pattern check still passes (5 ≤ 7).
        let mut rng = Rng::seed_from_u64(5);
        let mut w = Matrix::from_vec(2, 16, (0..32).map(|_| rng.range_f32(-1.0, 1.0)).collect());
        // zero two per block
        for r in 0..2 {
            for b in 0..2 {
                *w.at_mut(r, b * 8) = 0.0;
                *w.at_mut(r, b * 8 + 1) = 0.0;
            }
        }
        let d = decompose(&w, &cfg(DecompMetric::Magnitude, DecompOrder::Large), None, 16)
            .unwrap();
        assert!(NmPattern::new(5, 8).check(&d.inliers));
    }

    #[test]
    fn coverage_full_for_tiny_ratio() {
        let mut rng = Rng::seed_from_u64(9);
        let w =
            Matrix::from_vec(8, 64, (0..512).map(|_| rng.range_f32(-1.0, 1.0)).collect());
        // ratio so small no outliers exist at all
        assert_eq!(coverage(&w, NmPattern::new(1, 8), 0.0001, OutlierScope::Global), 1.0);
    }

    #[test]
    fn coverage_semilocal_one_per_qvec_is_perfect() {
        // One outlier per 64-wide Q-vector: the Q-vector max is always the
        // max of its own 8-block too, so 1:8 captures it.
        let mut rng = Rng::seed_from_u64(10);
        let mut w =
            Matrix::from_vec(4, 128, (0..512).map(|_| rng.range_f32(-0.1, 0.1)).collect());
        for r in 0..4 {
            for q in 0..2 {
                *w.at_mut(r, q * 64 + (r * 13) % 64) = 50.0;
            }
        }
        let c = coverage(&w, NmPattern::new(1, 8), 1.0 / 64.0, OutlierScope::SemiLocal { qvec: 64 });
        assert_eq!(c, 1.0);
    }

    #[test]
    fn coverage_monotone_in_n() {
        let mut rng = Rng::seed_from_u64(11);
        let w = Matrix::from_vec(
            16,
            256,
            (0..4096).map(|_| rng.range_f32(-1.0, 1.0).powi(5)).collect(),
        );
        let mut prev = 0.0;
        for n in 1..=4 {
            let c = coverage(&w, NmPattern::new(n, 8), 0.05, OutlierScope::Global);
            assert!(c >= prev - 1e-12, "coverage must grow with N");
            prev = c;
        }
    }
}
