//! Fig. 8 — effective-compute-throughput estimation per configuration:
//! the analytical decomposition (outlier-pass fraction + inlier-pass
//! fraction) plus the *achieved* speedup on the simulated flexible N:M
//! sparse tensor core, including the sparsity tax.

use sdq::harness;
use sdq::perfmodel::simtc::TensorCoreSpec;
use sdq::sdq::config::{CompressionConfig, Stages};
use sdq::util::bench::Table;

fn main() {
    let spec = TensorCoreSpec::default();
    let (t, k, o) = (512usize, 4096usize, 4096usize);
    let mut table = Table::new(
        "Fig 8: effective compute throughput (analytic vs simulated sparse TC)",
        &["Configuration", "OutlierCost", "InlierCost", "Analytic", "SimTC", "Tax%"],
    );
    for cfg_str in harness::table2_configs() {
        let cfg: CompressionConfig = cfg_str.parse().unwrap();
        let (oc, ic) = match &cfg.stages {
            Stages::Sdq { decompose, .. } => (
                decompose.outlier_pattern.density() * decompose.outlier_fmt.bits() as f64
                    / 16.0,
                decompose.inlier_pattern.density() * decompose.inlier_fmt.bits() as f64
                    / 16.0,
            ),
            _ => (0.0, 1.0 / cfg.effective_throughput()),
        };
        let sim = spec.simulate(&cfg, t, k, o);
        table.row(vec![
            cfg_str.to_string(),
            format!("{oc:.4}"),
            format!("{ic:.4}"),
            format!("{:.2}x", sim.analytic_speedup),
            format!("{:.2}x", sim.speedup),
            format!("{:.1}", sim.tax * 100.0),
        ]);
    }
    table.print();
    table.save_json("fig8_throughput");

    // The paper's worked example: SDQ-7:8 → 1/16 + 3/16 = 1/4 → 4×.
    let c: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
    println!(
        "\nworked example SDQ-W7:8-1:8int8-6:8fp4: 1/8·1/2 + 6/8·1/4 = 1/4 → {:.2}x ✓",
        c.effective_throughput()
    );
}
