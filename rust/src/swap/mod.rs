//! Tiered KV spill: where a preempted sequence's [`Snapshot`] waits.
//!
//! PR 5's preemption always parked the swapped-out snapshot in host
//! memory. That is the right call when host RAM is plentiful — restore
//! is a memcpy — but it means the KV bytes the pool just freed are
//! still held by the process, so an oversubscribed engine's *host*
//! footprint grows with the swap queue, not with the pool budget. This
//! module adds the other two tiers and the policy that picks between
//! them, per victim, at suspend time:
//!
//! * **resident** — keep the [`Snapshot`] in memory (the default, and
//!   the fallback when the disk tier is unavailable);
//! * **spill** — serialize through [`crate::kv::wire`] (optionally
//!   RLE-compressing the quantized code slabs) into a [`SwapDir`] and
//!   drop the in-memory bytes; restore is a read + decode, byte-exact
//!   by the wire round-trip guarantee;
//! * **reprefill** — drop the bytes entirely and re-run the model over
//!   the committed token history at resume. Only offered on **f32**
//!   pools, where verbatim rows + row-independent kernels make replay
//!   bit-exact at any batching; quantized codes depend on the exact
//!   incremental write/read schedule (see
//!   [`crate::kv::pool::Snapshot`]), so quantized victims never take
//!   this tier.
//!
//! The victim cost model ([`choose`]) ranks the freeing tiers by
//! **bytes freed per token lost**: both spill and reprefill free the
//! snapshot's bytes, so the comparison collapses to their token-
//! denominated costs — a disk round-trip priced at
//! [`SwapConfig::disk_cost_tokens`] versus recomputing `len` tokens.
//! Short sequences are cheaper to replay; long ones are cheaper to
//! ship to disk. Neither fires while resident snapshots still fit
//! [`SwapConfig::resident_budget_bytes`].

use std::fs;
use std::path::{Path, PathBuf};

use crate::kv::{KvDtype, Snapshot};

/// A directory holding spilled snapshots, one wire-format file per
/// suspended sequence. Keys are the engine-local request ids, so a
/// `SwapDir` must not be shared between engine replicas — give each
/// replica its own subdirectory (as `examples/serve.rs --swap-dir`
/// does).
#[derive(Clone, Debug)]
pub struct SwapDir {
    root: PathBuf,
}

impl SwapDir {
    /// Open (creating if needed) a spill directory.
    pub fn new(path: impl Into<PathBuf>) -> crate::Result<Self> {
        let root = path.into();
        fs::create_dir_all(&root)?;
        Ok(SwapDir { root })
    }

    pub fn path(&self) -> &Path {
        &self.root
    }

    fn file(&self, key: u64) -> PathBuf {
        self.root.join(format!("seq-{key}.kvw"))
    }

    /// Persist one sequence's wire bytes.
    pub fn spill(&self, key: u64, bytes: &[u8]) -> crate::Result<()> {
        Ok(fs::write(self.file(key), bytes)?)
    }

    /// Read a spilled sequence back and remove its file.
    pub fn restore(&self, key: u64) -> crate::Result<Vec<u8>> {
        let p = self.file(key);
        let bytes = fs::read(&p)?;
        let _ = fs::remove_file(&p);
        Ok(bytes)
    }

    /// Drop a spilled sequence without reading it (cancellation).
    pub fn discard(&self, key: u64) {
        let _ = fs::remove_file(self.file(key));
    }
}

/// Spill-tier configuration the scheduler consults on every
/// preemption ([`crate::coordinator::Scheduler::set_swap`]). The
/// default is PR 5's behavior exactly: every snapshot stays resident.
#[derive(Clone, Debug)]
pub struct SwapConfig {
    /// Disk tier; `None` disables spilling.
    pub dir: Option<SwapDir>,
    /// Host bytes the resident snapshot tier may hold before the cost
    /// model starts freeing (`usize::MAX` = never spill or drop).
    pub resident_budget_bytes: usize,
    /// Price of one disk round-trip in recompute-token equivalents —
    /// the exchange rate between the spill and reprefill tiers. A
    /// victim shorter than this replays; a longer one spills.
    pub disk_cost_tokens: usize,
    /// Run the quantized code slabs through the wire RLE codec when
    /// spilling.
    pub codec: bool,
}

impl Default for SwapConfig {
    fn default() -> Self {
        SwapConfig {
            dir: None,
            resident_budget_bytes: usize::MAX,
            disk_cost_tokens: 8,
            codec: true,
        }
    }
}

/// Where the cost model parks one victim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapVerdict {
    Resident,
    Spill,
    Reprefill,
}

/// The victim cost model: bytes freed per token lost.
///
/// * A snapshot that owns no bytes (block-aligned f32 tail) frees
///   nothing whatever tier it takes — keep it resident.
/// * While `resident_bytes + snap.bytes()` fits the resident budget
///   there is no host pressure — resident.
/// * Otherwise both freeing tiers release `snap.bytes()`, so the
///   bytes-per-token-lost ranking reduces to comparing token costs:
///   spill pays `disk_cost_tokens`, reprefill pays `snap.len()`
///   recomputed tokens. Reprefill is only *sound* on f32 pools
///   (`reprefill_exact`); when neither tier is available the snapshot
///   degrades to resident.
pub fn choose(
    cfg: &SwapConfig,
    resident_bytes: usize,
    snap: &Snapshot,
    reprefill_exact: bool,
) -> SwapVerdict {
    if snap.bytes() == 0 {
        return SwapVerdict::Resident;
    }
    if resident_bytes.saturating_add(snap.bytes()) <= cfg.resident_budget_bytes {
        return SwapVerdict::Resident;
    }
    let can_spill = cfg.dir.is_some();
    let can_drop = reprefill_exact && snap.len() > 0;
    match (can_spill, can_drop) {
        (false, false) => SwapVerdict::Resident,
        (true, false) => SwapVerdict::Spill,
        (false, true) => SwapVerdict::Reprefill,
        // Same bytes freed either way — lower token cost wins; ties go
        // to the disk (exact for every dtype, no model time).
        (true, true) => {
            if snap.len() < cfg.disk_cost_tokens {
                SwapVerdict::Reprefill
            } else {
                SwapVerdict::Spill
            }
        }
    }
}

/// Whether the reprefill tier is sound for a pool dtype: replay is
/// bit-exact only where rows are stored verbatim and kernels are
/// row-independent — f32.
pub fn reprefill_is_exact(dtype: KvDtype) -> bool {
    dtype == KvDtype::F32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::{BlockPool, BlockTable};
    use crate::model::{Arch, ModelConfig};
    use crate::util::testdir::TempDir;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "swap-test".into(),
            arch: Arch::Gpt,
            d_model: 8,
            n_layer: 2,
            n_head: 2,
            d_ff: 16,
            vocab: 256,
            max_seq: 64,
            eps: 1e-5,
            rope_theta: 10000.0,
            kv_dtype: KvDtype::F32,
        }
    }

    fn snapshot(dtype: KvDtype, n: usize) -> (BlockPool, Snapshot) {
        let c = cfg();
        let bb = BlockPool::block_bytes_for(c.n_layer, 4, c.d_model, dtype);
        let mut p = BlockPool::with_params(&c, 16 * bb, 4, dtype);
        let mut t = BlockTable::new(64);
        p.prepare_tokens(&mut t, n);
        let toks: Vec<u8> = (1..=n as u8).collect();
        for (j, tok) in toks.iter().enumerate() {
            for li in 0..2 {
                let row = vec![*tok as f32 + li as f32; 8];
                p.write_row(&t, li, j, &row, &row);
            }
        }
        p.commit(&mut t, &toks);
        let s = p.suspend(t);
        (p, s)
    }

    #[test]
    fn swapdir_round_trip_and_discard() {
        let tmp = TempDir::new("swapdir");
        let dir = SwapDir::new(tmp.path().join("tier")).unwrap();
        dir.spill(7, b"payload").unwrap();
        assert_eq!(dir.restore(7).unwrap(), b"payload");
        // restore removed the file
        assert!(dir.restore(7).is_err());
        dir.spill(9, b"x").unwrap();
        dir.discard(9);
        assert!(dir.restore(9).is_err());
    }

    #[test]
    fn cost_model_tiers() {
        let tmp = TempDir::new("swap-cost");
        let with_dir = SwapConfig {
            dir: Some(SwapDir::new(tmp.path().join("d")).unwrap()),
            resident_budget_bytes: 0,
            disk_cost_tokens: 8,
            codec: true,
        };
        // Quantized snapshot (owns bytes): must spill, never replay.
        let (_, q) = snapshot(KvDtype::Int8, 11);
        assert!(q.bytes() > 0);
        assert_eq!(
            choose(&with_dir, 0, &q, reprefill_is_exact(KvDtype::Int8)),
            SwapVerdict::Spill
        );
        // f32 partial tail, short sequence → cheaper to replay.
        let (_, f) = snapshot(KvDtype::F32, 5);
        assert!(f.bytes() > 0);
        assert_eq!(
            choose(&with_dir, 0, &f, reprefill_is_exact(KvDtype::F32)),
            SwapVerdict::Reprefill
        );
        // Long f32 sequence → disk round-trip wins.
        let (_, long) = snapshot(KvDtype::F32, 21);
        assert_eq!(
            choose(&with_dir, 0, &long, reprefill_is_exact(KvDtype::F32)),
            SwapVerdict::Spill
        );
        // Under the resident budget nothing is freed.
        let roomy = SwapConfig { resident_budget_bytes: usize::MAX, ..with_dir.clone() };
        assert_eq!(choose(&roomy, 0, &q, false), SwapVerdict::Resident);
        // No dir, quantized → degrade to resident even under pressure.
        let no_dir = SwapConfig { dir: None, resident_budget_bytes: 0, ..SwapConfig::default() };
        assert_eq!(choose(&no_dir, 0, &q, false), SwapVerdict::Resident);
        // No dir, f32 → replay is the only freeing tier.
        assert_eq!(choose(&no_dir, 0, &long, true), SwapVerdict::Reprefill);
        // Block-aligned f32 snapshot owns zero bytes → resident.
        let (_, aligned) = snapshot(KvDtype::F32, 8);
        assert_eq!(aligned.bytes(), 0);
        assert_eq!(choose(&with_dir, 0, &aligned, true), SwapVerdict::Resident);
    }
}
