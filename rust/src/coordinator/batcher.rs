//! Admission queue + batch-formation policy.
//!
//! Continuous batching with a KV-memory budget: new requests are
//! admitted into the active set whenever (a) an active slot is free and
//! (b) the projected KV-cache bytes stay under the budget. Waiting
//! requests queue FIFO. The policy mirrors vLLM's admission control at
//! the granularity this engine needs.

use std::collections::VecDeque;

use super::request::{InFlight, Request};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max concurrently-active sequences (decode round width).
    pub max_active: usize,
    /// KV-cache memory budget in bytes across active sequences.
    pub kv_budget_bytes: usize,
    /// Max prompts prefilled per scheduling round (prefill burst limit —
    /// keeps decode latency bounded while the queue drains).
    pub max_prefill_per_round: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_active: 8,
            kv_budget_bytes: 512 << 20,
            max_prefill_per_round: 4,
        }
    }
}

/// FIFO admission queue.
#[derive(Debug, Default)]
pub struct Batcher {
    waiting: VecDeque<InFlight>,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn enqueue(&mut self, req: Request) {
        self.waiting.push_back(InFlight::new(req));
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Admit up to the policy limits given the current active set size
    /// and KV usage. `kv_bytes_per_seq` is the per-sequence cache cost
    /// (fixed-size caches in this engine).
    pub fn admit(
        &mut self,
        policy: &BatchPolicy,
        active: usize,
        kv_in_use: usize,
        kv_bytes_per_seq: usize,
    ) -> Vec<InFlight> {
        let mut out = Vec::new();
        let mut kv = kv_in_use;
        while out.len() < policy.max_prefill_per_round
            && active + out.len() < policy.max_active
            && kv + kv_bytes_per_seq <= policy.kv_budget_bytes
        {
            match self.waiting.pop_front() {
                Some(f) => {
                    kv += kv_bytes_per_seq;
                    out.push(f);
                }
                None => break,
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1u8; 4], 8)
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let admitted = b.admit(&BatchPolicy::default(), 0, 0, 1);
        let ids: Vec<u64> = admitted.iter().map(|f| f.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]); // max_prefill_per_round = 4
        assert_eq!(b.waiting(), 1);
    }

    #[test]
    fn respects_max_active() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let policy = BatchPolicy { max_active: 3, ..Default::default() };
        let admitted = b.admit(&policy, 2, 0, 1);
        assert_eq!(admitted.len(), 1);
    }

    #[test]
    fn respects_kv_budget() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let policy = BatchPolicy { kv_budget_bytes: 100, ..Default::default() };
        // 60 bytes in use, 30 per seq → only one more fits.
        let admitted = b.admit(&policy, 0, 60, 30);
        assert_eq!(admitted.len(), 1);
    }

    #[test]
    fn empty_queue() {
        let mut b = Batcher::new();
        assert!(b.admit(&BatchPolicy::default(), 0, 0, 1).is_empty());
    }
}
