//! Fig. 5 — coverage of N:8 *local* outlier extraction versus the
//! outlier ratio, for global outliers (left plot) and semi-local
//! (Q-vector-64) outliers (right plot).
//!
//! Uses (a) a real trained layer and (b) synthetic tensors with
//! controlled outlier injection matching LLM statistics (1–5% heavy
//! outliers, Guo et al. / Dettmers et al.).

use sdq::harness;
use sdq::sdq::decompose::{coverage, OutlierScope};
use sdq::sdq::nm::NmPattern;
use sdq::tensor::Matrix;
use sdq::util::bench::Table;
use sdq::util::rng::Rng;

/// Gaussian tensor with `ratio` of entries amplified into outliers.
fn outlier_tensor(rows: usize, cols: usize, ratio: f64, seed: u64) -> Matrix {
    let mut rng = Rng::seed_from_u64(seed);
    let mut m = Matrix::zeros(rows, cols);
    for v in &mut m.data {
        *v = rng.normal() * 0.02;
    }
    let n_out = (ratio * m.len() as f64) as usize;
    for _ in 0..n_out {
        let i = rng.below(m.len());
        m.data[i] = rng.normal().signum() * (0.2 + 0.3 * rng.f32());
    }
    m
}

fn sweep(w: &Matrix, label: &str, table: &mut Table) {
    for n in 1..=4 {
        let pat = NmPattern::new(n, 8);
        for pct in [0.5f64, 1.0, 2.0, 3.0, 4.0, 5.0, 8.0, 10.0] {
            let ratio = pct / 100.0;
            let g = coverage(w, pat, ratio, OutlierScope::Global);
            let s = coverage(w, pat, ratio, OutlierScope::SemiLocal { qvec: 64 });
            table.row(vec![
                label.to_string(),
                format!("{n}:8"),
                format!("{pct:.1}"),
                format!("{g:.4}"),
                format!("{s:.4}"),
            ]);
        }
    }
}

fn main() {
    let mut table = Table::new(
        "Fig 5: N:8 local-extraction coverage vs outlier ratio",
        &["tensor", "extract", "ratio%", "global", "semi-local(64)"],
    );

    // Synthetic tensors with controlled outlier ratio (the sweep driver).
    let w_syn = outlier_tensor(512, 1024, 0.05, 7);
    sweep(&w_syn, "synthetic-5%inj", &mut table);

    // A real trained layer, if artifacts exist.
    if harness::artifacts_ready() {
        if let Ok(model) = harness::load_model("gpt-micro") {
            let w = model.linears()[0].lin.dense_view();
            sweep(&w, "gpt-micro.b0.q", &mut table);
        }
    }
    table.print();
    table.save_json("fig5_coverage");

    // Paper's headline observations:
    let c28 = coverage(&w_syn, NmPattern::new(2, 8), 0.04, OutlierScope::Global);
    let c18 = coverage(
        &w_syn,
        NmPattern::new(1, 8),
        0.03,
        OutlierScope::SemiLocal { qvec: 64 },
    );
    println!("\n2:8 captures {:.1}% of global outliers at 4% ratio (paper: ~99%)", c28 * 100.0);
    println!("1:8 captures {:.1}% of semi-local outliers at 3% ratio (paper: ~100%)", c18 * 100.0);
}
