//! In-tree substrates replacing external crates (this workspace builds
//! fully offline):
//!
//! * [`rng`] — deterministic xoshiro256** PRNG (replaces `rand`).
//! * [`par`] — scoped-thread data parallelism (replaces `rayon`).
//! * [`json`] — JSON parse/serialize (replaces `serde_json`).
//! * [`bench`] — benchmark harness + paper-style tables (replaces
//!   `criterion`).
//! * [`prop`] — tiny property-based testing driver (replaces `proptest`).
//! * [`cli`] — flag parsing for the `sdq` binary (replaces `clap`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod testdir;
