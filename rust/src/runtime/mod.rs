//! PJRT runtime: load and execute AOT-compiled XLA artifacts.
//!
//! The build-time pipeline (`make artifacts`) lowers the L2 JAX model —
//! including the L1 Pallas decomposed-GEMM kernels (interpret=True) — to
//! **HLO text** under `artifacts/*.hlo.txt` (text, not serialized proto:
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids). This module compiles those
//! artifacts once on the PJRT CPU client and executes them from the
//! serving hot path. Python never runs here.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context};

use crate::tensor::Matrix;
use crate::Result;

/// A compiled artifact cache over one PJRT client.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client, executables: HashMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact under `name`.
    pub fn load_hlo(&mut self, name: &str, path: &Path) -> Result<()> {
        if !path.exists() {
            bail!("artifact {} not found (run `make artifacts`)", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Loaded artifact names.
    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute `name` with mixed inputs; returns all tuple outputs as
    /// flat f32 vectors.
    pub fn execute(&self, name: &str, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not loaded"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| i.to_literal())
            .collect::<Result<_>>()
            .context("building input literals")?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        // jax lowers with return_tuple=True: one device, one tuple output.
        let mut lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().map_err(|e| anyhow!("read output: {e:?}"))?);
        }
        Ok(out)
    }
}

/// A typed input to an artifact execution.
pub enum Input {
    F32(Matrix),
    /// Flat i32 data + shape.
    I32(Vec<i32>, Vec<i64>),
}

impl Input {
    /// Token ids as `[batch, seq]` i32.
    pub fn tokens(tokens: &[u8], batch: usize, seq: usize) -> Input {
        assert_eq!(tokens.len(), batch * seq);
        Input::I32(
            tokens.iter().map(|t| *t as i32).collect(),
            vec![batch as i64, seq as i64],
        )
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Input::F32(m) => {
                let lit = xla::Literal::vec1(&m.data);
                lit.reshape(&[m.rows as i64, m.cols as i64])
                    .map_err(|e| anyhow!("reshape f32 literal: {e:?}"))
            }
            Input::I32(data, shape) => {
                let lit = xla::Literal::vec1(data);
                lit.reshape(shape).map_err(|e| anyhow!("reshape i32 literal: {e:?}"))
            }
        }
    }
}

/// Standard artifact locations relative to a repo root.
pub fn artifact_path(root: &Path, name: &str) -> PathBuf {
    root.join("artifacts").join(format!("{name}.hlo.txt"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT smoke tests live in `tests/runtime_pjrt.rs` (they need the
    // artifacts built); here we only check pure logic.

    #[test]
    fn artifact_path_layout() {
        let p = artifact_path(Path::new("/repo"), "model_fwd");
        assert_eq!(p, PathBuf::from("/repo/artifacts/model_fwd.hlo.txt"));
    }

    #[test]
    fn tokens_input_shape() {
        let i = Input::tokens(&[1, 2, 3, 4, 5, 6], 2, 3);
        match i {
            Input::I32(data, shape) => {
                assert_eq!(data, vec![1, 2, 3, 4, 5, 6]);
                assert_eq!(shape, vec![2, 3]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn missing_artifact_errors() {
        let mut rt = match PjrtRuntime::cpu() {
            Ok(rt) => rt,
            Err(_) => return, // PJRT unavailable in this env: skip
        };
        assert!(rt.load_hlo("x", Path::new("/nonexistent/x.hlo.txt")).is_err());
        assert!(rt.execute("x", &[]).is_err());
    }
}
