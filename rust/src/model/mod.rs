//! Transformer inference engine (the evaluation substrate).
//!
//! A decoder-only LM in two architectural flavours matching the paper's
//! evaluation families:
//!
//! * `Gpt` — OPT-style: learned positional embeddings, LayerNorm
//!   (gain+bias), GELU MLP;
//! * `Llama` — LLaMA-style: RoPE, RMSNorm, SwiGLU MLP.
//!
//! Every linear layer is a [`Linear`] that is either plain fp32 weights
//! or a compressed [`CompressedLayer`] executing the paper's fake-quant /
//! decomposed two-path GEMM (§5.1). The engine supports full-sequence
//! forward (perplexity eval + calibration capture) and KV-cached
//! incremental decode (serving) in three flavours sharing one ragged
//! attention substrate:
//!
//! * [`Model::forward_cached`] — one sequence over a private chunked
//!   [`generate::KvCache`] (grow-on-demand, the PR 1 baseline);
//! * [`Model::decode_step`] — ragged-batched decode over chunked
//!   caches: each linear layer streams its (compressed) weights once
//!   per round across every active sequence;
//! * [`Model::forward_paged`] — prefill *and* decode over the shared
//!   [`crate::kv::BlockPool`]: `n_new ≥ 1` tokens per sequence through
//!   per-sequence block tables, enabling batched multi-prompt prefill,
//!   prompt-prefix sharing and copy-on-write.
//!
//! All three produce bit-identical logits per sequence — the kernels
//! are row-independent, so batching changes *when* weights stream, not
//! what each row computes.

pub mod forward;
pub mod generate;
pub mod ops;
pub mod paged;

use std::borrow::Cow;

use anyhow::bail;

use crate::artifacts::WeightBundle;
use crate::sdq::calib::CalibStats;
use crate::sdq::config::CompressionConfig;
use crate::sdq::pipeline::{compress_layer, CompressedLayer, ExecPath, LayerReport};
use crate::sdq::quantize::fake_quant_dynamic_inplace;
use crate::tensor::{matmul_into, matmul_q_into, Matrix};
use crate::Result;

/// Architecture flavour.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Gpt,
    Llama,
}

impl Arch {
    pub fn tag(&self) -> &'static str {
        match self {
            Arch::Gpt => "gpt",
            Arch::Llama => "llama",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Arch> {
        match s {
            "gpt" => Ok(Arch::Gpt),
            "llama" => Ok(Arch::Llama),
            _ => anyhow::bail!("unknown arch: {s}"),
        }
    }
}

/// Model hyperparameters (mirrors the JSON the JAX trainer writes).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub arch: Arch,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub eps: f32,
    pub rope_theta: f32,
    /// KV-cache block storage dtype (f32 exact baseline, or fp8/int8
    /// with per-block-per-layer scales). Serving policy may override
    /// per-engine; this is the model-level default.
    pub kv_dtype: crate::kv::KvDtype,
}

impl ModelConfig {
    /// Parse from the JSON the JAX trainer writes (missing optional
    /// fields get defaults: vocab 256, eps 1e-5, rope_theta 10000).
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            arch: Arch::parse(j.req_str("arch")?)?,
            d_model: j.req_usize("d_model")?,
            n_layer: j.req_usize("n_layer")?,
            n_head: j.req_usize("n_head")?,
            d_ff: j.req_usize("d_ff")?,
            vocab: j.get("vocab").and_then(|v| v.as_usize()).unwrap_or(256),
            max_seq: j.req_usize("max_seq")?,
            eps: j.get("eps").and_then(|v| v.as_f64()).unwrap_or(1e-5) as f32,
            rope_theta: j.get("rope_theta").and_then(|v| v.as_f64()).unwrap_or(10000.0)
                as f32,
            kv_dtype: match j.get("kv_dtype").and_then(|v| v.as_str()) {
                Some(s) => crate::kv::KvDtype::parse(s)?,
                None => crate::kv::KvDtype::F32,
            },
        })
    }

    /// Serialize back to JSON (round-trips with [`Self::from_json`]).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("name", Json::from(self.name.clone())),
            ("arch", Json::from(self.arch.tag())),
            ("d_model", Json::from(self.d_model)),
            ("n_layer", Json::from(self.n_layer)),
            ("n_head", Json::from(self.n_head)),
            ("d_ff", Json::from(self.d_ff)),
            ("vocab", Json::from(self.vocab)),
            ("max_seq", Json::from(self.max_seq)),
            ("eps", Json::Num(self.eps as f64)),
            ("rope_theta", Json::Num(self.rope_theta as f64)),
            ("kv_dtype", Json::from(self.kv_dtype.tag())),
        ])
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_head
    }

    /// Linear-layer shapes `(out, in)` — what the perf model rolls up.
    pub fn linear_shapes(&self) -> Vec<(usize, usize)> {
        let d = self.d_model;
        let f = self.d_ff;
        let mut shapes = Vec::new();
        for _ in 0..self.n_layer {
            shapes.extend([(d, d); 4]); // q, k, v, o
            shapes.push((f, d)); // ff1
            shapes.push((d, f)); // ff2
            if self.arch == Arch::Llama {
                shapes.push((f, d)); // ff3 (gate)
            }
        }
        shapes
    }

    /// Total parameters (embeddings + linears + norms).
    pub fn param_count(&self) -> usize {
        let lin: usize = self.linear_shapes().iter().map(|(o, i)| o * i).sum();
        let emb = self.vocab * self.d_model
            + if self.arch == Arch::Gpt { self.max_seq * self.d_model } else { 0 };
        let norms = self.n_layer * 2 * self.d_model * if self.arch == Arch::Gpt { 2 } else { 1 }
            + self.d_model;
        lin + emb + norms
    }
}

/// A linear layer: plain fp32 or compressed.
#[derive(Clone, Debug)]
pub enum Linear {
    Plain(Matrix),
    Compressed(Box<CompressedLayer>),
}

impl Linear {
    /// Output features.
    pub fn out_features(&self) -> usize {
        match self {
            Linear::Plain(w) => w.rows,
            Linear::Compressed(c) => match &c.path {
                ExecPath::Dense { w, .. } => w.rows,
                ExecPath::Decomposed { outlier_w, .. } => outlier_w.rows,
            },
        }
    }

    /// Input features.
    pub fn in_features(&self) -> usize {
        match self {
            Linear::Plain(w) => w.cols,
            Linear::Compressed(c) => match &c.path {
                ExecPath::Dense { w, .. } => w.cols,
                ExecPath::Decomposed { outlier_w, .. } => outlier_w.cols,
            },
        }
    }

    /// `out = x · Wᵀ` with whatever quantization/sparsity this layer
    /// carries. `out` is fully overwritten.
    ///
    /// Dispatch per plane: packed SpMM when a structured-sparse form
    /// exists, else the fused quantized GEMM over real packed codes
    /// ([`matmul_q_into`], bit-identical to the f32 GEMM — see
    /// `sdq::qmat`), else the dense f32 GEMM.
    pub fn forward_into(&self, x: &Matrix, out: &mut Matrix) {
        match self {
            Linear::Plain(w) => matmul_into(x, w, out),
            Linear::Compressed(c) => match &c.path {
                ExecPath::Dense { w, act_fmt, packed, qw } => {
                    let xq;
                    let x_eff = match act_fmt {
                        Some(fmt) => {
                            let mut t = x.clone();
                            fake_quant_dynamic_inplace(&mut t, *fmt, c.qvec);
                            xq = t;
                            &xq
                        }
                        None => x,
                    };
                    match (packed, qw) {
                        (Some(p), _) => {
                            out.data.fill(0.0);
                            p.spmm_into(x_eff, out);
                        }
                        (None, Some(q)) => matmul_q_into(x_eff, q, out),
                        (None, None) => matmul_into(x_eff, w, out),
                    }
                }
                ExecPath::Decomposed {
                    outlier_w,
                    outlier_packed,
                    outlier_q,
                    outlier_act,
                    inlier_w,
                    inlier_packed,
                    inlier_q,
                    inlier_act,
                } => {
                    // Y = Q_o(X)·W_oᵀ + Q_i(X)·W_iᵀ  (Fig. 8)
                    out.data.fill(0.0);
                    let mut xo = x.clone();
                    fake_quant_dynamic_inplace(&mut xo, *outlier_act, c.qvec);
                    match (outlier_packed, outlier_q) {
                        (Some(p), _) => p.spmm_into(&xo, out),
                        (None, q) => {
                            let mut t = Matrix::zeros(out.rows, out.cols);
                            match q {
                                Some(q) => matmul_q_into(&xo, q, &mut t),
                                None => matmul_into(&xo, outlier_w, &mut t),
                            }
                            ops::add_inplace(out, &t);
                        }
                    }
                    let mut xi = x.clone();
                    fake_quant_dynamic_inplace(&mut xi, *inlier_act, c.qvec);
                    match (inlier_packed, inlier_q) {
                        (Some(p), _) => p.spmm_into(&xi, out),
                        (None, q) => {
                            let mut t = Matrix::zeros(out.rows, out.cols);
                            match q {
                                Some(q) => matmul_q_into(&xi, q, &mut t),
                                None => matmul_into(&xi, inlier_w, &mut t),
                            }
                            ops::add_inplace(out, &t);
                        }
                    }
                }
            },
        }
    }

    /// Underlying dense weight view (original or dequantized-summed).
    /// Borrows when a dense matrix already exists (`Plain` and every
    /// `Dense` path); only the decomposed two-plane sum allocates.
    pub fn dense_view(&self) -> Cow<'_, Matrix> {
        match self {
            Linear::Plain(w) => Cow::Borrowed(w),
            Linear::Compressed(c) => match &c.path {
                ExecPath::Dense { w, .. } => Cow::Borrowed(w),
                ExecPath::Decomposed { outlier_w, inlier_w, .. } => {
                    let mut s = outlier_w.clone();
                    ops::add_inplace(&mut s, inlier_w);
                    Cow::Owned(s)
                }
            },
        }
    }

    /// Weight bytes the serving hot path streams through one forward of
    /// this layer, and the bytes *avoided* versus streaming the same
    /// plane(s) as dense f32 — `(streamed, avoided)`. Deterministic
    /// (depends only on the compressed representation), so the
    /// scheduler can account traffic without hot-loop counters.
    pub fn weight_stream_bytes(&self) -> (u64, u64) {
        fn plane(dense_len: usize, packed: &Option<crate::sdq::packed::PackedNm>,
                 qw: &Option<crate::sdq::qmat::QuantMat>) -> (u64, u64) {
            let dense = 4 * dense_len as u64;
            let streamed = match (packed, qw) {
                (Some(p), _) => p.stream_bytes(),
                (None, Some(q)) => q.packed_bytes() as u64,
                (None, None) => dense,
            };
            (streamed, dense.saturating_sub(streamed))
        }
        match self {
            Linear::Plain(w) => (4 * w.len() as u64, 0),
            Linear::Compressed(c) => match &c.path {
                ExecPath::Dense { w, packed, qw, .. } => plane(w.len(), packed, qw),
                ExecPath::Decomposed {
                    outlier_w, outlier_packed, outlier_q,
                    inlier_w, inlier_packed, inlier_q, ..
                } => {
                    let (so, ao) = plane(outlier_w.len(), outlier_packed, outlier_q);
                    let (si, ai) = plane(inlier_w.len(), inlier_packed, inlier_q);
                    (so + si, ao + ai)
                }
            },
        }
    }

    /// Resident bytes of the representation the serving path streams
    /// (packed codes + scales + sparse metadata where those exist, f32
    /// otherwise) — the honest numerator for compression ratios. The
    /// dequantized f32 views kept for eval paths are not counted.
    pub fn weight_bytes(&self) -> u64 {
        fn plane(dense_len: usize, packed: &Option<crate::sdq::packed::PackedNm>,
                 qw: &Option<crate::sdq::qmat::QuantMat>) -> u64 {
            match (packed, qw) {
                (Some(p), _) => p.packed_weight_bytes(),
                (None, Some(q)) => q.packed_bytes() as u64,
                (None, None) => 4 * dense_len as u64,
            }
        }
        match self {
            Linear::Plain(w) => 4 * w.len() as u64,
            Linear::Compressed(c) => match &c.path {
                ExecPath::Dense { w, packed, qw, .. } => plane(w.len(), packed, qw),
                ExecPath::Decomposed {
                    outlier_w, outlier_packed, outlier_q,
                    inlier_w, inlier_packed, inlier_q, ..
                } => {
                    plane(outlier_w.len(), outlier_packed, outlier_q)
                        + plane(inlier_w.len(), inlier_packed, inlier_q)
                }
            },
        }
    }
}

/// A named linear with its calibration-stats key (q/k/v share inputs, so
/// they share one stats entry).
#[derive(Clone, Debug)]
pub struct NamedLinear {
    pub name: String,
    pub stats_key: String,
    pub lin: Linear,
}

/// One transformer block.
#[derive(Clone, Debug)]
pub struct Block {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Option<Vec<f32>>,
    pub q: NamedLinear,
    pub k: NamedLinear,
    pub v: NamedLinear,
    pub o: NamedLinear,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Option<Vec<f32>>,
    pub ff1: NamedLinear,
    pub ff2: NamedLinear,
    /// SwiGLU gate (llama arch only).
    pub ff3: Option<NamedLinear>,
}

/// The model: embeddings + blocks + final norm (lm head tied to tok_emb).
#[derive(Clone, Debug)]
pub struct Model {
    pub cfg: ModelConfig,
    pub tok_emb: Matrix,
    pub pos_emb: Option<Matrix>,
    pub blocks: Vec<Block>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Option<Vec<f32>>,
}

impl Model {
    /// Build from a loaded weight bundle (as written by train.py).
    pub fn from_bundle(mut b: WeightBundle) -> Result<Self> {
        let cfg = ModelConfig::from_json(&b.config)?;
        if cfg.d_model % cfg.n_head != 0 {
            bail!("d_model must divide n_head");
        }
        let gpt = cfg.arch == Arch::Gpt;
        let tok_emb = b.take("tok_emb")?;
        let pos_emb = if gpt { Some(b.take("pos_emb")?) } else { None };
        let mut blocks = Vec::with_capacity(cfg.n_layer);
        for i in 0..cfg.n_layer {
            let p = |s: &str| format!("block{i}.{s}");
            let nl = |b: &mut WeightBundle, name: &str, key: &str| -> Result<NamedLinear> {
                Ok(NamedLinear {
                    name: p(name),
                    stats_key: p(key),
                    lin: Linear::Plain(b.take(&p(name))?),
                })
            };
            blocks.push(Block {
                ln1_g: b.take_vec(&p("ln1.g"))?,
                ln1_b: gpt.then(|| b.take_vec(&p("ln1.b"))).transpose()?,
                q: nl(&mut b, "attn.q", "attn.in")?,
                k: nl(&mut b, "attn.k", "attn.in")?,
                v: nl(&mut b, "attn.v", "attn.in")?,
                o: nl(&mut b, "attn.o", "attn.o.in")?,
                ln2_g: b.take_vec(&p("ln2.g"))?,
                ln2_b: gpt.then(|| b.take_vec(&p("ln2.b"))).transpose()?,
                ff1: nl(&mut b, "mlp.ff1", "mlp.in")?,
                ff2: nl(&mut b, "mlp.ff2", "mlp.ff2.in")?,
                ff3: (cfg.arch == Arch::Llama)
                    .then(|| nl(&mut b, "mlp.ff3", "mlp.in"))
                    .transpose()?,
            });
        }
        let lnf_g = b.take_vec("ln_f.g")?;
        let lnf_b = gpt.then(|| b.take_vec("ln_f.b")).transpose()?;
        Ok(Model { cfg, tok_emb, pos_emb, blocks, lnf_g, lnf_b })
    }

    /// Iterate all linear layers mutably.
    pub fn linears_mut(&mut self) -> Vec<&mut NamedLinear> {
        let mut v = Vec::new();
        for blk in &mut self.blocks {
            v.push(&mut blk.q);
            v.push(&mut blk.k);
            v.push(&mut blk.v);
            v.push(&mut blk.o);
            v.push(&mut blk.ff1);
            v.push(&mut blk.ff2);
            if let Some(f3) = &mut blk.ff3 {
                v.push(f3);
            }
        }
        v
    }

    /// Iterate all linear layers.
    pub fn linears(&self) -> Vec<&NamedLinear> {
        let mut v = Vec::new();
        for blk in &self.blocks {
            v.push(&blk.q);
            v.push(&blk.k);
            v.push(&blk.v);
            v.push(&blk.o);
            v.push(&blk.ff1);
            v.push(&blk.ff2);
            if let Some(f3) = &blk.ff3 {
                v.push(f3);
            }
        }
        v
    }

    /// Apply a compression configuration to every linear layer, using the
    /// given calibration statistics. Returns per-layer reports.
    ///
    /// Embeddings, norms and the (tied) LM head stay fp16, matching the
    /// paper's scope (§2.1: only linear-layer GEMMs are compressed).
    pub fn compress(
        &mut self,
        cfg: &CompressionConfig,
        calib: &CalibStats,
    ) -> Result<Vec<LayerReport>> {
        let mut reports = Vec::new();
        for nl in self.linears_mut() {
            let w = match &nl.lin {
                Linear::Plain(w) => w.clone(),
                Linear::Compressed(_) => bail!("layer {} already compressed", nl.name),
            };
            let stats = calib.get(&nl.stats_key);
            let c = compress_layer(&nl.name, &w, cfg, stats)?;
            reports.push(c.report.clone());
            nl.lin = Linear::Compressed(Box::new(c));
        }
        Ok(reports)
    }

    /// Restore all layers to plain weights (from their dense views) —
    /// used by sweeps that re-compress the same base model.
    pub fn decompress(&mut self) {
        for nl in self.linears_mut() {
            if let Linear::Compressed(_) = nl.lin {
                let w = nl.lin.dense_view().into_owned();
                nl.lin = Linear::Plain(w);
            }
        }
    }

    /// Drop every packed quantized code plane (`qw` / `outlier_q` /
    /// `inlier_q`), reverting the dense planes to the dequantized f32
    /// GEMM. A/B switch for the fused weight plane — output must be
    /// bit-identical either way (`tests/integration.rs` pins it).
    pub fn strip_packed_weights(&mut self) {
        for nl in self.linears_mut() {
            if let Linear::Compressed(c) = &mut nl.lin {
                match &mut c.path {
                    ExecPath::Dense { qw, .. } => *qw = None,
                    ExecPath::Decomposed { outlier_q, inlier_q, .. } => {
                        *outlier_q = None;
                        *inlier_q = None;
                    }
                }
            }
        }
    }

    /// Sum of [`Linear::weight_stream_bytes`] over all linear layers:
    /// `(streamed, avoided)` per full weight stream (one decode round
    /// or one prefill batch — every layer streams once per forward).
    pub fn weight_stream_bytes(&self) -> (u64, u64) {
        self.linears().iter().fold((0, 0), |(s, a), nl| {
            let (ls, la) = nl.lin.weight_stream_bytes();
            (s + ls, a + la)
        })
    }

    /// Sum of [`Linear::weight_bytes`] over all linear layers — actual
    /// packed size of the serving weight representation.
    pub fn weight_bytes(&self) -> u64 {
        self.linears().iter().map(|nl| nl.lin.weight_bytes()).sum()
    }
}

/// Test/bench utilities: small randomly-initialized models.
pub mod testutil {
    use super::*;
    use crate::util::rng::Rng;

    /// Random small model for unit tests.
    pub fn tiny_model(arch: Arch, seed: u64) -> Model {
        let cfg = ModelConfig {
            name: "test-tiny".into(),
            arch,
            d_model: 32,
            n_layer: 2,
            n_head: 4,
            d_ff: 64,
            vocab: 256,
            max_seq: 64,
            eps: 1e-5,
            rope_theta: 10000.0,
            kv_dtype: crate::kv::KvDtype::F32,
        };
        let mut rng = Rng::seed_from_u64(seed);
        let mut m = |r: usize, c: usize| {
            let s = 1.0 / (c as f32).sqrt();
            Matrix::from_vec(r, c, (0..r * c).map(|_| rng.range_f32(-s, s)).collect())
        };
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let gpt = arch == Arch::Gpt;
        let blocks = (0..cfg.n_layer)
            .map(|i| {
                let p = |s: &str| format!("block{i}.{s}");
                let mut nl = |name: &str, key: &str, r: usize, c: usize| NamedLinear {
                    name: p(name),
                    stats_key: p(key),
                    lin: Linear::Plain(m(r, c)),
                };
                Block {
                    ln1_g: vec![1.0; d],
                    ln1_b: gpt.then(|| vec![0.0; d]),
                    q: nl("attn.q", "attn.in", d, d),
                    k: nl("attn.k", "attn.in", d, d),
                    v: nl("attn.v", "attn.in", d, d),
                    o: nl("attn.o", "attn.o.in", d, d),
                    ln2_g: vec![1.0; d],
                    ln2_b: gpt.then(|| vec![0.0; d]),
                    ff1: nl("mlp.ff1", "mlp.in", f, d),
                    ff2: nl("mlp.ff2", "mlp.ff2.in", d, f),
                    ff3: (!gpt).then(|| nl("mlp.ff3", "mlp.in", f, d)),
                }
            })
            .collect();
        Model {
            tok_emb: m(cfg.vocab, d),
            pos_emb: gpt.then(|| m(cfg.max_seq, d)),
            blocks,
            lnf_g: vec![1.0; d],
            lnf_b: gpt.then(|| vec![0.0; d]),
            cfg,
        }
    }

    /// Synthetic GPT big enough that decode is weight-stream bound (the
    /// regime batching is supposed to win in). Shared by
    /// `benches/serving.rs`, `benches/latency.rs`, and
    /// `examples/serve.rs --gateway` as the fallback when `make
    /// artifacts` hasn't been run — all three must serve the *same*
    /// weights so their greedy tokens are comparable.
    pub fn synth_model() -> Model {
        let cfg = ModelConfig {
            name: "synthetic-gpt".into(),
            arch: Arch::Gpt,
            d_model: 128,
            n_layer: 4,
            n_head: 8,
            d_ff: 512,
            vocab: 256,
            max_seq: 128,
            eps: 1e-5,
            rope_theta: 10000.0,
            kv_dtype: crate::kv::KvDtype::F32,
        };
        let mut rng = Rng::seed_from_u64(42);
        let mut m = |r: usize, c: usize| {
            let s = 1.0 / (c as f32).sqrt();
            Matrix::from_vec(r, c, (0..r * c).map(|_| rng.range_f32(-s, s)).collect())
        };
        let (d, f) = (cfg.d_model, cfg.d_ff);
        let blocks = (0..cfg.n_layer)
            .map(|i| {
                let p = |s: &str| format!("block{i}.{s}");
                let mut nl = |name: &str, key: &str, r: usize, c: usize| NamedLinear {
                    name: p(name),
                    stats_key: p(key),
                    lin: Linear::Plain(m(r, c)),
                };
                Block {
                    ln1_g: vec![1.0; d],
                    ln1_b: Some(vec![0.0; d]),
                    q: nl("attn.q", "attn.in", d, d),
                    k: nl("attn.k", "attn.in", d, d),
                    v: nl("attn.v", "attn.in", d, d),
                    o: nl("attn.o", "attn.o.in", d, d),
                    ln2_g: vec![1.0; d],
                    ln2_b: Some(vec![0.0; d]),
                    ff1: nl("mlp.ff1", "mlp.in", f, d),
                    ff2: nl("mlp.ff2", "mlp.ff2.in", d, f),
                    ff3: None,
                }
            })
            .collect();
        Model {
            tok_emb: m(cfg.vocab, d),
            pos_emb: Some(m(cfg.max_seq, d)),
            blocks,
            lnf_g: vec![1.0; d],
            lnf_b: Some(vec![0.0; d]),
            cfg,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::tiny_model;
    use super::*;

    #[test]
    fn config_param_count_matches_shapes() {
        let m = tiny_model(Arch::Gpt, 1);
        let lin: usize = m.linears().iter().map(|l| {
            match &l.lin {
                Linear::Plain(w) => w.len(),
                _ => 0,
            }
        }).sum();
        let expect: usize = m.cfg.linear_shapes().iter().map(|(o, i)| o * i).sum();
        assert_eq!(lin, expect);
    }

    #[test]
    fn llama_has_gate_and_no_pos_emb() {
        let m = tiny_model(Arch::Llama, 2);
        assert!(m.pos_emb.is_none());
        assert!(m.blocks[0].ff3.is_some());
        assert_eq!(m.linears().len(), 2 * 7);
    }

    #[test]
    fn compress_then_decompress_roundtrips_dense_view() {
        let mut m = tiny_model(Arch::Gpt, 3);
        let orig: Vec<Matrix> =
            m.linears().iter().map(|l| l.lin.dense_view().into_owned()).collect();
        let calib = crate::sdq::calib::CalibStats::new(false);
        let cfg: CompressionConfig = "Q-VSQuant-WAint8".parse().unwrap();
        let reports = m.compress(&cfg, &calib).unwrap();
        assert_eq!(reports.len(), 12);
        for r in &reports {
            assert!(r.rel_err < 0.02, "{}: {}", r.name, r.rel_err);
        }
        m.decompress();
        for (l, o) in m.linears().iter().zip(&orig) {
            let now = l.lin.dense_view();
            assert!(now.rel_frob_dist(o) < 0.02);
        }
    }

    #[test]
    fn plain_dense_view_borrows_without_cloning() {
        let m = tiny_model(Arch::Gpt, 7);
        let l = &m.linears()[0].lin;
        let v = l.dense_view();
        assert!(matches!(v, Cow::Borrowed(_)));
        if let Linear::Plain(w) = l {
            assert!(std::ptr::eq(&*v, w));
        } else {
            panic!("tiny model starts plain");
        }
    }

    #[test]
    fn packed_weight_plane_strips_to_bit_identical_forward() {
        let mut m = tiny_model(Arch::Gpt, 5);
        let calib = crate::sdq::calib::CalibStats::new(false);
        let cfg: CompressionConfig = "Q-VSQuant-WAint8".parse().unwrap();
        m.compress(&cfg, &calib).unwrap();
        // int8 codes + fp8 scales cut dense-plane traffic ~3.66× at
        // serving widths (asserted ≥3.5 in benches/serving.rs); the
        // tiny 32-dim test model pays 4 B of chan-scale per 32-weight
        // row, so the floor here is 3.0.
        let (streamed, avoided) = m.weight_stream_bytes();
        let dense = streamed + avoided;
        assert!(
            dense as f64 / streamed as f64 >= 3.0,
            "int8 plane only cut {dense}/{streamed}"
        );
        assert!(m.weight_bytes() < dense / 3);
        let x = Matrix::from_vec(3, 32, (0..96).map(|i| (i as f32).sin()).collect());
        let mut with_q = Matrix::zeros(3, 32);
        m.linears()[0].lin.forward_into(&x, &mut with_q);
        m.strip_packed_weights();
        // Stripping reverts to the f32 view: traffic goes dense again…
        assert_eq!(m.weight_stream_bytes(), (dense, 0));
        let mut without_q = Matrix::zeros(3, 32);
        m.linears()[0].lin.forward_into(&x, &mut without_q);
        // …and the outputs match to the bit.
        for (a, b) in with_q.data.iter().zip(&without_q.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn double_compress_fails() {
        let mut m = tiny_model(Arch::Gpt, 4);
        let calib = crate::sdq::calib::CalibStats::new(false);
        let cfg: CompressionConfig = "Q-VSQuant-WAint8".parse().unwrap();
        m.compress(&cfg, &calib).unwrap();
        assert!(m.compress(&cfg, &calib).is_err());
    }
}
