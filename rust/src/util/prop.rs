//! Tiny property-based testing driver (no external `proptest`).
//!
//! [`check`] runs a property over `n` deterministically-seeded random
//! cases; on failure it reports the case index and seed so the case
//! reproduces exactly. Generators are plain closures over
//! [`crate::util::rng::Rng`].

use super::rng::Rng;

/// Derive a decorrelated seed for a case index.
#[inline]
pub fn seed_for_case(case: u64) -> u64 {
    case.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(0x517cc1b727220a95)
}

/// Run `prop(rng)` for `cases` seeded cases; panic with the failing seed.
///
/// The property receives a fresh deterministic RNG per case. Use the RNG
/// for all randomness so a failure reproduces from the printed seed.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let seed = seed_for_case(case);
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Random dimension that is a multiple of `mult` within `[lo, hi]`.
pub fn dim_multiple(rng: &mut Rng, mult: usize, lo: usize, hi: usize) -> usize {
    let lo_m = lo.div_ceil(mult);
    let hi_m = hi / mult;
    assert!(hi_m >= lo_m, "no multiple of {mult} in [{lo}, {hi}]");
    mult * (lo_m + rng.below(hi_m - lo_m + 1))
}

/// Assert two slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 20, |rng| {
            let v = rng.below(10);
            if v < 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn check_reports_failures() {
        check("fails", 5, |rng| {
            if rng.below(2) == 0 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn dim_multiple_respects_bounds() {
        let mut rng = Rng::seed_from_u64(1);
        for _ in 0..100 {
            let d = dim_multiple(&mut rng, 8, 16, 128);
            assert!(d % 8 == 0 && (16..=128).contains(&d));
        }
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.00001], 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
