//! Fig. 11 — sensitivity to the scale-factor number format:
//! ufp8-e6m2 vs fp8-e4m3 per-vector scales for int8 dual quant, fp4 dual
//! quant, and the headline SDQ configuration.

use sdq::formats::NumFormat;
use sdq::harness;
use sdq::sdq::config::CompressionConfig;
use sdq::util::bench::Table;

fn main() {
    if !harness::artifacts_ready() {
        return;
    }
    let mname = "gpt-micro";
    let model = harness::load_model(mname).expect("model");
    let ds = harness::load_dataset().expect("corpus");
    let ecfg = harness::eval_cfg_for(&model, false);

    let mut table = Table::new(
        &format!("Fig 11: scale-factor-format sensitivity — {mname}"),
        &["Configuration", "ufp8-e6m2", "fp8-e4m3"],
    );
    for cfg_str in ["Q-VSQuant-WAint8", "Q-VSQuant-WAfp4", "SDQ-W7:8-1:8int8-6:8fp4"] {
        let mut cells = vec![cfg_str.to_string()];
        for scale_fmt in [NumFormat::UFp8E6M2, NumFormat::Fp8E4M3] {
            let mut cfg: CompressionConfig = cfg_str.parse().unwrap();
            cfg.scale_fmt = scale_fmt;
            match harness::eval_config(&model, &ds, &cfg, ecfg) {
                Ok(r) => {
                    eprintln!("  {cfg_str} scale={scale_fmt}: {:.3}", r.ppl.ppl);
                    cells.push(format!("{:.3}", r.ppl.ppl));
                }
                Err(e) => cells.push(format!("err {e}")),
            }
        }
        table.row(cells);
    }
    table.print();
    table.save_json("fig11_scalefmt");
    println!("\nExpected shape: fp8-e4m3 column ≤ ufp8-e6m2 column everywhere (paper Fig. 11).");
}
