//! Fig. 10 — sensitivity to the decomposition metric: magnitude /
//! weight-activation-product / quantization-error saliency, each with
//! Large (descending) or Small (ascending) selection order, on the
//! headline SDQ-W7:8-1:8int8-6:8fp4 configuration.

use sdq::harness;
use sdq::sdq::config::{CompressionConfig, DecompMetric, DecompOrder, Stages};
use sdq::util::bench::Table;

fn main() {
    if !harness::artifacts_ready() {
        return;
    }
    let mname = "gpt-micro";
    let model = harness::load_model(mname).expect("model");
    let ds = harness::load_dataset().expect("corpus");
    let ecfg = harness::eval_cfg_for(&model, false);

    let mut table = Table::new(
        &format!("Fig 10: decomposition-metric sensitivity — {mname} SDQ-W7:8-1:8int8-6:8fp4"),
        &["Metric", "Order", "ppl", "Δ vs product-large %"],
    );
    let mut results = Vec::new();
    for (metric, mn) in [
        (DecompMetric::Magnitude, "magnitude"),
        (DecompMetric::Product, "product"),
        (DecompMetric::Error, "error"),
    ] {
        for (order, on) in [(DecompOrder::Large, "Large"), (DecompOrder::Small, "Small")] {
            let mut cfg: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
            if let Stages::Sdq { decompose, .. } = &mut cfg.stages {
                decompose.metric = metric;
                decompose.order = order;
            }
            match harness::eval_config(&model, &ds, &cfg, ecfg) {
                Ok(r) => {
                    eprintln!("  {mn}/{on}: {:.3}", r.ppl.ppl);
                    results.push((mn, on, r.ppl.ppl));
                }
                Err(e) => eprintln!("  {mn}/{on}: {e}"),
            }
        }
    }
    let reference = results
        .iter()
        .find(|(m, o, _)| *m == "product" && *o == "Large")
        .map(|(_, _, p)| *p)
        .unwrap_or(f64::NAN);
    for (m, o, p) in &results {
        table.row(vec![
            m.to_string(),
            o.to_string(),
            format!("{p:.3}"),
            format!("{:+.2}", (p - reference) / reference * 100.0),
        ]);
    }
    table.print();
    table.save_json("fig10_decomp");
    println!("\nExpected shape: product/Large best; Small orders clearly worse (paper: up to ~7% swing).");
}
