//! Regenerate every paper table/figure in one run and write a combined
//! markdown report to `target/paper_tables.md` (the EXPERIMENTS.md
//! source). This is the long-running full-eval driver; the individual
//! `cargo bench --bench …` targets run the same experiments one at a
//! time.
//!
//! Run: `cargo run --release --example paper_tables [-- --fast]`

use std::fmt::Write as _;

use sdq::eval::zeroshot;
use sdq::harness;
use sdq::sdq::config::CompressionConfig;

fn main() -> sdq::Result<()> {
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let args = sdq::util::cli::Args::parse();
    let fast = args.has("fast");
    let ds = harness::load_dataset()?;
    let mut md = String::new();
    writeln!(md, "# Paper tables — measured\n").unwrap();

    for (title, prefix) in [("Table 2 (GPT family)", "gpt-"), ("Table 3 (LLaMA family)", "llama-")] {
        let models = harness::available_models(prefix);
        writeln!(md, "## {title}\n").unwrap();
        write!(md, "| Configuration | Tput |").unwrap();
        for m in &models {
            write!(md, " {m} |").unwrap();
        }
        writeln!(md).unwrap();
        write!(md, "|---|---|").unwrap();
        for _ in &models {
            write!(md, "---|").unwrap();
        }
        writeln!(md).unwrap();
        let mut baselines = vec![f64::NAN; models.len()];
        for cfg_str in harness::table2_configs() {
            let cfg: CompressionConfig = cfg_str.parse().unwrap();
            write!(md, "| {cfg_str} | {:.2}x |", cfg.effective_throughput()).unwrap();
            for (mi, mname) in models.iter().enumerate() {
                let model = harness::load_model(mname)?;
                let ecfg = harness::eval_cfg_for(&model, !fast);
                match harness::eval_config(&model, &ds, &cfg, ecfg) {
                    Ok(r) => {
                        if cfg_str == "Dense-WA16" {
                            baselines[mi] = r.ppl.ppl;
                        }
                        let d = (r.ppl.ppl - baselines[mi]) / baselines[mi] * 100.0;
                        write!(md, " {:.3} ({d:+.1}%) |", r.ppl.ppl).unwrap();
                        eprintln!("{title} {mname} {cfg_str}: {:.3}", r.ppl.ppl);
                    }
                    Err(e) => {
                        write!(md, " err |").unwrap();
                        eprintln!("{title} {mname} {cfg_str}: {e}");
                    }
                }
            }
            writeln!(md).unwrap();
        }
        writeln!(md).unwrap();
    }

    // Table 4.
    writeln!(md, "## Table 4 (zero-shot)\n").unwrap();
    let per_task = if fast { 15 } else { 30 };
    let tasks = zeroshot::build_tasks(&ds, per_task, 42);
    let configs = [
        "Dense-WA16",
        "S-SparseGPT-2:8",
        "S-Wanda-2:8",
        "Q-VSQuant-WAint4",
        "Q-VSQuant-WAfp4",
        "SDQ-7:8-1:8int8-6:8fp4",
    ];
    let mut models = vec!["gpt-micro".to_string()];
    models.extend(harness::available_models("llama-"));
    for mname in &models {
        writeln!(md, "### {mname}\n").unwrap();
        write!(md, "| Method |").unwrap();
        for t in &tasks {
            write!(md, " {} |", t.name).unwrap();
        }
        writeln!(md, " Average |").unwrap();
        write!(md, "|---|").unwrap();
        for _ in 0..tasks.len() + 1 {
            write!(md, "---|").unwrap();
        }
        writeln!(md).unwrap();
        let base = harness::load_model(mname)?;
        for cfg_str in configs {
            let cfg: CompressionConfig = cfg_str.parse().unwrap();
            let mut model = base.clone();
            let calib = harness::calibrate(&model, &ds, 1536, harness::needs_gram(&cfg));
            model.compress(&cfg, &calib)?;
            let (results, avg) = zeroshot::eval_suite(&model, &tasks);
            write!(md, "| {cfg_str} |").unwrap();
            for r in &results {
                write!(md, " {:.2} |", r.accuracy).unwrap();
            }
            writeln!(md, " **{avg:.2}** |").unwrap();
            eprintln!("table4 {mname} {cfg_str}: avg {avg:.2}%");
        }
        writeln!(md).unwrap();
    }

    let out = harness::repo_root().join("target/paper_tables.md");
    std::fs::create_dir_all(out.parent().unwrap())?;
    std::fs::write(&out, &md)?;
    println!("\nwrote {}", out.display());
    Ok(())
}
