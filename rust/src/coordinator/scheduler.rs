//! Continuous-batching scheduler.
//!
//! Each scheduling **round**: admit + prefill a bounded burst of waiting
//! requests, then decode one token for every active sequence. Decode
//! parallelism is across sequences (each sequence's single-token GEMMs
//! are too small to parallelize internally); prefill parallelism is
//! inside the GEMMs (prompt rows). Completed sequences retire at the end
//! of the round.

use std::time::Instant;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{InFlight, Response};
use crate::model::generate::KvCache;
use crate::model::Model;
use crate::util::par::par_chunks_mut;

/// Scheduler over a (possibly compressed) model.
pub struct Scheduler<'m> {
    model: &'m Model,
    pub policy: BatchPolicy,
    active: Vec<InFlight>,
    pub metrics: Metrics,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m Model, policy: BatchPolicy) -> Self {
        Scheduler { model, policy, active: Vec::new(), metrics: Metrics::default() }
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Whether any work remains (active or waiting).
    pub fn has_work(&self, batcher: &Batcher) -> bool {
        !self.active.is_empty() || batcher.waiting() > 0
    }

    /// KV bytes a single sequence costs in this engine (fixed-size cache).
    pub fn kv_bytes_per_seq(&self) -> usize {
        self.model.cfg.n_layer * self.model.cfg.max_seq * self.model.cfg.d_model * 4 * 2
    }

    /// One scheduling round. Returns completed responses.
    pub fn round(&mut self, batcher: &mut Batcher) -> Vec<Response> {
        let t0 = Instant::now();
        // ---- admission + prefill ----
        let kv_per = self.kv_bytes_per_seq();
        let kv_in_use = self.active.len() * kv_per;
        let mut admitted =
            batcher.admit(&self.policy, self.active.len(), kv_in_use, kv_per);
        for f in &mut admitted {
            f.started = Some(Instant::now());
            let mut cache = KvCache::new(self.model);
            // Clamp over-long prompts to leave ≥1 slot for generation.
            let keep = f.req.prompt.len().min(self.model.cfg.max_seq - 1);
            let prompt = &f.req.prompt[f.req.prompt.len() - keep..];
            let logits = self.model.forward_cached(prompt, &mut cache);
            self.metrics.prefill_tokens += prompt.len() as u64;
            let tok = self.model.sample(&logits, f.req.temperature, &mut f.rng);
            f.generated.push(tok);
            f.first_token = Some(Instant::now());
            f.cache = Some(cache);
        }
        self.active.append(&mut admitted);

        // ---- decode one token for all active (parallel across seqs) ----
        let model = self.model;
        par_chunks_mut(&mut self.active, 1, |_i, slot| {
            let f = &mut slot[0];
            if f.remaining() == 0 {
                return;
            }
            let cache = f.cache.as_mut().expect("prefilled");
            if cache.remaining() == 0 {
                return;
            }
            let last = *f.generated.last().expect("has first token");
            let logits = model.forward_cached(&[last], cache);
            let tok = model.sample(&logits, f.req.temperature, &mut f.rng);
            f.generated.push(tok);
        });
        self.metrics.decode_rounds += 1;

        // ---- retire completed ----
        let mut done = Vec::new();
        let mut still = Vec::with_capacity(self.active.len());
        for f in self.active.drain(..) {
            let out_of_cache =
                f.cache.as_ref().map(|c| c.remaining() == 0).unwrap_or(false);
            if f.remaining() == 0 || out_of_cache {
                let resp = f.finish();
                self.metrics.requests_completed += 1;
                self.metrics.tokens_generated += resp.tokens.len() as u64;
                self.metrics.ttft.record(resp.timing.ttft);
                self.metrics.total_latency.record(resp.timing.total);
                done.push(resp);
            } else {
                still.push(f);
            }
        }
        self.active = still;
        self.metrics.serve_time += t0.elapsed();
        done
    }

    /// Drive rounds until the queue and active set drain.
    pub fn run_to_completion(&mut self, batcher: &mut Batcher) -> Vec<Response> {
        let mut out = Vec::new();
        while self.has_work(batcher) {
            out.extend(self.round(batcher));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::model::testutil::tiny_model;
    use crate::model::Arch;

    #[test]
    fn serves_all_requests() {
        let model = tiny_model(Arch::Gpt, 1);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        for i in 0..6 {
            batcher.enqueue(Request::new(i, vec![(i + 65) as u8; 4], 5));
        }
        let responses = sched.run_to_completion(&mut batcher);
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert_eq!(r.tokens.len(), 5);
            assert!(r.timing.ttft <= r.timing.total);
        }
        assert_eq!(sched.metrics.requests_completed, 6);
        assert_eq!(sched.metrics.tokens_generated, 30);
    }

    #[test]
    fn deterministic_greedy_matches_generate() {
        let model = tiny_model(Arch::Llama, 2);
        let prompt = b"abcd".to_vec();
        let direct = model.generate(&prompt, 6, 0.0, 0);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, prompt, 6));
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp[0].tokens, direct);
    }

    #[test]
    fn respects_max_active() {
        let model = tiny_model(Arch::Gpt, 3);
        let policy = BatchPolicy { max_active: 2, max_prefill_per_round: 2, ..Default::default() };
        let mut sched = Scheduler::new(&model, policy);
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 2], 3));
        }
        let _ = sched.round(&mut batcher);
        assert!(sched.active() <= 2);
        let all = sched.run_to_completion(&mut batcher);
        assert_eq!(all.len() + 0, 4);
    }

    #[test]
    fn long_prompt_is_clamped() {
        let model = tiny_model(Arch::Gpt, 4);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, vec![66u8; 200], 4)); // > max_seq=64
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp.len(), 1);
        assert!(!resp[0].tokens.is_empty());
    }
}
