"""L2: the JAX transformer (decoder-only LM), numerically mirroring the
Rust inference engine (`rust/src/model/`).

Two architecture flavours (paper's evaluation families):
* ``gpt``   — OPT-style: learned positional embeddings, LayerNorm, GELU.
* ``llama`` — LLaMA-style: RoPE, RMSNorm, SwiGLU.

`forward` is the trainable fp32 graph; `forward_sdq` swaps every linear
layer for the L1 Pallas decomposed dual-quantized GEMM (`sdq_matmul`),
which is what `aot.py` lowers for the serving artifact.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.sdq_matmul import sdq_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str  # "gpt" | "llama"
    d_model: int
    n_layer: int
    n_head: int
    d_ff: int
    vocab: int = 256
    max_seq: int = 128
    eps: float = 1e-5
    rope_theta: float = 10000.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_head

    def linear_names(self) -> list[str]:
        names = []
        for i in range(self.n_layer):
            names += [f"block{i}.attn.{x}" for x in ("q", "k", "v", "o")]
            names += [f"block{i}.mlp.ff1", f"block{i}.mlp.ff2"]
            if self.arch == "llama":
                names.append(f"block{i}.mlp.ff3")
        return names


def init_params(cfg: ModelConfig, key) -> dict:
    """Scaled-normal init; weights stored `[out, in]` like the Rust side."""
    params = {}
    keys = iter(jax.random.split(key, 64 + 16 * cfg.n_layer))

    def mat(rows, cols, std):
        return (jax.random.normal(next(keys), (rows, cols)) * std).astype(jnp.float32)

    d, f = cfg.d_model, cfg.d_ff
    params["tok_emb"] = mat(cfg.vocab, d, 0.02)
    if cfg.arch == "gpt":
        params["pos_emb"] = mat(cfg.max_seq, d, 0.01)
    res_std = 0.02 / math.sqrt(2 * cfg.n_layer)
    for i in range(cfg.n_layer):
        p = f"block{i}."
        std = 1.0 / math.sqrt(d)
        params[p + "attn.q"] = mat(d, d, std)
        params[p + "attn.k"] = mat(d, d, std)
        params[p + "attn.v"] = mat(d, d, std)
        params[p + "attn.o"] = mat(d, d, res_std)
        params[p + "mlp.ff1"] = mat(f, d, std)
        params[p + "mlp.ff2"] = mat(d, f, res_std)
        if cfg.arch == "llama":
            params[p + "mlp.ff3"] = mat(f, d, std)
        params[p + "ln1.g"] = jnp.ones((1, d), jnp.float32)
        params[p + "ln2.g"] = jnp.ones((1, d), jnp.float32)
        if cfg.arch == "gpt":
            params[p + "ln1.b"] = jnp.zeros((1, d), jnp.float32)
            params[p + "ln2.b"] = jnp.zeros((1, d), jnp.float32)
    params["ln_f.g"] = jnp.ones((1, d), jnp.float32)
    if cfg.arch == "gpt":
        params["ln_f.b"] = jnp.zeros((1, d), jnp.float32)
    return params


def _layernorm(x, g, b, eps):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    y = (x - mean) / jnp.sqrt(var + eps) * g[0]
    return y + b[0] if b is not None else y


def _rmsnorm(x, g, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * g[0]


def _norm(cfg, params, prefix, x):
    if cfg.arch == "gpt":
        return _layernorm(x, params[prefix + ".g"], params[prefix + ".b"], cfg.eps)
    return _rmsnorm(x, params[prefix + ".g"], cfg.eps)


def _rope(x, theta_base):
    """Interleaved-pair RoPE over `[B, S, H, dh]` (matches rust
    `rope_inplace`: pairs (2i, 2i+1), theta = pos / base^(2i/dh))."""
    b, s, h, dh = x.shape
    pos = jnp.arange(s, dtype=jnp.float32)[None, :, None, None]
    i = jnp.arange(dh // 2, dtype=jnp.float32)[None, None, None, :]
    theta = pos / jnp.power(theta_base, 2.0 * i / dh)
    sin, cos = jnp.sin(theta), jnp.cos(theta)
    x2 = x.reshape(b, s, h, dh // 2, 2)
    a, bb = x2[..., 0], x2[..., 1]
    rot = jnp.stack([a * cos - bb * sin, a * sin + bb * cos], axis=-1)
    return rot.reshape(b, s, h, dh)


def _attention(cfg: ModelConfig, q, k, v):
    """Causal MHA over `[B, S, D]` projections."""
    b, s, d = q.shape
    h, dh = cfg.n_head, cfg.head_dim
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, h, dh)
    v = v.reshape(b, s, h, dh)
    if cfg.arch == "llama":
        q = _rope(q, cfg.rope_theta)
        k = _rope(k, cfg.rope_theta)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(dh)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(b, s, d)


def forward(cfg: ModelConfig, params: dict, tokens, linear_fn=None):
    """Logits `[B, S, vocab]` for int32 tokens `[B, S]`.

    `linear_fn(name, x2d) -> y2d` overrides linear execution (used by
    `forward_sdq`); default is plain `x @ Wᵀ`.
    """
    if linear_fn is None:
        def linear_fn(name, x2d):
            return x2d @ params[name].T

    b, s = tokens.shape
    x = params["tok_emb"][tokens]
    if cfg.arch == "gpt":
        x = x + params["pos_emb"][None, :s]

    def lin(name, t3d, out_dim):
        y = linear_fn(name, t3d.reshape(b * s, -1))
        return y.reshape(b, s, out_dim)

    d, f = cfg.d_model, cfg.d_ff
    for i in range(cfg.n_layer):
        p = f"block{i}."
        h = _norm(cfg, params, p + "ln1", x)
        q = lin(p + "attn.q", h, d)
        k = lin(p + "attn.k", h, d)
        v = lin(p + "attn.v", h, d)
        attn = _attention(cfg, q, k, v)
        x = x + lin(p + "attn.o", attn, d)
        h = _norm(cfg, params, p + "ln2", x)
        a = lin(p + "mlp.ff1", h, f)
        if cfg.arch == "gpt":
            a = jax.nn.gelu(a, approximate=True)
        else:
            a = jax.nn.silu(a) * lin(p + "mlp.ff3", h, f)
        x = x + lin(p + "mlp.ff2", a, d)

    x = _norm(cfg, params, "ln_f", x)
    return x @ params["tok_emb"].T  # tied LM head


def loss_fn(cfg: ModelConfig, params: dict, inputs, targets):
    """Mean next-token cross-entropy in nats."""
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# SDQ serving graph: linears run the L1 Pallas kernel.
# ---------------------------------------------------------------------------

def compress_params_sdq(cfg: ModelConfig, params: dict, *, n_out=1, m=8,
                        qvec=16, outlier_fmt="int8", inlier_fmt="fp4"):
    """Build the SDQ serving parameter set: per-linear outlier/inlier
    codes + scales (magnitude decomposition — the calibration-free
    configuration), everything else passed through."""
    out = {}
    lin_names = set(cfg.linear_names())
    for name, w in params.items():
        if name in lin_names:
            wo, wi = ref.decompose_local_outliers(jnp.asarray(w), n_out, m)
            woc, wos = ref.quantize_weight_codes(wo, outlier_fmt, qvec)
            wic, wis = ref.quantize_weight_codes(wi, inlier_fmt, qvec)
            out[name + ".woc"] = woc
            out[name + ".wos"] = wos
            out[name + ".wic"] = wic
            out[name + ".wis"] = wis
        else:
            out[name] = jnp.asarray(w)
    return out


def forward_sdq(cfg: ModelConfig, sdq_params: dict, tokens, *, qvec=16,
                outlier_fmt="int8", inlier_fmt="fp4", interpret=True):
    """Forward pass where every linear layer executes the Pallas
    decomposed dual-quantized GEMM (the graph `aot.py` lowers)."""
    lin_names = set(cfg.linear_names())

    def linear_fn(name, x2d):
        if name not in lin_names:  # pragma: no cover - defensive
            raise KeyError(name)
        return sdq_matmul(
            x2d,
            sdq_params[name + ".woc"],
            sdq_params[name + ".wos"],
            sdq_params[name + ".wic"],
            sdq_params[name + ".wis"],
            qvec=qvec,
            outlier_fmt=outlier_fmt,
            inlier_fmt=inlier_fmt,
            interpret=interpret,
        )

    return forward(cfg, sdq_params, tokens, linear_fn=linear_fn)


# Model family registry (paper's OPT / LLaMA size ladders, scaled to this
# testbed — see DESIGN.md substitutions).
FAMILY = {
    "gpt-nano": ModelConfig("gpt-nano", "gpt", 48, 2, 4, 192),
    "gpt-micro": ModelConfig("gpt-micro", "gpt", 96, 3, 4, 384),
    "gpt-tiny": ModelConfig("gpt-tiny", "gpt", 160, 4, 4, 640),
    "gpt-small": ModelConfig("gpt-small", "gpt", 224, 4, 8, 896),
    "llama-micro": ModelConfig("llama-micro", "llama", 96, 3, 4, 256),
    "llama-tiny": ModelConfig("llama-tiny", "llama", 160, 4, 4, 432),
}
