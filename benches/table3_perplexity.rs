//! Table 3 — perplexity of the LLaMA-family stand-ins under every
//! compression configuration (`cargo bench --bench table3_perplexity`).

use sdq::harness;
use sdq::sdq::config::CompressionConfig;
use sdq::util::bench::Table;

fn main() {
    if !harness::artifacts_ready() {
        return;
    }
    let models = harness::available_models("llama-");
    if models.is_empty() {
        eprintln!("no llama-* models trained");
        return;
    }
    let ds = harness::load_dataset().expect("corpus");
    let full = std::env::var("SDQ_FULL_EVAL").is_ok();

    let mut headers: Vec<&str> = vec!["Configuration", "Tput"];
    headers.extend(models.iter().map(|s| s.as_str()));
    let mut table = Table::new(
        "Table 3: LLaMA-family perplexity on held-out corpus (lower is better)",
        &headers,
    );
    let mut baselines = vec![f64::NAN; models.len()];
    for cfg_str in harness::table2_configs() {
        let cfg: CompressionConfig = cfg_str.parse().unwrap();
        let mut row =
            vec![cfg_str.to_string(), format!("{:.2}x", cfg.effective_throughput())];
        for (mi, mname) in models.iter().enumerate() {
            let model = harness::load_model(mname).expect("model");
            let ecfg = harness::eval_cfg_for(&model, full);
            match harness::eval_config(&model, &ds, &cfg, ecfg) {
                Ok(r) => {
                    if cfg_str == "Dense-WA16" {
                        baselines[mi] = r.ppl.ppl;
                    }
                    let delta = (r.ppl.ppl - baselines[mi]) / baselines[mi] * 100.0;
                    row.push(format!("{:.3} ({:+.1}%)", r.ppl.ppl, delta));
                    eprintln!("  {mname} {cfg_str}: ppl {:.3}", r.ppl.ppl);
                }
                Err(e) => row.push(format!("err: {e}")),
            }
        }
        table.row(row);
    }
    table.print();
    table.save_json("table3_perplexity");
}
