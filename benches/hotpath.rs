//! Hot-path microbenchmarks: the kernels the eval/serving stack spends
//! its time in. Drives the §Perf optimization loop (EXPERIMENTS.md).
//!
//! Covers: dense GEMM, the fused quantized-weight GEMM (`gemm-q8` /
//! `gemm-q4`: QuantMat codes decoded in register, `matmul_q_into`),
//! packed N:M SpMM at several densities (validating
//! `PACK_DENSITY_THRESHOLD`) plus the fused-dequant int8-value SpMM,
//! paged attention over the KV pool (f32 zero-copy, quantized via the
//! scratch-dequant route vs the quantized-domain `kv::qattn` route),
//! dynamic activation quantization, the compression pipeline itself,
//! and the simulated tensor core.
//!
//! `--smoke` keeps every shape (so row names — the CI baseline keys —
//! are identical to a full run) but shrinks the per-bench minimum
//! runtime: the CI guard that keeps the bench compiling, running, and
//! feeding `BENCH_hotpath.json` (cwd) to the bench-regression gate
//! alongside the usual `target/bench-results/hotpath.json` record.

use sdq::formats::NumFormat;
use sdq::kv::{BlockPool, BlockTable, KvDtype, KvScratch};
use sdq::model::forward::{paged_attention, KvSegs, SeqKv};
use sdq::model::{Arch, ModelConfig};
use sdq::perfmodel::simtc::TensorCoreSpec;
use sdq::sdq::nm::{topn_block_mask, NmPattern};
use sdq::sdq::packed::pack;
use sdq::sdq::pipeline::compress_layer;
use sdq::sdq::qmat::QuantMat;
use sdq::sdq::quantize::{fake_quant_dynamic_inplace, quantize_tensor, VsQuantCfg};
use sdq::tensor::{matmul_into, matmul_q_into, Matrix};
use sdq::util::bench::{bench, report, Measurement, Table};
use sdq::util::rng::Rng;

fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::seed_from_u64(seed);
    Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.range_f32(-1.0, 1.0)).collect())
}

fn sparse_matrix(rows: usize, cols: usize, pat: NmPattern, seed: u64) -> Matrix {
    let mut w = rand_matrix(rows, cols, seed);
    let mut mask = vec![false; cols];
    for r in 0..rows {
        let row = w.row_mut(r);
        let scores: Vec<f32> = row.iter().map(|v| v.abs()).collect();
        topn_block_mask(&scores, pat, &mut mask);
        for (v, keep) in row.iter_mut().zip(&mask) {
            if !keep {
                *v = 0.0;
            }
        }
    }
    w
}

fn gflops(m: &Measurement, flops: f64) -> String {
    format!("{:.2}", flops / m.median_ns)
}

/// Build a quantity of committed KV state to attend over: `n_seq`
/// tables of `tokens` rows each in a pool of the given dtype.
fn attn_fixture(
    cfg: &ModelConfig,
    dtype: KvDtype,
    n_seq: usize,
    tokens: usize,
) -> (BlockPool, Vec<BlockTable>) {
    let mut pool = BlockPool::with_dtype(cfg, 16 * 1024 * 1024, dtype);
    let mut rng = Rng::seed_from_u64(17);
    let d = cfg.d_model;
    let mut tables = Vec::with_capacity(n_seq);
    for s in 0..n_seq {
        let mut tb = BlockTable::new(cfg.max_seq);
        let toks: Vec<u8> = (0..tokens).map(|t| ((s * 31 + t) % 256) as u8).collect();
        pool.prepare_tokens(&mut tb, tokens);
        for pos in 0..tokens {
            for li in 0..cfg.n_layer {
                let k: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                pool.write_row(&tb, li, pos, &k, &v);
            }
        }
        pool.commit(&mut tb, &toks);
        tables.push(tb);
    }
    (pool, tables)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // Same shapes in smoke mode (row names are the CI baseline keys);
    // only the timing budget shrinks.
    let mrt = |full: u64| if smoke { 30 } else { full };
    let mut table = Table::new("hotpath microbenchmarks", &["bench", "median ms", "GFLOP/s"]);

    // Dense GEMM at serving shapes (prefill + eval batch).
    for (t, k, o) in [(64usize, 384usize, 384usize), (512, 384, 384), (512, 384, 1536)] {
        let x = rand_matrix(t, k, 1);
        let w = rand_matrix(o, k, 2);
        let mut c = Matrix::zeros(t, o);
        let m = bench(&format!("gemm {t}x{k}x{o}"), mrt(300), || {
            matmul_into(&x, &w, &mut c);
            std::hint::black_box(&c);
        });
        report(&m);
        table.row(vec![m.name.clone(), format!("{:.3}", m.median_ms()),
                       gflops(&m, 2.0 * (t * k * o) as f64)]);
    }

    // Fused quantized-weight GEMM: decode QuantMat codes (int8 bytes /
    // fp4 nibbles × fp8 scales) in register inside the same micro-tile
    // schedule as the dense GEMM above. Bit-identical output to
    // dequantize-then-matmul_into (tests/qmat.rs) at ~4× / ~7× less
    // weight traffic; this measures the decode overhead against the
    // `gemm 512x384x384` dense row.
    {
        let (t, k, o) = (512usize, 384usize, 384usize);
        let x = rand_matrix(t, k, 7);
        let w = rand_matrix(o, k, 8);
        let mut c = Matrix::zeros(t, o);
        for (name, fmt) in
            [("gemm-q8", NumFormat::Int(8)), ("gemm-q4", NumFormat::Fp4E2M1)]
        {
            let qt = quantize_tensor(
                &w,
                VsQuantCfg { fmt, qvec: 16, scale_fmt: NumFormat::Fp8E4M3 },
            );
            let qm = QuantMat::try_from_tensor(&qt).expect("format must pack");
            let m = bench(&format!("{name} {t}x{k}x{o}"), mrt(300), || {
                matmul_q_into(&x, &qm, &mut c);
                std::hint::black_box(&c);
            });
            report(&m);
            table.row(vec![m.name.clone(), format!("{:.3}", m.median_ms()),
                           gflops(&m, 2.0 * (t * k * o) as f64)]);
        }
    }

    // Packed SpMM vs dense at several densities (threshold validation),
    // plus the fused-dequant int8-value plane at the same shape.
    let (t, k, o) = (256usize, 512usize, 512usize);
    let x = rand_matrix(t, k, 3);
    for pat in [NmPattern::new(1, 8), NmPattern::new(2, 8), NmPattern::new(4, 8), NmPattern::new(6, 8)] {
        let w = sparse_matrix(o, k, pat, 4);
        let mut p = pack(&w, pat).unwrap();
        let mut c = Matrix::zeros(t, o);
        let m = bench(&format!("spmm {pat} {t}x{k}x{o}"), mrt(300), || {
            c.data.fill(0.0);
            p.spmm_into(&x, &mut c);
            std::hint::black_box(&c);
        });
        report(&m);
        let useful = 2.0 * (t * k * o) as f64 * pat.density();
        table.row(vec![m.name.clone(), format!("{:.3}", m.median_ms()), gflops(&m, useful)]);
        p.quantize_values_int8();
        let mq = bench(&format!("spmm-q8 {pat} {t}x{k}x{o}"), mrt(300), || {
            c.data.fill(0.0);
            p.spmm_into(&x, &mut c);
            std::hint::black_box(&c);
        });
        report(&mq);
        table.row(vec![mq.name.clone(), format!("{:.3}", mq.median_ms()), gflops(&mq, useful)]);
        let mut cd = Matrix::zeros(t, o);
        let md = bench(&format!("gemm-as-dense {pat}"), mrt(300), || {
            matmul_into(&x, &w, &mut cd);
            std::hint::black_box(&cd);
        });
        report(&md);
        table.row(vec![md.name.clone(), format!("{:.3}", md.median_ms()),
                       gflops(&md, 2.0 * (t * k * o) as f64)]);
    }

    // Paged attention over committed pool state, decode shape: 4
    // sequences × 1 new token over a 128-token prefix. The f32 row is
    // the zero-copy reference; each quantized dtype is measured twice —
    // the scratch route (layer_views: dequantize all rows to fp32, then
    // attend) vs the quantized-domain route (layer_code_views +
    // kv::qattn: decode codes in register inside the kernels). The two
    // produce bit-identical outputs (tests/qattn.rs); this measures the
    // staging traffic they don't share.
    {
        let acfg = ModelConfig {
            name: "attn-bench".into(),
            arch: Arch::Gpt,
            d_model: 128,
            n_layer: 1,
            n_head: 8,
            d_ff: 128,
            vocab: 256,
            max_seq: 256,
            eps: 1e-5,
            rope_theta: 10000.0,
            kv_dtype: KvDtype::F32,
        };
        let (n_seq, tokens) = (4usize, 128usize);
        let (nh, dh, d) = (acfg.n_head, acfg.head_dim(), acfg.d_model);
        let q = rand_matrix(n_seq, d, 19);
        let attn_flops = (4 * n_seq * d * tokens) as f64;
        fn seqs_from_f32<'a>(
            views: Vec<(Vec<&'a [f32]>, Vec<&'a [f32]>)>,
            bt: usize,
            past: usize,
        ) -> Vec<SeqKv<'a>> {
            views
                .into_iter()
                .enumerate()
                .map(|(i, (kk, vv))| SeqKv {
                    q_row0: i,
                    n_new: 1,
                    past,
                    segs: KvSegs::F32 { k: kk, v: vv },
                    seg_tokens: bt,
                })
                .collect()
        }
        for dtype in [KvDtype::F32, KvDtype::Int8, KvDtype::Fp8E4M3] {
            let (pool, tables) = attn_fixture(&acfg, dtype, n_seq, tokens);
            let tb_refs: Vec<&BlockTable> = tables.iter().collect();
            let uptos = vec![tokens; n_seq];
            let bt = pool.block_tokens();
            let mut scratch = KvScratch::new();
            let route = if dtype == KvDtype::F32 { "zero-copy" } else { "scratch" };
            let m = bench(
                &format!("attn-{route} {} {n_seq}x{tokens}", dtype.tag()),
                mrt(200),
                || {
                    let views = pool.layer_views(&tb_refs, 0, &uptos, &mut scratch);
                    let seqs = seqs_from_f32(views, bt, tokens - 1);
                    let o = paged_attention(&q, &seqs, nh, dh, None);
                    std::hint::black_box(&o);
                },
            );
            report(&m);
            table.row(vec![m.name.clone(), format!("{:.3}", m.median_ms()),
                           gflops(&m, attn_flops)]);
            if dtype == KvDtype::F32 {
                continue;
            }
            let mq = bench(
                &format!("attn-qdomain {} {n_seq}x{tokens}", dtype.tag()),
                mrt(200),
                || {
                    let seqs: Vec<SeqKv> = pool
                        .layer_code_views(&tb_refs, 0, &uptos)
                        .into_iter()
                        .enumerate()
                        .map(|(i, (kk, vv))| SeqKv {
                            q_row0: i,
                            n_new: 1,
                            past: tokens - 1,
                            segs: KvSegs::Quant { dtype, k: kk, v: vv },
                            seg_tokens: bt,
                        })
                        .collect();
                    let o = paged_attention(&q, &seqs, nh, dh, None);
                    std::hint::black_box(&o);
                },
            );
            report(&mq);
            table.row(vec![mq.name.clone(), format!("{:.3}", mq.median_ms()),
                           gflops(&mq, attn_flops)]);
        }
    }

    // Dynamic activation quantization.
    for fmt in [NumFormat::Int(8), NumFormat::Fp4E2M1] {
        let mut x = rand_matrix(512, 384, 5);
        let m = bench(&format!("act-quant {fmt} 512x384"), mrt(200), || {
            fake_quant_dynamic_inplace(&mut x, fmt, 16);
            std::hint::black_box(&x);
        });
        report(&m);
        table.row(vec![m.name.clone(), format!("{:.3}", m.median_ms()),
                       format!("{:.2}", (512 * 384) as f64 / m.median_ns)]);
    }

    // Compression pipeline cost (per layer).
    let w = rand_matrix(384, 384, 6);
    for cfg_str in ["Q-VSQuant-WAint4", "SDQ-8:8-1:8int8-7:8fp4"] {
        let mut cfg: sdq::sdq::config::CompressionConfig = cfg_str.parse().unwrap();
        // Calibration-free microbench: magnitude decomposition metric.
        if let sdq::sdq::config::Stages::Sdq { decompose, .. } = &mut cfg.stages {
            decompose.metric = sdq::sdq::config::DecompMetric::Magnitude;
        }
        let m = bench(&format!("compress {cfg_str} 384x384"), mrt(300), || {
            let c = compress_layer("l", &w, &cfg, None).unwrap();
            std::hint::black_box(&c);
        });
        report(&m);
        table.row(vec![m.name.clone(), format!("{:.3}", m.median_ms()), "-".into()]);
    }

    // Simulated tensor core (pure model, should be ~ns).
    let spec = TensorCoreSpec::default();
    let cfg = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
    let m = bench("simtc 512x4096x4096", mrt(100), || {
        std::hint::black_box(spec.simulate(&cfg, 512, 4096, 4096));
    });
    report(&m);
    table.row(vec![m.name.clone(), format!("{:.4}", m.median_ms()), "-".into()]);

    table.print();
    table.save_json("hotpath");
    // Cross-PR trajectory record at the repo root (the CI
    // bench-regression gate's input, like BENCH_serving.json).
    let _ = std::fs::write("BENCH_hotpath.json", table.to_json().to_string());
}
