//! Stage 3 — VS-Quant per-vector scaled quantization (Dai et al., 2021).
//!
//! Two-level scaling, exactly as VS-Quant:
//!
//! * a **per-Q-vector scale factor** `s_v`, itself quantized to a low-bit
//!   format (`fp8-e4m3` by default; `ufp8-e6m2` in the Fig. 11 ablation),
//! * a **per-output-channel fp32 scale** `s_c` that normalizes the
//!   per-vector ratios into the scale format's sweet spot.
//!
//! `quantize_tensor` produces a [`QuantizedTensor`] holding grid codes
//! plus both scale levels (what packed storage and the Pallas kernel
//! consume); `fake_quant` is the dequantized view used for model-quality
//! evaluation (standard PTQ methodology). Activations are quantized
//! dynamically per token vector with fp32 scales ([`fake_quant_dynamic`]).

use crate::util::par::par_chunks_mut;

use crate::formats::NumFormat;
use crate::tensor::Matrix;

/// VS-Quant configuration for one tensor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VsQuantCfg {
    /// Value format (int4/int8/fp4/fp8…).
    pub fmt: NumFormat,
    /// Q-vector size: elements sharing one scale factor.
    pub qvec: usize,
    /// Scale-factor format (Fig. 11: fp8-e4m3 vs ufp8-e6m2).
    pub scale_fmt: NumFormat,
}

/// A VS-Quant-quantized tensor: codes on the format grid plus two-level
/// scales. `value ≈ code · vec_scale · chan_scale`.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub cfg: VsQuantCfg,
    pub rows: usize,
    pub cols: usize,
    /// Grid codes (stored as f32 for convenience; each is representable
    /// in `cfg.fmt`).
    pub codes: Vec<f32>,
    /// Quantized per-vector scale ratios, `rows × ceil(cols/qvec)`.
    pub vec_scales: Vec<f32>,
    /// Per-row (output-channel) fp32 second-level scales.
    pub chan_scales: Vec<f32>,
}

impl QuantizedTensor {
    /// Number of Q-vectors per row.
    pub fn qvecs_per_row(&self) -> usize {
        self.cols.div_ceil(self.cfg.qvec)
    }

    /// Effective scale for (row, qvec index).
    #[inline]
    pub fn scale(&self, r: usize, q: usize) -> f32 {
        self.vec_scales[r * self.qvecs_per_row() + q] * self.chan_scales[r]
    }

    /// Dequantize back to a dense matrix.
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        let qn = self.qvecs_per_row();
        for r in 0..self.rows {
            let row = out.row_mut(r);
            for q in 0..qn {
                let s = self.vec_scales[r * qn + q] * self.chan_scales[r];
                let lo = q * self.cfg.qvec;
                let hi = ((q + 1) * self.cfg.qvec).min(self.cols);
                for i in lo..hi {
                    row[i] = self.codes[r * self.cols + i] * s;
                }
            }
        }
        out
    }

    /// Mean-squared error against the original.
    pub fn mse(&self, orig: &Matrix) -> f64 {
        let deq = self.dequantize();
        deq.data
            .iter()
            .zip(&orig.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / orig.data.len().max(1) as f64
    }
}

/// Quantize `w` (`[out, in]`, Q-vectors along the input dimension) with
/// two-level VS-Quant scaling.
pub fn quantize_tensor(w: &Matrix, cfg: VsQuantCfg) -> QuantizedTensor {
    assert!(cfg.qvec > 0);
    let qn = w.cols.div_ceil(cfg.qvec);
    let mut codes = vec![0.0f32; w.rows * w.cols];
    let mut vec_scales = vec![0.0f32; w.rows * qn];
    let mut chan_scales = vec![1.0f32; w.rows];

    // Row-parallel: compute per-row (scales row, channel scale) into a
    // side vector, codes directly into their chunk.
    let side: Vec<(Vec<f32>, f32)> = crate::util::par::par_map(w.rows, |r| {
        let row = w.row(r);
        {
            // Raw (ideal) per-vector scales.
            let mut raw = vec![0.0f32; qn];
            for (q, blk) in row.chunks(cfg.qvec).enumerate() {
                let max_abs = blk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                raw[q] = max_abs / cfg.fmt.max_value();
            }
            // Second level: per-channel fp32 scale = max raw scale, so the
            // quantized ratios live in (0, 1] where the scale format has
            // full relative precision.
            let s_c = raw.iter().fold(0.0f32, |m, v| m.max(*v));
            let chan = if s_c > 0.0 { s_c } else { 1.0 };
            let mut srow = vec![0.0f32; qn];
            for (q, r_raw) in raw.iter().enumerate() {
                let ratio = r_raw / chan;
                srow[q] = if ratio > 0.0 { cfg.scale_fmt.quantize(ratio) } else { 0.0 };
            }
            (srow, chan)
        }
    });
    for (r, (srow, chan)) in side.iter().enumerate() {
        vec_scales[r * qn..(r + 1) * qn].copy_from_slice(srow);
        chan_scales[r] = *chan;
    }
    par_chunks_mut(&mut codes, w.cols, |r, crow| {
        let row = w.row(r);
        for q in 0..qn {
            let s = vec_scales[r * qn + q] * chan_scales[r];
            if s == 0.0 {
                // all-zero vector (or ratio underflow): codes stay 0
                continue;
            }
            let lo = q * cfg.qvec;
            let hi = ((q + 1) * cfg.qvec).min(w.cols);
            for i in lo..hi {
                crow[i] = cfg.fmt.quantize(row[i] / s);
            }
        }
    });

    QuantizedTensor { cfg, rows: w.rows, cols: w.cols, codes, vec_scales, chan_scales }
}

/// Quantize→dequantize round trip (the PTQ evaluation view).
pub fn fake_quant(w: &Matrix, cfg: VsQuantCfg) -> Matrix {
    quantize_tensor(w, cfg).dequantize()
}

/// Dynamic activation quantization: per-Q-vector fp32 max-abs scales
/// (computed on the fly by hardware; no stored metadata). Rounds onto
/// `fmt`'s grid and back.
pub fn fake_quant_dynamic(x: &Matrix, fmt: NumFormat, qvec: usize) -> Matrix {
    let mut out = Matrix::zeros(x.rows, x.cols);
    par_chunks_mut(&mut out.data, x.cols, |r, orow| {
        let xrow = x.row(r);
        for (q, blk) in xrow.chunks(qvec).enumerate() {
            let max_abs = blk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if max_abs == 0.0 {
                continue;
            }
            let s = max_abs / fmt.max_value();
            let lo = q * qvec;
            for (i, v) in blk.iter().enumerate() {
                orow[lo + i] = fmt.quantize(v / s) * s;
            }
        }
    });
    out
}

/// In-place variant of [`fake_quant_dynamic`] for the eval hot path.
pub fn fake_quant_dynamic_inplace(x: &mut Matrix, fmt: NumFormat, qvec: usize) {
    let cols = x.cols;
    par_chunks_mut(&mut x.data, cols, |_r, xrow| {
        for blk in xrow.chunks_mut(qvec) {
            let max_abs = blk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if max_abs == 0.0 {
                continue;
            }
            let s = max_abs / fmt.max_value();
            for v in blk.iter_mut() {
                *v = fmt.quantize(*v / s) * s;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(fmt: NumFormat) -> VsQuantCfg {
        VsQuantCfg { fmt, qvec: 16, scale_fmt: NumFormat::Fp8E4M3 }
    }

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from_u64(seed);
        Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.range_f32(-3.0, 3.0)).collect())
    }

    #[test]
    fn int8_roundtrip_is_tight() {
        let w = rand_matrix(8, 64, 1);
        let q = quantize_tensor(&w, cfg(NumFormat::Int(8)));
        let rel = q.dequantize().rel_frob_dist(&w);
        assert!(rel < 0.01, "int8 rel err {rel}");
    }

    #[test]
    fn fp4_roundtrip_is_loose_but_bounded() {
        let w = rand_matrix(8, 64, 2);
        let q = quantize_tensor(&w, cfg(NumFormat::Fp4E2M1));
        let rel = q.dequantize().rel_frob_dist(&w);
        assert!(rel > 0.01 && rel < 0.25, "fp4 rel err {rel}");
    }

    #[test]
    fn error_ordering_matches_bit_width() {
        // Heavy-tailed weights (the LLM regime): fp4's non-uniform grid
        // beats int4's uniform grid, and int8 beats both (§6.2's
        // int4-vs-fp4 ordering).
        let mut rng = Rng::seed_from_u64(3);
        let w = Matrix::from_vec(
            16,
            128,
            (0..16 * 128).map(|_| rng.normal().powi(3)).collect(),
        );
        let e8 = quantize_tensor(&w, cfg(NumFormat::Int(8))).mse(&w);
        let e4 = quantize_tensor(&w, cfg(NumFormat::Int(4))).mse(&w);
        let f4 = quantize_tensor(&w, cfg(NumFormat::Fp4E2M1)).mse(&w);
        assert!(e8 < f4 && f4 < e4, "int8 {e8} < fp4 {f4} < int4 {e4}");
    }

    #[test]
    fn codes_live_on_the_grid() {
        let w = rand_matrix(4, 32, 4);
        let q = quantize_tensor(&w, cfg(NumFormat::Fp4E2M1));
        for c in &q.codes {
            assert_eq!(NumFormat::Fp4E2M1.quantize(*c), *c, "code {c} off-grid");
        }
    }

    #[test]
    fn scale_ratios_live_on_scale_grid() {
        let w = rand_matrix(4, 64, 5);
        let q = quantize_tensor(&w, cfg(NumFormat::Int(8)));
        for s in &q.vec_scales {
            assert_eq!(NumFormat::Fp8E4M3.quantize(*s), *s);
            assert!(*s <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn zero_tensor_roundtrips_to_zero() {
        let w = Matrix::zeros(3, 32);
        let q = quantize_tensor(&w, cfg(NumFormat::Int(4)));
        assert!(q.dequantize().data.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn outlier_inflates_vector_error_only_locally() {
        // Outlier in vector 0 must not hurt vector 1's precision.
        let mut data = vec![0.5f32; 32];
        data[0] = 100.0;
        let w = Matrix::from_vec(1, 32, data);
        let q = quantize_tensor(&w, cfg(NumFormat::Int(4)));
        let deq = q.dequantize();
        // vector 1 (cols 16..32) must round-trip tightly
        for i in 16..32 {
            assert!((deq.data[i] - 0.5).abs() < 0.06, "col {i}: {}", deq.data[i]);
        }
        // vector 0 inliers get crushed by the outlier-driven scale
        assert!((deq.data[1] - 0.5).abs() > 0.2);
    }

    #[test]
    fn e6m2_scales_hurt_more_than_e4m3() {
        let w = rand_matrix(32, 256, 6);
        let a = quantize_tensor(
            &w,
            VsQuantCfg { fmt: NumFormat::Fp4E2M1, qvec: 16, scale_fmt: NumFormat::Fp8E4M3 },
        )
        .mse(&w);
        let b = quantize_tensor(
            &w,
            VsQuantCfg { fmt: NumFormat::Fp4E2M1, qvec: 16, scale_fmt: NumFormat::UFp8E6M2 },
        )
        .mse(&w);
        assert!(b > a, "coarser scale mantissa must increase error: e4m3={a} e6m2={b}");
    }

    #[test]
    fn dynamic_activation_quant_preserves_zero_and_sign() {
        let x = Matrix::from_vec(2, 8, vec![0., 1., -1., 2., -2., 0.5, -0.5, 4., 0., 0., 0., 0., 0., 0., 0., 0.]);
        let q = fake_quant_dynamic(&x, NumFormat::Int(8), 8);
        assert_eq!(q.data[0], 0.0);
        assert!(q.data[1] > 0.0 && q.data[2] < 0.0);
        // all-zero row untouched
        for i in 8..16 {
            assert_eq!(q.data[i], 0.0);
        }
        // inplace variant agrees
        let mut x2 = x.clone();
        fake_quant_dynamic_inplace(&mut x2, NumFormat::Int(8), 8);
        assert_eq!(x2.data, q.data);
    }

    #[test]
    fn smaller_qvec_reduces_error() {
        // Finer scale granularity ⇒ lower quantization error (§3.3).
        let mut rng = Rng::seed_from_u64(7);
        let w = Matrix::from_vec(
            16,
            256,
            (0..4096).map(|_| rng.range_f32(-1.0, 1.0) * rng.range_f32(0.1, 4.0)).collect(),
        );
        let e16 = quantize_tensor(
            &w,
            VsQuantCfg { fmt: NumFormat::Int(4), qvec: 16, scale_fmt: NumFormat::Fp8E4M3 },
        )
        .mse(&w);
        let e64 = quantize_tensor(
            &w,
            VsQuantCfg { fmt: NumFormat::Int(4), qvec: 64, scale_fmt: NumFormat::Fp8E4M3 },
        )
        .mse(&w);
        assert!(e16 < e64, "qvec16 ({e16}) must beat qvec64 ({e64})");
    }
}
