//! Compression design-space sweep (the DESIGN.md ablation driver):
//! Q-vector size × value format × scale format on a real model layer,
//! reporting reconstruction error vs bits/weight — the §3.3 trade-off.
//!
//! Run: `cargo run --release --example compress_sweep`

use sdq::formats::NumFormat;
use sdq::harness;
use sdq::perfmodel::bits_breakdown;
use sdq::sdq::nm::NmPattern;
use sdq::sdq::quantize::{quantize_tensor, VsQuantCfg};
use sdq::util::bench::Table;

fn main() -> sdq::Result<()> {
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let model = harness::load_model("gpt-micro")?;
    // The widest layer (ff1) has the most interesting statistics.
    let w = model
        .linears()
        .iter()
        .find(|l| l.name.ends_with("mlp.ff1"))
        .map(|l| l.lin.dense_view())
        .unwrap();
    println!("sweeping layer block0.mlp.ff1 ({}x{})", w.rows, w.cols);

    let mut table = Table::new(
        "VS-Quant design space: qvec × format × scale format",
        &["fmt", "scale_fmt", "qvec", "rel RMSE", "bits/w"],
    );
    for fmt in [NumFormat::Int(8), NumFormat::Int(4), NumFormat::Fp4E2M1, NumFormat::Fp8E4M3] {
        for scale_fmt in [NumFormat::Fp8E4M3, NumFormat::UFp8E6M2] {
            for qvec in [8usize, 16, 32, 64] {
                let q = quantize_tensor(&w, VsQuantCfg { fmt, qvec, scale_fmt });
                let rel = q.dequantize().rel_frob_dist(&w);
                let bits =
                    bits_breakdown(NmPattern::new(1, 1), fmt.bits(), scale_fmt.bits(), qvec)
                        .total();
                table.row(vec![
                    fmt.to_string(),
                    scale_fmt.to_string(),
                    qvec.to_string(),
                    format!("{rel:.5}"),
                    format!("{bits:.2}"),
                ]);
            }
        }
    }
    table.print();
    table.save_json("compress_sweep");
    println!("\nReadings: error falls with smaller qvec but bits/w rises (§3.3);");
    println!("ufp8-e6m2 scales always lose to fp8-e4m3 at equal bits (Fig. 11).");
    Ok(())
}
