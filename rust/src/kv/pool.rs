//! The shared KV block pool: allocation, content-addressed prefix
//! sharing, copy-on-write, LRU eviction, and dtype-selectable block
//! storage (see module docs in [`super`]).

use std::collections::HashMap;

use super::store::{KvDtype, KvScratch, KvStore};
use super::table::BlockTable;
use super::NO_PARENT;
use crate::model::ModelConfig;

/// Content address of a frozen (full) block: the parent block pins the
/// entire prefix before this block (parent ids are themselves deduped,
/// and the generation counter invalidates the key if the parent slot is
/// ever reused), and `tokens` are this block's own token bytes. Exact —
/// equality compares real bytes, so there are no collision corruptions.
/// Keys are dtype-agnostic: content addressing is by *token* identity,
/// and quantized payloads are a deterministic function of the token
/// chain (see [`super::store`]), so dedup stays exact at any dtype.
#[derive(Clone, Debug, Hash, PartialEq, Eq)]
struct BlockKey {
    parent: usize,
    parent_gen: u64,
    tokens: Vec<u8>,
}

/// One fixed-size KV block: `block_tokens` rows of K and V for **every**
/// layer, held in a dtype-selected [`KvStore`] (layer-major slabs).
/// Holding all layers in one refcounted unit is what makes a block the
/// unit of prefix sharing — a token range's KV is shared or not as a
/// whole.
#[derive(Debug)]
struct Block {
    store: KvStore,
    /// Tables currently referencing this block. 0 ⇒ free-listed (if
    /// unkeyed) or cached awaiting reuse/eviction (if keyed).
    refs: u32,
    /// Bumped every time the slot is (re)allocated; embedded in child
    /// keys so stale chains can never match after reuse.
    gen: u64,
    /// Set when the block is frozen into the content index.
    key: Option<BlockKey>,
    /// LRU stamp among cached (refs == 0) blocks.
    last_used: u64,
}

/// Pool counters the coordinator surfaces as serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Prompt tokens served straight from cached blocks at admission.
    pub shared_tokens: u64,
    /// Total prompt tokens seen by `attach_prefix`.
    pub prompt_tokens: u64,
    /// Cached blocks evicted to make room or trim to budget.
    pub evictions: u64,
    /// Copy-on-write block copies (forked tables diverging).
    pub cow_copies: u64,
    /// Duplicate blocks merged at freeze time (identical prompts
    /// admitted in the same round).
    pub dedup_merges: u64,
}

impl PoolStats {
    /// Fraction of prompt tokens that hit the prefix cache. `0.0` before
    /// any prompt was seen — never NaN, so the rate is always valid JSON
    /// when emitted as a number.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            return 0.0;
        }
        self.shared_tokens as f64 / self.prompt_tokens as f64
    }
}

/// Shared, ref-counted KV block pool (see [`super`] for the full
/// design).
#[derive(Debug)]
pub struct BlockPool {
    dtype: KvDtype,
    block_tokens: usize,
    d: usize,
    n_layer: usize,
    /// Admission budget in blocks (derived from the byte budget at the
    /// pool dtype's *compressed* block size — int8 blocks are ~4× denser
    /// than f32, so the same byte budget admits ~4× the blocks).
    budget_blocks: usize,
    /// Hard allocation cap: ≥ one `max_seq` sequence so a forced single
    /// admission can always complete.
    max_blocks: usize,
    blocks: Vec<Block>,
    free: Vec<usize>,
    index: HashMap<BlockKey, usize>,
    tick: u64,
    pub stats: PoolStats,
}

impl BlockPool {
    /// Pool for `cfg` under `budget_bytes`, with the default
    /// [`super::KV_BLOCK_TOKENS`] block size and the config's
    /// `kv_dtype`.
    pub fn new(cfg: &ModelConfig, budget_bytes: usize) -> Self {
        Self::with_params(cfg, budget_bytes, super::KV_BLOCK_TOKENS, cfg.kv_dtype)
    }

    /// Pool with an explicit storage dtype (the scheduler's
    /// `BatchPolicy::kv_dtype` override lands here).
    pub fn with_dtype(cfg: &ModelConfig, budget_bytes: usize, dtype: KvDtype) -> Self {
        Self::with_params(cfg, budget_bytes, super::KV_BLOCK_TOKENS, dtype)
    }

    pub fn with_block_tokens(cfg: &ModelConfig, budget_bytes: usize, block_tokens: usize) -> Self {
        Self::with_params(cfg, budget_bytes, block_tokens, cfg.kv_dtype)
    }

    pub fn with_params(
        cfg: &ModelConfig,
        budget_bytes: usize,
        block_tokens: usize,
        dtype: KvDtype,
    ) -> Self {
        assert!(block_tokens > 0);
        let block_bytes = Self::block_bytes_for(cfg.n_layer, block_tokens, cfg.d_model, dtype);
        let budget_blocks = (budget_bytes / block_bytes).max(1);
        let one_seq = cfg.max_seq.div_ceil(block_tokens);
        BlockPool {
            dtype,
            block_tokens,
            d: cfg.d_model,
            n_layer: cfg.n_layer,
            budget_blocks,
            max_blocks: budget_blocks.max(one_seq),
            blocks: Vec::new(),
            free: Vec::new(),
            index: HashMap::new(),
            tick: 0,
            stats: PoolStats::default(),
        }
    }

    // ---- geometry & accounting ----

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Storage dtype of every block in this pool.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    fn block_bytes_for(n_layer: usize, block_tokens: usize, d: usize, dtype: KvDtype) -> usize {
        // K + V payloads for all layers, plus per-layer-per-side scale
        // metadata for quantized stores.
        2 * n_layer * (block_tokens * d * dtype.bytes_per_elem() + dtype.scale_bytes())
    }

    /// *Actual* (compressed) bytes of one block: K + V payloads at the
    /// storage dtype, plus scale metadata. This is the unit every
    /// byte-denominated number in the system uses — budget conversion,
    /// residency, peak metrics.
    pub fn block_bytes(&self) -> usize {
        Self::block_bytes_for(self.n_layer, self.block_tokens, self.d, self.dtype)
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Admission budget in blocks.
    pub fn budget_blocks(&self) -> usize {
        self.budget_blocks
    }

    /// Blocks currently resident: referenced by tables **or** cached for
    /// prefix reuse. Free-listed slots don't count.
    pub fn blocks_in_use(&self) -> usize {
        self.blocks.len() - self.free.len()
    }

    /// Logical KV residency in compressed bytes (referenced + cached
    /// blocks).
    pub fn bytes_in_use(&self) -> usize {
        self.blocks_in_use() * self.block_bytes()
    }

    /// Residency as a fraction of the admission budget.
    pub fn utilization(&self) -> f64 {
        self.blocks_in_use() as f64 / self.budget_blocks as f64
    }

    /// Cached blocks reclaimable on demand (frozen, unreferenced).
    pub fn evictable_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.refs == 0 && b.key.is_some()).count()
    }

    // ---- allocation ----

    /// Claim a block slot: free list first, grow while under the
    /// admission budget second, evict the LRU cached block third, and —
    /// as the forced-admission safety valve — grow up to the hard cap
    /// last. Panics if every block is referenced; admission control must
    /// make that unreachable.
    fn alloc_block(&mut self) -> usize {
        let id = if let Some(id) = self.free.pop() {
            id
        } else if self.blocks.len() < self.budget_blocks {
            self.grow_one()
        } else if let Some(id) = self.evict_one() {
            id
        } else if self.blocks.len() < self.max_blocks {
            self.grow_one()
        } else {
            panic!(
                "BlockPool exhausted ({} blocks, all referenced) — admission \
                 control must reserve growth before it happens",
                self.max_blocks
            );
        };
        let b = &mut self.blocks[id];
        debug_assert_eq!(b.refs, 0);
        debug_assert!(b.key.is_none());
        debug_assert_eq!(b.store.dtype(), self.dtype, "pool blocks share one dtype");
        b.refs = 1;
        b.gen += 1;
        b.store.reset();
        id
    }

    fn grow_one(&mut self) -> usize {
        self.blocks.push(Block {
            store: KvStore::new(self.dtype, self.n_layer, self.block_tokens, self.d),
            refs: 0,
            gen: 0,
            key: None,
            last_used: 0,
        });
        self.blocks.len() - 1
    }

    /// Drop the least-recently-used cached block from the content index
    /// and return its (refs == 0, unkeyed) slot. `None` when nothing is
    /// evictable.
    ///
    /// Linear scan by design: eviction only runs once the pool is at
    /// its block budget, and a scan keeps every other path free of
    /// LRU-list bookkeeping. Swap in an intrusive list if profiles ever
    /// show retirement-time trims on the hot path.
    fn evict_one(&mut self) -> Option<usize> {
        let id = self
            .blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.refs == 0 && b.key.is_some())
            .min_by_key(|(_, b)| b.last_used)
            .map(|(i, _)| i)?;
        let key = self.blocks[id].key.take().expect("evictable blocks are keyed");
        // The index may point at a different (canonical) block for this
        // key only if we never indexed this one — but unindexed blocks
        // carry no key, so the entry is ours.
        self.index.remove(&key);
        self.stats.evictions += 1;
        Some(id)
    }

    // ---- the sequence lifecycle ----

    /// Walk `prompt` down the content index and attach every leading
    /// full block already resident, bumping refcounts instead of
    /// recomputing KV. Returns the shared token count (always a block
    /// multiple, and < `prompt.len()` so at least one token is left to
    /// prefill). The table must be fresh.
    pub fn attach_prefix(&mut self, table: &mut BlockTable, prompt: &[u8]) -> usize {
        assert!(table.len == 0 && table.blocks.is_empty(), "attach needs a fresh table");
        let bt = self.block_tokens;
        // Never share the whole prompt: the last token must be prefilled
        // to produce the logits that seed sampling.
        let max_share = (prompt.len().saturating_sub(1) / bt) * bt;
        let mut shared = 0;
        let (mut parent, mut parent_gen) = (NO_PARENT, 0u64);
        while shared < max_share {
            let key =
                BlockKey { parent, parent_gen, tokens: prompt[shared..shared + bt].to_vec() };
            match self.index.get(&key) {
                Some(&id) => {
                    self.blocks[id].refs += 1;
                    table.blocks.push(id);
                    table.tokens.extend_from_slice(&key.tokens);
                    shared += bt;
                    parent = id;
                    parent_gen = self.blocks[id].gen;
                }
                None => break,
            }
        }
        table.len = shared;
        self.stats.shared_tokens += shared as u64;
        self.stats.prompt_tokens += prompt.len() as u64;
        shared
    }

    /// Make room for `n_new` tokens after `table.len`: allocate every
    /// block the new rows will land in and copy-on-write a shared
    /// partial tail (forked tables). Called once per forward step, so
    /// the per-layer write loop never allocates or re-checks ownership.
    pub fn prepare_tokens(&mut self, table: &mut BlockTable, n_new: usize) {
        let bt = self.block_tokens;
        for pos in table.len..table.len + n_new {
            let bi = pos / bt;
            if bi == table.blocks.len() {
                let id = self.alloc_block();
                table.blocks.push(id);
            } else if self.blocks[table.blocks[bi]].refs > 1 {
                // Copy-on-write: give this table a private copy of the
                // shared tail before the first new row lands in it.
                let src = table.blocks[bi];
                let dst = self.alloc_block();
                let rows = table.len - bi * bt;
                debug_assert!(rows <= bt);
                self.copy_rows(src, dst, rows);
                self.blocks[src].refs -= 1;
                table.blocks[bi] = dst;
                self.stats.cow_copies += 1;
            }
        }
    }

    /// Copy the first `rows` committed rows of every layer from block
    /// `src` to block `dst` (codes *and* scales for quantized stores).
    fn copy_rows(&mut self, src: usize, dst: usize, rows: usize) {
        debug_assert_ne!(src, dst);
        let (d, bt, nl) = (self.d, self.block_tokens, self.n_layer);
        let (lo, hi, src_is_lo) = if src < dst { (src, dst, true) } else { (dst, src, false) };
        let (head, tail) = self.blocks.split_at_mut(hi);
        let (a, b) = (&mut head[lo], &mut tail[0]);
        let (from, to) = if src_is_lo { (a, b) } else { (b, a) };
        to.store.copy_rows_from(&from.store, rows, nl, bt, d);
    }

    /// Stage the K/V row for layer `li` at absolute position `pos`
    /// (which [`Self::prepare_tokens`] must already have made room for).
    /// Quantized pools encode the row on the block's per-layer scale
    /// here — writes are where compression happens.
    pub fn write_row(&mut self, table: &BlockTable, li: usize, pos: usize, k: &[f32], v: &[f32]) {
        let (d, bt) = (self.d, self.block_tokens);
        debug_assert_eq!(k.len(), d);
        debug_assert_eq!(v.len(), d);
        let id = table.blocks[pos / bt];
        let b = &mut self.blocks[id];
        debug_assert_eq!(b.refs, 1, "staged writes require exclusive ownership");
        b.store.write_row(li, pos % bt, bt, d, k, v);
    }

    /// Commit `toks` (the tokens whose rows were just written), freezing
    /// every block that became full into the content index. Freezing a
    /// key that is already indexed merges onto the canonical block and
    /// frees ours — identical prompts admitted in the same round
    /// converge here.
    pub fn commit(&mut self, table: &mut BlockTable, toks: &[u8]) {
        let bt = self.block_tokens;
        table.tokens.extend_from_slice(toks);
        let old_len = table.len;
        table.len += toks.len();
        debug_assert_eq!(table.tokens.len(), table.len);
        for bi in old_len / bt..table.len / bt {
            self.freeze_block(table, bi);
        }
    }

    fn freeze_block(&mut self, table: &mut BlockTable, bi: usize) {
        let bt = self.block_tokens;
        let id = table.blocks[bi];
        if self.blocks[id].key.is_some() {
            return; // already frozen (shared via fork, committed twice)
        }
        let (parent, parent_gen) = if bi == 0 {
            (NO_PARENT, 0)
        } else {
            let p = table.blocks[bi - 1];
            (p, self.blocks[p].gen)
        };
        let key =
            BlockKey { parent, parent_gen, tokens: table.tokens[bi * bt..(bi + 1) * bt].to_vec() };
        match self.index.get(&key) {
            None => {
                self.index.insert(key.clone(), id);
                self.blocks[id].key = Some(key);
            }
            Some(&canonical) => {
                // Same parent chain + same tokens ⇒ identical KV content
                // (bit-identical even quantized: codes are a pure
                // function of the write history); fold onto the
                // canonical block.
                debug_assert_ne!(canonical, id);
                self.blocks[canonical].refs += 1;
                table.blocks[bi] = canonical;
                let b = &mut self.blocks[id];
                b.refs -= 1;
                if b.refs == 0 {
                    self.free.push(id);
                }
                self.stats.dedup_merges += 1;
            }
        }
    }

    /// Clone a table, sharing all its blocks (refcount +1 each,
    /// including a partial tail — the copy-on-write case).
    pub fn fork(&mut self, table: &BlockTable) -> BlockTable {
        for &id in &table.blocks {
            self.blocks[id].refs += 1;
        }
        table.clone()
    }

    /// Return a finished sequence's blocks. Frozen blocks that drop to
    /// zero references stay cached (and indexed) for future prefix hits;
    /// unkeyed partials go straight to the free list. Afterwards,
    /// residency is trimmed back under the admission budget by evicting
    /// LRU cached blocks.
    pub fn release(&mut self, table: BlockTable) {
        for &id in table.blocks.iter().rev() {
            let b = &mut self.blocks[id];
            debug_assert!(b.refs > 0);
            b.refs -= 1;
            if b.refs == 0 {
                self.tick += 1;
                b.last_used = self.tick;
                if b.key.is_none() {
                    self.free.push(id);
                }
            }
        }
        while self.blocks_in_use() > self.budget_blocks {
            match self.evict_one() {
                Some(id) => self.free.push(id),
                None => break,
            }
        }
    }

    /// Borrowed K/V row segments for layer `li` of one table — the
    /// single-sequence convenience over [`Self::layer_views`].
    pub fn layer_view<'a>(
        &'a self,
        table: &BlockTable,
        li: usize,
        upto: usize,
        scratch: &'a mut KvScratch,
    ) -> (Vec<&'a [f32]>, Vec<&'a [f32]>) {
        self.layer_views(&[table], li, &[upto], scratch).pop().expect("one table in, one out")
    }

    /// Borrowed K/V row segments for layer `li` across `tables`, each
    /// covering the first `uptos[i]` tokens of its sequence — one
    /// `(rows × d)` slice per block, gather-free. `upto` may exceed
    /// `table.len` by the rows staged in the current forward step.
    ///
    /// F32 pools hand back slices borrowed straight from block storage
    /// (zero-copy, unchanged from the pre-dtype design). Quantized pools
    /// dequantize each sequence's rows into `scratch` first and borrow
    /// the segments from there — same shapes, same segment walk, so
    /// attention is dtype-blind. One call covers every sequence in the
    /// layer's ragged batch because all the views must stay alive at
    /// once (the arena is sized before any slice is taken).
    pub fn layer_views<'a>(
        &'a self,
        tables: &[&BlockTable],
        li: usize,
        uptos: &[usize],
        scratch: &'a mut KvScratch,
    ) -> Vec<(Vec<&'a [f32]>, Vec<&'a [f32]>)> {
        assert_eq!(tables.len(), uptos.len(), "one upto per table");
        let (d, bt) = (self.d, self.block_tokens);
        // Fill phase (quantized only): decode block slabs into per-
        // sequence contiguous scratch buffers. Blocks before the tail
        // are always full, so block `bi`'s rows start at `bi * bt * d`.
        scratch.reset();
        let mut bufs: Vec<Option<(usize, usize)>> = Vec::with_capacity(tables.len());
        if self.dtype != KvDtype::F32 {
            for (t, &upto) in tables.iter().zip(uptos) {
                let ki = scratch.take(upto * d);
                let vi = scratch.take(upto * d);
                for bi in 0..upto.div_ceil(bt) {
                    let rows = (upto - bi * bt).min(bt);
                    let store = &self.blocks[t.blocks[bi]].store;
                    let base = bi * bt * d;
                    let (k_out, v_out) = scratch.bufs_pair_mut(ki, vi);
                    store.dequant_into(
                        li,
                        rows,
                        bt,
                        d,
                        &mut k_out[base..base + rows * d],
                        &mut v_out[base..base + rows * d],
                    );
                }
                bufs.push(Some((ki, vi)));
            }
        } else {
            bufs.resize(tables.len(), None);
        }
        // View phase: downgrade the scratch borrow to shared and hand
        // out per-block segments from storage (f32) or scratch (q8).
        let scr: &KvScratch = scratch;
        tables
            .iter()
            .zip(uptos)
            .zip(bufs)
            .map(|((t, &upto), ids)| {
                let nb = upto.div_ceil(bt);
                debug_assert!(nb <= t.blocks.len(), "view past prepared blocks");
                let mut ks = Vec::with_capacity(nb);
                let mut vs = Vec::with_capacity(nb);
                for bi in 0..nb {
                    let rows = (upto - bi * bt).min(bt);
                    match ids {
                        None => {
                            let (k, v) =
                                self.blocks[t.blocks[bi]].store.f32_slices(li, rows, bt, d);
                            ks.push(k);
                            vs.push(v);
                        }
                        Some((ki, vi)) => {
                            let base = bi * bt * d;
                            ks.push(&scr.buf(ki)[base..base + rows * d]);
                            vs.push(&scr.buf(vi)[base..base + rows * d]);
                        }
                    }
                }
                (ks, vs)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "pool-test".into(),
            arch: Arch::Gpt,
            d_model: 8,
            n_layer: 2,
            n_head: 2,
            d_ff: 16,
            vocab: 256,
            max_seq: 64,
            eps: 1e-5,
            rope_theta: 10000.0,
            kv_dtype: KvDtype::F32,
        }
    }

    /// Pool with a 4-token block (small enough to cross boundaries fast)
    /// and room for `budget` blocks.
    fn pool(budget: usize) -> BlockPool {
        pool_dt(budget, KvDtype::F32)
    }

    fn pool_dt(budget: usize, dtype: KvDtype) -> BlockPool {
        let c = cfg();
        let bb = BlockPool::block_bytes_for(c.n_layer, 4, c.d_model, dtype);
        BlockPool::with_params(&c, budget * bb, 4, dtype)
    }

    /// Drive a table through `toks` as the model would: prepare, write
    /// one distinctive row per (layer, pos), commit.
    fn run_tokens(p: &mut BlockPool, t: &mut BlockTable, toks: &[u8]) {
        p.prepare_tokens(t, toks.len());
        let d = 8;
        for (j, tok) in toks.iter().enumerate() {
            let pos = t.len() + j;
            for li in 0..2 {
                let row = vec![(*tok as f32) + li as f32 * 0.5; d];
                let vrow = vec![-((*tok as f32) + li as f32 * 0.5); d];
                p.write_row(t, li, pos, &row, &vrow);
            }
        }
        p.commit(t, toks);
    }

    #[test]
    fn alloc_write_view_roundtrip() {
        let mut p = pool(8);
        let mut t = BlockTable::new(64);
        run_tokens(&mut p, &mut t, &[1, 2, 3, 4, 5]); // 2 blocks (4 + 1)
        assert_eq!(t.len(), 5);
        assert_eq!(t.block_ids().len(), 2);
        assert_eq!(p.blocks_in_use(), 2);
        assert_eq!(p.bytes_in_use(), 2 * p.block_bytes());
        let mut scr = KvScratch::new();
        let (ks, vs) = p.layer_view(&t, 1, 5, &mut scr);
        assert_eq!(ks.len(), 2);
        assert_eq!(ks[0].len(), 4 * 8);
        assert_eq!(ks[1].len(), 8);
        // row for token 5 (pos 4) in layer 1 carries value 5.5
        assert_eq!(ks[1][0], 5.5);
        assert_eq!(vs[1][0], -5.5);
        p.release(t);
        // block 0 was frozen (full) → cached; block 1 partial → freed
        assert_eq!(p.blocks_in_use(), 1);
        assert_eq!(p.evictable_blocks(), 1);
    }

    #[test]
    fn quantized_roundtrip_within_tolerance() {
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let mut p = pool_dt(8, dtype);
            let mut t = BlockTable::new(64);
            run_tokens(&mut p, &mut t, &[1, 2, 3, 4, 5]);
            let mut scr = KvScratch::new();
            let (ks, vs) = p.layer_view(&t, 1, 5, &mut scr);
            // Rows carry constants per token; the layer-1 slab amax is
            // 5.5. int8 (8-bit uniform grid) stays within a few quanta
            // even after the ascending-amax rescales; fp8-e4m3's 3-bit
            // mantissa allows ≤6.25% relative error per round-trip,
            // compounded across rescales.
            let tol = match dtype {
                KvDtype::Int8 => 5.5 * 0.02,
                _ => 5.5 * 0.12,
            };
            for (bi, toks) in [(0usize, &[1u8, 2, 3, 4][..]), (1, &[5u8][..])] {
                for (r, tok) in toks.iter().enumerate() {
                    let want = *tok as f32 + 0.5;
                    for c in 0..8 {
                        let got = ks[bi][r * 8 + c];
                        assert!((got - want).abs() <= tol, "{dtype:?} k: {got} vs {want}");
                        let gv = vs[bi][r * 8 + c];
                        assert!((gv + want).abs() <= tol, "{dtype:?} v: {gv} vs {want}");
                    }
                }
            }
            p.release(t);
        }
    }

    #[test]
    fn quantized_blocks_are_denser() {
        let f32_pool = pool(1);
        let i8_pool = pool_dt(1, KvDtype::Int8);
        let fp8_pool = pool_dt(1, KvDtype::Fp8E4M3);
        assert!(i8_pool.block_bytes() * 3 < f32_pool.block_bytes(),
            "int8 blocks must be >3x smaller: {} vs {}",
            i8_pool.block_bytes(), f32_pool.block_bytes());
        assert_eq!(i8_pool.block_bytes(), fp8_pool.block_bytes());
        // Same byte budget ⇒ proportionally more blocks.
        let c = cfg();
        let budget = 64 * BlockPool::block_bytes_for(c.n_layer, 4, c.d_model, KvDtype::F32);
        let a = BlockPool::with_params(&c, budget, 4, KvDtype::F32);
        let b = BlockPool::with_params(&c, budget, 4, KvDtype::Int8);
        assert!(b.budget_blocks() as f64 >= 1.8 * a.budget_blocks() as f64,
            "compressed budget must buy >=1.8x blocks: {} vs {}",
            b.budget_blocks(), a.budget_blocks());
    }

    #[test]
    fn prefix_attach_shares_blocks() {
        let mut p = pool(16);
        let prompt: Vec<u8> = (10..20).collect(); // 10 tokens → 2 full blocks
        let mut a = BlockTable::new(64);
        assert_eq!(p.attach_prefix(&mut a, &prompt), 0, "cold cache");
        run_tokens(&mut p, &mut a, &prompt);
        let a_blocks = a.block_ids().to_vec();
        p.release(a);
        // Same prompt again: both full blocks hit.
        let mut b = BlockTable::new(64);
        let shared = p.attach_prefix(&mut b, &prompt);
        assert_eq!(shared, 8);
        assert_eq!(&b.block_ids()[..2], &a_blocks[..2]);
        assert!((p.stats.prefix_hit_rate() - 8.0 / 20.0).abs() < 1e-12);
        // Residency: 2 shared + nothing new yet.
        let before = p.bytes_in_use();
        run_tokens(&mut p, &mut b, &prompt[8..]);
        assert_eq!(p.bytes_in_use(), before + p.block_bytes(), "only the tail is new");
        p.release(b);
    }

    #[test]
    fn prefix_hit_rate_is_zero_not_nan_when_cold() {
        let p = pool(4);
        assert_eq!(p.stats.prefix_hit_rate(), 0.0, "no prompts seen must yield 0.0, not NaN");
    }

    #[test]
    fn whole_prompt_never_fully_shared() {
        let mut p = pool(8);
        let prompt: Vec<u8> = (1..9).collect(); // exactly 2 blocks
        let mut a = BlockTable::new(64);
        p.attach_prefix(&mut a, &prompt);
        run_tokens(&mut p, &mut a, &prompt);
        p.release(a);
        let mut b = BlockTable::new(64);
        // Only block 0 may attach: the last token must be prefilled.
        assert_eq!(p.attach_prefix(&mut b, &prompt), 4);
        p.release(b);
    }

    #[test]
    fn divergent_prompts_share_until_divergence() {
        let mut p = pool(16);
        let a_toks: Vec<u8> = vec![7, 7, 7, 7, 1, 2, 3, 4, 9];
        let b_toks: Vec<u8> = vec![7, 7, 7, 7, 5, 6, 7, 8, 9];
        let mut a = BlockTable::new(64);
        p.attach_prefix(&mut a, &a_toks);
        run_tokens(&mut p, &mut a, &a_toks);
        p.release(a);
        let mut b = BlockTable::new(64);
        let shared = p.attach_prefix(&mut b, &b_toks);
        assert_eq!(shared, 4, "share exactly the common first block");
        run_tokens(&mut p, &mut b, &b_toks[4..]);
        // b's second block differs from a's in content ⇒ distinct id.
        p.release(b);
    }

    #[test]
    fn cow_on_forked_tail() {
        // The COW path must preserve content at every dtype (quantized
        // copies carry codes + scales).
        for dtype in [KvDtype::F32, KvDtype::Int8] {
            let mut p = pool_dt(8, dtype);
            let mut a = BlockTable::new(64);
            run_tokens(&mut p, &mut a, &[1, 2, 3, 4, 5, 6]); // tail block holds 2 rows
            let tail = *a.block_ids().last().unwrap();
            let mut b = p.fork(&a);
            assert_eq!(p.blocks_in_use(), 2, "fork allocates nothing");
            run_tokens(&mut p, &mut b, &[42]);
            assert_eq!(p.stats.cow_copies, 1);
            let b_tail = b.block_ids()[1];
            assert_ne!(b_tail, tail, "fork diverged onto a private tail copy");
            // a's rows survive intact; b carries the copied prefix + new
            // row (within quantization tolerance of slab amax 42).
            let mut scr = KvScratch::new();
            let tol = if dtype == KvDtype::F32 { 0.0 } else { 42.0 / 127.0 + 1e-4 };
            {
                let (ka, _) = p.layer_view(&a, 0, 6, &mut scr);
                assert!((ka[1][8] - 6.0).abs() <= if dtype == KvDtype::F32 { 0.0 } else { 6.0 * 0.02 });
            }
            let (kb, _) = p.layer_view(&b, 0, 7, &mut scr);
            assert!((kb[1][8] - 6.0).abs() <= tol, "COW copied committed rows");
            assert!((kb[1][16] - 42.0).abs() <= tol, "new row landed in the copy");
            p.release(a);
            p.release(b);
        }
    }

    #[test]
    fn identical_streams_dedup_at_freeze() {
        for dtype in [KvDtype::F32, KvDtype::Int8, KvDtype::Fp8E4M3] {
            let mut p = pool_dt(8, dtype);
            let toks: Vec<u8> = (1..6).collect();
            let mut a = BlockTable::new(64);
            let mut b = BlockTable::new(64);
            // Neither is frozen when the other starts (same admission round).
            p.attach_prefix(&mut a, &toks);
            p.attach_prefix(&mut b, &toks);
            run_tokens(&mut p, &mut a, &toks);
            run_tokens(&mut p, &mut b, &toks);
            assert_eq!(p.stats.dedup_merges, 1, "{dtype:?}");
            assert_eq!(a.block_ids()[0], b.block_ids()[0], "full blocks converged");
            assert_ne!(a.block_ids()[1], b.block_ids()[1], "partial tails stay private");
            assert_eq!(p.blocks_in_use(), 3);
            p.release(a);
            p.release(b);
        }
    }

    #[test]
    fn lru_eviction_and_stale_chain_safety() {
        let mut p = pool(4); // tight: 4 blocks
        let prompt: Vec<u8> = (50..59).collect(); // 9 tokens → 2 full + tail
        let mut a = BlockTable::new(64);
        p.attach_prefix(&mut a, &prompt);
        run_tokens(&mut p, &mut a, &prompt);
        p.release(a); // 2 cached blocks remain
        assert_eq!(p.evictable_blocks(), 2);
        // A new 12-token sequence needs 3 blocks: 1 free + grow to cap +
        // evict the LRU cached block.
        let other: Vec<u8> = (100..112).collect();
        let mut b = BlockTable::new(64);
        assert_eq!(p.attach_prefix(&mut b, &other), 0);
        run_tokens(&mut p, &mut b, &other);
        assert!(p.stats.evictions >= 1, "tight pool must evict");
        p.release(b);
        // The evicted parent chain must never serve a stale hit.
        let mut c = BlockTable::new(64);
        let shared = p.attach_prefix(&mut c, &prompt);
        let bt = p.block_tokens();
        // Either the chain root survived (shared ≥ 1 block) or nothing
        // matches — but a partial/stale chain can only match a prefix of
        // what was cached, never wrong content.
        assert!(shared % bt == 0 && shared <= 8);
        if shared > 0 {
            // Attached blocks must carry the right K rows for layer 0.
            let mut scr = KvScratch::new();
            let (ks, _) = p.layer_view(&c, 0, shared, &mut scr);
            for (bi, seg) in ks.iter().enumerate() {
                for r in 0..bt {
                    assert_eq!(seg[r * 8], prompt[bi * bt + r] as f32, "stale KV served");
                }
            }
        }
        p.release(c);
    }

    #[test]
    fn slot_reuse_resets_quantized_scales() {
        // A freed block's stale amax must not leak into its next tenant:
        // write huge rows, free, then write tiny rows into the recycled
        // slot and check they survive quantization.
        let mut p = pool_dt(8, KvDtype::Int8);
        let mut a = BlockTable::new(64);
        p.prepare_tokens(&mut a, 4);
        for pos in 0..4 {
            for li in 0..2 {
                p.write_row(&a, li, pos, &[1000.0; 8], &[-1000.0; 8]);
            }
        }
        // Don't commit: the partial block goes straight to the free list.
        p.release(a);
        let mut b = BlockTable::new(64);
        run_tokens(&mut p, &mut b, &[2, 2, 2]); // rows ≈ 2.5 max
        let mut scr = KvScratch::new();
        let (ks, _) = p.layer_view(&b, 0, 3, &mut scr);
        // On a stale 1000.0 scale, 2.0 would quantize to 0.
        assert!((ks[0][0] - 2.0).abs() < 0.05, "stale scale survived slot reuse: {}", ks[0][0]);
        p.release(b);
    }

    #[test]
    fn release_trims_to_budget() {
        let mut p = pool(2);
        let mut a = BlockTable::new(64);
        run_tokens(&mut p, &mut a, &(0..8).collect::<Vec<u8>>()); // 2 full blocks
        assert_eq!(p.blocks_in_use(), 2);
        p.release(a);
        // Both froze; in_use (2) ≤ budget (2) → stay cached.
        assert_eq!(p.blocks_in_use(), 2);
        let mut b = BlockTable::new(64);
        run_tokens(&mut p, &mut b, &[99, 98, 97, 96, 95]); // needs 2 blocks → evicts
        assert!(p.stats.evictions >= 1);
        p.release(b);
        assert!(p.blocks_in_use() <= 2, "release trims residency to the budget");
    }

    #[test]
    #[should_panic(expected = "BlockPool exhausted")]
    fn exhaustion_panics_loudly() {
        let c = cfg();
        // Budget of 1 block but max_seq forces the cap to 64/4 = 16 with
        // bt=4; hold every block with live tables to truly exhaust.
        let bb = BlockPool::block_bytes_for(c.n_layer, 4, c.d_model, KvDtype::F32);
        let mut p = BlockPool::with_params(&c, bb, 4, KvDtype::F32);
        let mut tables = Vec::new();
        for i in 0..17u8 {
            let mut t = BlockTable::new(64);
            run_tokens(&mut p, &mut t, &[i, i, i, i]);
            tables.push(t);
        }
    }
}
