//! Paged forward pass: prefill **and** decode over the shared
//! [`BlockPool`], ragged across sequences.
//!
//! [`Model::forward_paged`] is one function for both phases — each
//! sequence contributes `n_new ≥ 1` new tokens on top of its
//! [`BlockTable`], and every linear layer runs **one** fused GEMM over
//! the stacked `[Σ n_new, d]` activations. With one-token slices it is
//! the paged twin of [`Model::decode_step`]; with whole prompt suffixes
//! it is **batched multi-prompt prefill**, amortizing the (compressed)
//! weight streams across every prompt admitted in a scheduling round
//! exactly as PR 1's fused decode amortizes them across sequences.
//! Each of those weight streams is itself packed: quantized dense
//! planes serve real int8/nibble codes through the fused
//! [`crate::tensor::matmul_q_into`] GEMM (`sdq::qmat`, bit-identical
//! to the f32 view), so the per-round traffic the scheduler accounts
//! in `Metrics::weight_bytes_streamed` is ~4× (int8) to ~7× (fp4)
//! below dense f32.
//!
//! Attention reads K/V *through the block tables*: per layer, an f32
//! pool hands back one borrowed row segment per block per sequence via
//! [`BlockPool::layer_views`] (zero-copy, gather-free), while a
//! quantized pool (fp8/int8 blocks with per-block-per-layer scales)
//! hands back raw *code* segments via [`BlockPool::layer_code_views`]
//! and the shared [`Model::attention_kv`] substrate decodes them in
//! register ([`crate::kv::qattn`]) — no per-layer [`KvScratch`]
//! staging, bit-identical to dequantize-then-attend. Because every
//! kernel on the path is row-independent, an **f32** pool's logits are
//! bit-identical to the chunked per-request cache path
//! ([`Model::forward_cached`]) — the property tests pin this; quantized
//! pools trade bounded KV error for ~4× pool capacity
//! (tolerance-tested, and `tests/qattn.rs` pins the quantized-domain
//! read against the scratch route bit-for-bit).

use super::forward::{KvSegs, SeqKv};
use super::ops::*;
use super::{Arch, Model};
use crate::data::embed;
use crate::kv::{BlockPool, BlockTable, KvDtype, KvScratch};
use crate::tensor::{matmul, Matrix};

impl Model {
    /// Advance `n_seq` sequences by their `new_tokens[i]` (≥ 1 each) on
    /// top of their block tables, through one fused ragged forward.
    /// Returns the **last-position** logits per sequence,
    /// `[n_seq, vocab]` (row `i` seeds sequence `i`'s next sample) —
    /// bit-identical to what per-sequence [`Model::forward_cached`]
    /// calls would produce.
    ///
    /// Tables must already hold any shared prefix
    /// ([`BlockPool::attach_prefix`]); this call allocates (and, for
    /// forked tables, copy-on-writes) the blocks the new rows land in,
    /// then commits them — freezing newly-filled blocks into the pool's
    /// prefix index.
    pub fn forward_paged(
        &self,
        new_tokens: &[&[u8]],
        pool: &mut BlockPool,
        tables: &mut [&mut BlockTable],
    ) -> Matrix {
        let mut scratch = KvScratch::new();
        self.forward_paged_in(new_tokens, pool, tables, &mut scratch)
    }

    /// [`Self::forward_paged`] with a caller-owned [`KvScratch`] — the
    /// scheduler holds one scratch for the whole serving run so warm
    /// rounds never reallocate the dequant arena (the f32 fallback
    /// paths; the quantized hot path reads codes directly and does not
    /// touch it).
    pub fn forward_paged_in(
        &self,
        new_tokens: &[&[u8]],
        pool: &mut BlockPool,
        tables: &mut [&mut BlockTable],
        scratch: &mut KvScratch,
    ) -> Matrix {
        let (x, offs) = self.paged_core(new_tokens, pool, tables, scratch);
        // Only each sequence's last position seeds sampling: project
        // just those rows through the tied head. Row-independent GEMMs
        // make this bit-identical to projecting all rows and selecting.
        let last_rows: Vec<usize> =
            new_tokens.iter().enumerate().map(|(i, t)| offs[i] + t.len() - 1).collect();
        matmul(&gather_rows(&x, &last_rows), &self.tok_emb)
    }

    /// The speculative-verify flavour of [`Self::forward_paged`]: same
    /// fused ragged forward, but it returns logits for **every** new
    /// position (`[Σ n_new, vocab]`; sequence `i`'s rows start at
    /// `offs[i]`). The acceptance engine needs all positions — each
    /// drafted token is judged against the greedy choice at the
    /// position before it. Row-independence makes every returned row
    /// bit-identical to what a last-position-only call would produce
    /// for that prefix.
    pub fn forward_paged_spec(
        &self,
        new_tokens: &[&[u8]],
        pool: &mut BlockPool,
        tables: &mut [&mut BlockTable],
    ) -> (Matrix, Vec<usize>) {
        let mut scratch = KvScratch::new();
        self.forward_paged_spec_in(new_tokens, pool, tables, &mut scratch)
    }

    /// [`Self::forward_paged_spec`] with a caller-owned [`KvScratch`]
    /// (see [`Self::forward_paged_in`]).
    pub fn forward_paged_spec_in(
        &self,
        new_tokens: &[&[u8]],
        pool: &mut BlockPool,
        tables: &mut [&mut BlockTable],
        scratch: &mut KvScratch,
    ) -> (Matrix, Vec<usize>) {
        let (x, offs) = self.paged_core(new_tokens, pool, tables, scratch);
        (matmul(&x, &self.tok_emb), offs)
    }

    /// Shared body of the paged forwards: embed, run every block with
    /// staged pool writes and ragged block-table attention, apply the
    /// final norm. Returns the normed hidden states `[Σ n_new, d]` and
    /// each sequence's starting row offset.
    fn paged_core(
        &self,
        new_tokens: &[&[u8]],
        pool: &mut BlockPool,
        tables: &mut [&mut BlockTable],
        scratch: &mut KvScratch,
    ) -> (Matrix, Vec<usize>) {
        let n_seq = new_tokens.len();
        assert_eq!(n_seq, tables.len(), "one block table per sequence");
        assert!(n_seq > 0, "forward_paged needs at least one sequence");
        let d = self.cfg.d_model;
        // Row layout: sequence i's new tokens occupy rows
        // offs[i]..offs[i] + n_new_i of the stacked activations.
        let mut offs = Vec::with_capacity(n_seq);
        let mut flat: Vec<u8> = Vec::new();
        for (toks, tb) in new_tokens.iter().zip(tables.iter()) {
            assert!(!toks.is_empty(), "each sequence needs at least one new token");
            assert!(tb.len() + toks.len() <= self.cfg.max_seq, "KV capacity overflow");
            offs.push(flat.len());
            flat.extend_from_slice(toks);
        }
        let total = flat.len();
        // Allocate (and copy-on-write) every block the new rows will
        // land in up front, so the layer loop only writes and reads.
        for (toks, tb) in new_tokens.iter().zip(tables.iter_mut()) {
            pool.prepare_tokens(tb, toks.len());
        }
        let pasts: Vec<usize> = tables.iter().map(|t| t.len()).collect();

        let mut x = embed(&flat, &self.tok_emb);
        if let Some(pe) = &self.pos_emb {
            for (i, toks) in new_tokens.iter().enumerate() {
                for j in 0..toks.len() {
                    let row = x.row_mut(offs[i] + j);
                    for (v, p) in row.iter_mut().zip(pe.row(pasts[i] + j)) {
                        *v += *p;
                    }
                }
            }
        }
        {
            // Read-only table views for the layer loop (commit below
            // needs the tables mutably again).
            let tb_views: Vec<&BlockTable> = tables.iter().map(|t| &**t).collect();
            let uptos: Vec<usize> =
                new_tokens.iter().zip(&pasts).map(|(t, p)| p + t.len()).collect();
            for (li, blk) in self.blocks.iter().enumerate() {
                let mut h = x.clone();
                self.norm1(blk, &mut h);
                let mut q = Matrix::zeros(total, d);
                let mut k_new = Matrix::zeros(total, d);
                let mut v_new = Matrix::zeros(total, d);
                blk.q.lin.forward_into(&h, &mut q);
                blk.k.lin.forward_into(&h, &mut k_new);
                blk.v.lin.forward_into(&h, &mut v_new);
                for (i, toks) in new_tokens.iter().enumerate() {
                    for j in 0..toks.len() {
                        pool.write_row(
                            tb_views[i],
                            li,
                            pasts[i] + j,
                            k_new.row(offs[i] + j),
                            v_new.row(offs[i] + j),
                        );
                    }
                }
                // Ragged attention through the block tables: one
                // borrowed segment per block, walked in place. F32
                // pools borrow storage zero-copy; quantized pools hand
                // out raw code segments and attention decodes them in
                // register (the quantized-domain path — bit-identical
                // to dequantizing into scratch first, without the
                // staging traffic).
                let attn = {
                    let pool_ref: &BlockPool = pool;
                    let dtype = pool_ref.dtype();
                    let seqs: Vec<SeqKv> = if dtype == KvDtype::F32 {
                        pool_ref
                            .layer_views(&tb_views, li, &uptos, scratch)
                            .into_iter()
                            .enumerate()
                            .map(|(i, (k, v))| SeqKv {
                                q_row0: offs[i],
                                n_new: new_tokens[i].len(),
                                past: pasts[i],
                                segs: KvSegs::F32 { k, v },
                                seg_tokens: pool_ref.block_tokens(),
                            })
                            .collect()
                    } else {
                        pool_ref
                            .layer_code_views(&tb_views, li, &uptos)
                            .into_iter()
                            .enumerate()
                            .map(|(i, (k, v))| SeqKv {
                                q_row0: offs[i],
                                n_new: new_tokens[i].len(),
                                past: pasts[i],
                                segs: KvSegs::Quant { dtype, k, v },
                                seg_tokens: pool_ref.block_tokens(),
                            })
                            .collect()
                    };
                    self.attention_kv(&q, &seqs)
                };
                let mut o_out = Matrix::zeros(total, d);
                blk.o.lin.forward_into(&attn, &mut o_out);
                add_inplace(&mut x, &o_out);

                let mut h = x.clone();
                self.norm2(blk, &mut h);
                let mut a = Matrix::zeros(total, self.cfg.d_ff);
                blk.ff1.lin.forward_into(&h, &mut a);
                match self.cfg.arch {
                    Arch::Gpt => map_inplace(&mut a, gelu),
                    Arch::Llama => {
                        let ff3 = blk.ff3.as_ref().expect("llama gate");
                        let mut g = Matrix::zeros(h.rows, self.cfg.d_ff);
                        ff3.lin.forward_into(&h, &mut g);
                        map_inplace(&mut a, silu);
                        mul_inplace(&mut a, &g);
                    }
                }
                let mut m_out = Matrix::zeros(total, d);
                blk.ff2.lin.forward_into(&a, &mut m_out);
                add_inplace(&mut x, &m_out);
            }
        }
        // Commit: advance lengths and freeze newly-filled blocks into
        // the prefix index (identical concurrent streams converge here).
        for (toks, tb) in new_tokens.iter().zip(tables.iter_mut()) {
            pool.commit(tb, toks);
        }
        match self.cfg.arch {
            Arch::Gpt => layernorm(&mut x, &self.lnf_g, self.lnf_b.as_deref(), self.cfg.eps),
            Arch::Llama => rmsnorm(&mut x, &self.lnf_g, self.cfg.eps),
        }
        (x, offs)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::tiny_model;
    use super::super::{Arch, Model};
    use crate::kv::{BlockPool, BlockTable, KvDtype, KV_BLOCK_TOKENS};
    use crate::model::generate::KvCache;

    fn pool_for(m: &Model) -> BlockPool {
        BlockPool::new(&m.cfg, 64 << 20)
    }

    #[test]
    fn paged_quantized_tracks_f32_logits() {
        // Quantized KV perturbs logits within a bounded envelope; the
        // f32 path stays the exact reference (pinned by the tests
        // below). int8 (8-bit uniform grid) must track tighter than fp8
        // (3-bit mantissa).
        for arch in [Arch::Gpt, Arch::Llama] {
            let m = tiny_model(arch, 36);
            let prompt: Vec<u8> = (5..45).collect(); // crosses 2 block boundaries
            let mut pf = pool_for(&m);
            let mut tf = BlockTable::new(m.cfg.max_seq);
            let reference = m.forward_paged(&[&prompt], &mut pf, &mut [&mut tf]);
            let norm: f32 = reference.row(0).iter().map(|x| x * x).sum::<f32>().sqrt();
            for (dtype, tol) in [(KvDtype::Int8, 0.15), (KvDtype::Fp8E4M3, 0.40)] {
                let mut pq = BlockPool::with_dtype(&m.cfg, 64 << 20, dtype);
                let mut tq = BlockTable::new(m.cfg.max_seq);
                let logits = m.forward_paged(&[&prompt], &mut pq, &mut [&mut tq]);
                let err: f32 = logits
                    .row(0)
                    .iter()
                    .zip(reference.row(0))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    .sqrt();
                assert!(
                    err <= tol * norm,
                    "{arch:?}/{dtype:?}: rel logit error {} > {tol}",
                    err / norm
                );
            }
        }
    }

    #[test]
    fn paged_prefill_matches_forward_cached() {
        for arch in [Arch::Gpt, Arch::Llama] {
            let m = tiny_model(arch, 31);
            // Crosses two block boundaries (37 > 2 × KV_BLOCK_TOKENS).
            let prompt: Vec<u8> = (3..40).collect();
            assert!(prompt.len() > 2 * KV_BLOCK_TOKENS);
            let mut cache = KvCache::new(&m);
            let reference = m.forward_cached(&prompt, &mut cache);
            let mut pool = pool_for(&m);
            let mut tb = BlockTable::new(m.cfg.max_seq);
            let logits = m.forward_paged(&[&prompt], &mut pool, &mut [&mut tb]);
            assert_eq!(logits.rows, 1);
            assert_eq!(
                logits.row(0),
                reference.row(reference.rows - 1),
                "{arch:?}: paged prefill diverged"
            );
            assert_eq!(tb.len(), prompt.len());
            assert_eq!(tb.block_ids().len(), prompt.len().div_ceil(KV_BLOCK_TOKENS));
        }
    }

    #[test]
    fn paged_decode_matches_decode_step() {
        for arch in [Arch::Gpt, Arch::Llama] {
            let m = tiny_model(arch, 33);
            let prompt: Vec<u8> = (1..19).collect();
            let mut cache = KvCache::new(&m);
            m.forward_cached(&prompt, &mut cache);
            let mut pool = pool_for(&m);
            let mut tb = BlockTable::new(m.cfg.max_seq);
            m.forward_paged(&[&prompt], &mut pool, &mut [&mut tb]);
            let mut t = 7u8;
            for step in 0..4 {
                let a = m.decode_step(&[t], &mut [&mut cache]);
                let b = m.forward_paged(&[&[t]], &mut pool, &mut [&mut tb]);
                assert_eq!(a.row(0), b.row(0), "{arch:?} step {step}: paged decode diverged");
                assert_eq!(cache.len, tb.len());
                t = t.wrapping_mul(31).wrapping_add(step);
            }
        }
    }

    #[test]
    fn batched_multi_prompt_prefill_matches_single() {
        let m = tiny_model(Arch::Llama, 34);
        let prompts: [&[u8]; 3] = [b"abcdefghijklmnopqrst", b"xy", b"hello world"];
        // Per-prompt reference rows, each on a fresh pool.
        let singles: Vec<Vec<f32>> = prompts
            .iter()
            .map(|p| {
                let mut pool = pool_for(&m);
                let mut tb = BlockTable::new(m.cfg.max_seq);
                let l = m.forward_paged(&[p], &mut pool, &mut [&mut tb]);
                l.row(0).to_vec()
            })
            .collect();
        // One fused ragged prefill over all three prompts.
        let mut pool = pool_for(&m);
        let mut tables: Vec<BlockTable> =
            prompts.iter().map(|_| BlockTable::new(m.cfg.max_seq)).collect();
        let mut refs: Vec<&mut BlockTable> = tables.iter_mut().collect();
        let logits = m.forward_paged(&prompts, &mut pool, &mut refs);
        assert_eq!(logits.rows, 3);
        for (i, want) in singles.iter().enumerate() {
            assert_eq!(logits.row(i), &want[..], "prompt {i}: fused prefill diverged");
        }
        for (tb, p) in tables.iter().zip(&prompts) {
            assert_eq!(tb.len(), p.len());
        }
    }

    #[test]
    fn spec_forward_matches_stepwise_rows() {
        // The fused multi-token verify forward must return, per
        // position, exactly the logits a 1-token-at-a-time decode would
        // have produced (f32 pool ⇒ bit-identical) — the property the
        // truncate-based speculative rollback rests on.
        for arch in [Arch::Gpt, Arch::Llama] {
            let m = tiny_model(arch, 37);
            let prompt: Vec<u8> = (5..25).collect(); // 20 tokens
            let mut p1 = pool_for(&m);
            let mut t1 = BlockTable::new(m.cfg.max_seq);
            m.forward_paged(&[&prompt], &mut p1, &mut [&mut t1]);
            let l_a = m.forward_paged(&[&[7u8]], &mut p1, &mut [&mut t1]);
            let l_b = m.forward_paged(&[&[9u8]], &mut p1, &mut [&mut t1]);
            let mut p2 = pool_for(&m);
            let mut t2 = BlockTable::new(m.cfg.max_seq);
            m.forward_paged(&[&prompt], &mut p2, &mut [&mut t2]);
            let (logits, offs) = m.forward_paged_spec(&[&[7u8, 9]], &mut p2, &mut [&mut t2]);
            assert_eq!(logits.rows, 2);
            assert_eq!(offs, vec![0]);
            assert_eq!(logits.row(0), l_a.row(0), "{arch:?}: verify position 0 diverged");
            assert_eq!(logits.row(1), l_b.row(0), "{arch:?}: verify position 1 diverged");
        }
    }

    #[test]
    fn spec_forward_ragged_offsets() {
        // Mixed draft lengths in one fused verify: offsets partition the
        // stacked rows, and each sequence's rows match its solo run.
        let m = tiny_model(Arch::Llama, 38);
        let (pa, pb): (Vec<u8>, Vec<u8>) = ((1..9).collect(), (30..47).collect());
        let solo = |prompt: &[u8], toks: &[u8]| {
            let mut pool = pool_for(&m);
            let mut tb = BlockTable::new(m.cfg.max_seq);
            m.forward_paged(&[prompt], &mut pool, &mut [&mut tb]);
            let (l, _) = m.forward_paged_spec(&[toks], &mut pool, &mut [&mut tb]);
            l
        };
        let la = solo(&pa, &[3, 4, 5]);
        let lb = solo(&pb, &[6]);
        let mut pool = pool_for(&m);
        let mut ta = BlockTable::new(m.cfg.max_seq);
        let mut tb = BlockTable::new(m.cfg.max_seq);
        m.forward_paged(&[&pa, &pb], &mut pool, &mut [&mut ta, &mut tb]);
        let (l, offs) =
            m.forward_paged_spec(&[&[3u8, 4, 5], &[6u8]], &mut pool, &mut [&mut ta, &mut tb]);
        assert_eq!(offs, vec![0, 3]);
        assert_eq!(l.rows, 4);
        for r in 0..3 {
            assert_eq!(l.row(r), la.row(r), "seq a row {r} diverged in the ragged batch");
        }
        assert_eq!(l.row(3), lb.row(0), "seq b diverged in the ragged batch");
    }

    #[test]
    fn prefill_on_attached_prefix_matches_cold() {
        // A sequence whose prompt prefix came from the cache must emit
        // the same logits as one that computed everything itself.
        for arch in [Arch::Gpt, Arch::Llama] {
            let m = tiny_model(arch, 35);
            let prompt: Vec<u8> = (40..80).collect(); // 40 tokens → 2 full blocks
            let mut pool = pool_for(&m);
            let mut a = BlockTable::new(m.cfg.max_seq);
            let cold = m.forward_paged(&[&prompt], &mut pool, &mut [&mut a]);
            pool.release(a);
            let mut b = BlockTable::new(m.cfg.max_seq);
            let shared = pool.attach_prefix(&mut b, &prompt);
            assert_eq!(shared, 2 * KV_BLOCK_TOKENS, "{arch:?}: prefix must hit");
            let warm = m.forward_paged(&[&prompt[shared..]], &mut pool, &mut [&mut b]);
            assert_eq!(cold.row(0), warm.row(0), "{arch:?}: shared prefix perturbed logits");
            pool.release(b);
        }
    }
}
