//! Elementwise / normalization / positional ops for the transformer.

use crate::tensor::Matrix;

/// In-place LayerNorm over the last dim with gain `g` and optional bias.
pub fn layernorm(x: &mut Matrix, g: &[f32], b: Option<&[f32]>, eps: f32) {
    assert_eq!(x.cols, g.len());
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let mean: f32 = row.iter().sum::<f32>() / row.len() as f32;
        let var: f32 =
            row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (var + eps).sqrt();
        match b {
            Some(b) => {
                for ((v, gi), bi) in row.iter_mut().zip(g).zip(b) {
                    *v = (*v - mean) * inv * gi + bi;
                }
            }
            None => {
                for (v, gi) in row.iter_mut().zip(g) {
                    *v = (*v - mean) * inv * gi;
                }
            }
        }
    }
}

/// In-place RMSNorm (LLaMA-style) over the last dim.
pub fn rmsnorm(x: &mut Matrix, g: &[f32], eps: f32) {
    assert_eq!(x.cols, g.len());
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (v, gi) in row.iter_mut().zip(g) {
            *v = *v * inv * gi;
        }
    }
}

/// GELU (tanh approximation, matches JAX `jax.nn.gelu(approximate=True)`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f32).tanh())
}

/// SiLU / swish.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// In-place map.
pub fn map_inplace(x: &mut Matrix, f: impl Fn(f32) -> f32 + Sync) {
    for v in &mut x.data {
        *v = f(*v);
    }
}

/// In-place elementwise product `a *= b`.
pub fn mul_inplace(a: &mut Matrix, b: &Matrix) {
    assert_eq!(a.data.len(), b.data.len());
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x *= *y;
    }
}

/// In-place residual add `a += b`.
pub fn add_inplace(a: &mut Matrix, b: &Matrix) {
    assert_eq!(a.data.len(), b.data.len());
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += *y;
    }
}

/// Row-wise in-place softmax with optional causal masking offset:
/// row `i` may only attend to columns `0..=i + past` (KV-cache decode
/// passes `past = cached_len`). Delegates each row's live prefix to
/// [`softmax_slice`], so the full-sequence and KV-cached decode paths
/// share one numerical implementation *structurally*.
pub fn causal_softmax(scores: &mut Matrix, past: usize) {
    for r in 0..scores.rows {
        let limit = (r + past + 1).min(scores.cols);
        let row = scores.row_mut(r);
        softmax_slice(&mut row[..limit]);
        for v in row[limit..].iter_mut() {
            *v = 0.0;
        }
    }
}

/// Rotary position embedding applied in place to a `[S, dh]` per-head
/// slice whose rows correspond to absolute positions `pos0..pos0+S`.
pub fn rope_inplace(x: &mut Matrix, pos0: usize, theta_base: f32) {
    assert_eq!(x.cols % 2, 0, "head dim must be even for RoPE");
    for r in 0..x.rows {
        rope_row_inplace(x.row_mut(r), pos0 + r, theta_base);
    }
}

/// RoPE for a single `[dh]` head row at absolute position `pos` (the
/// ragged-decode attention path rotates rows one at a time, straight off
/// the borrowed KV prefix).
#[inline]
pub fn rope_row_inplace(row: &mut [f32], pos: usize, theta_base: f32) {
    let dh = row.len();
    debug_assert_eq!(dh % 2, 0, "head dim must be even for RoPE");
    let posf = pos as f32;
    for i in 0..dh / 2 {
        let theta = posf / theta_base.powf(2.0 * i as f32 / dh as f32);
        let (sin, cos) = theta.sin_cos();
        let (a, b) = (row[2 * i], row[2 * i + 1]);
        row[2 * i] = a * cos - b * sin;
        row[2 * i + 1] = a * sin + b * cos;
    }
}

/// In-place softmax over an attention score slice — the shared kernel
/// behind [`causal_softmax`] (full-sequence path) and the KV-cached
/// decode paths, which express the causal mask by bounding the slice at
/// the causal limit. One implementation → batched and full-sequence
/// attention agree bit-for-bit.
pub fn softmax_slice(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, v| m.max(*v));
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum.max(1e-30);
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Copy the given rows of `x` into a fresh `[rows.len(), x.cols]`
/// matrix. The paged prefill path uses this to project only each
/// sequence's *last* position through the tied LM head instead of all
/// prompt rows — row-independent GEMMs make the result bit-identical to
/// projecting everything and selecting.
pub fn gather_rows(x: &Matrix, rows: &[usize]) -> Matrix {
    let mut out = Matrix::zeros(rows.len(), x.cols);
    for (i, &r) in rows.iter().enumerate() {
        out.row_mut(i).copy_from_slice(x.row(r));
    }
    out
}

/// Log-softmax cross-entropy over logits `[n, vocab]` against `targets`;
/// returns summed negative log-likelihood in nats (divide by `n` then
/// `exp` for perplexity).
pub fn cross_entropy_sum(logits: &Matrix, targets: &[u8]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut nll = 0.0f64;
    for (r, t) in targets.iter().enumerate() {
        let row = logits.row(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, v| m.max(*v));
        let logsum: f64 =
            (row.iter().map(|v| ((v - max) as f64).exp()).sum::<f64>()).ln() + max as f64;
        nll += logsum - row[*t as usize] as f64;
    }
    nll
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut x = Matrix::from_vec(1, 4, vec![1., 2., 3., 4.]);
        layernorm(&mut x, &[1.0; 4], None, 1e-5);
        let mean: f32 = x.data.iter().sum::<f32>() / 4.0;
        let var: f32 = x.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let mut x = Matrix::from_vec(1, 4, vec![2., -2., 2., -2.]);
        rmsnorm(&mut x, &[1.0; 4], 1e-6);
        for v in &x.data {
            assert!((v.abs() - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn causal_softmax_masks_future() {
        let mut s = Matrix::from_vec(2, 3, vec![1., 5., 9., 1., 1., 9.]);
        causal_softmax(&mut s, 0);
        // row 0 sees only col 0
        assert_eq!(s.row(0), &[1.0, 0.0, 0.0]);
        // row 1 sees cols 0..=1, equal logits → 0.5/0.5
        assert!((s.at(1, 0) - 0.5).abs() < 1e-6);
        assert!((s.at(1, 1) - 0.5).abs() < 1e-6);
        assert_eq!(s.at(1, 2), 0.0);
    }

    #[test]
    fn causal_softmax_with_past_sees_cache() {
        let mut s = Matrix::from_vec(1, 4, vec![1., 1., 1., 1.]);
        causal_softmax(&mut s, 2); // row 0 sees cols 0..=2
        assert!((s.at(0, 0) - 1.0 / 3.0).abs() < 1e-6);
        assert_eq!(s.at(0, 3), 0.0);
    }

    #[test]
    fn rope_preserves_norm_and_is_position_dependent() {
        let base = Matrix::from_vec(2, 4, vec![1., 0., 0.5, -0.5, 1., 0., 0.5, -0.5]);
        let mut x = base.clone();
        rope_inplace(&mut x, 0, 10000.0);
        // position 0 row unchanged
        assert_eq!(x.row(0), base.row(0));
        // position 1 row rotated but norm preserved
        assert_ne!(x.row(1), base.row(1));
        let n0: f32 = base.row(1).iter().map(|v| v * v).sum();
        let n1: f32 = x.row(1).iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-5);
    }

    #[test]
    fn softmax_slice_matches_causal_row() {
        let mut m = Matrix::from_vec(1, 5, vec![0.3, -1.0, 2.0, 0.1, 9.0]);
        causal_softmax(&mut m, 2); // row 0 sees cols 0..=2
        let mut s = [0.3f32, -1.0, 2.0];
        softmax_slice(&mut s);
        for (a, b) in m.row(0)[..3].iter().zip(&s) {
            assert_eq!(a, b, "bitwise equality expected");
        }
        assert_eq!(m.at(0, 3), 0.0);
    }

    #[test]
    fn rope_offset_matches_absolute() {
        // Processing row at offset pos0=5 equals processing position 5.
        let row = vec![0.3f32, -0.7, 1.1, 0.2];
        let mut a = Matrix::from_vec(6, 4, (0..24).map(|i| (i % 4) as f32).collect());
        for i in 0..4 {
            *a.at_mut(5, i) = row[i];
        }
        rope_inplace(&mut a, 0, 10000.0);
        let mut b = Matrix::from_vec(1, 4, row);
        rope_inplace(&mut b, 5, 10000.0);
        for i in 0..4 {
            assert!((a.at(5, i) - b.at(0, i)).abs() < 1e-5);
        }
    }

    #[test]
    fn gather_rows_copies() {
        let x = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let g = gather_rows(&x, &[2, 0]);
        assert_eq!(g.rows, 2);
        assert_eq!(g.row(0), &[5., 6.]);
        assert_eq!(g.row(1), &[1., 2.]);
    }

    #[test]
    fn cross_entropy_uniform() {
        let logits = Matrix::zeros(3, 256);
        let nll = cross_entropy_sum(&logits, &[0, 17, 255]);
        let per = nll / 3.0;
        assert!((per - (256.0f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn gelu_silu_sane() {
        assert!(gelu(0.0).abs() < 1e-9);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
    }
}
