//! Preemptive-scheduling stress tests (PR 5's archetype focus).
//!
//! Two layers of randomized coverage, both bit-exactness oracles:
//!
//! * **Scheduler level** — N ragged requests against a pool a fraction
//!   of their combined footprint, preemption on: every sequence's final
//!   greedy tokens must be **bit-identical** to an unconstrained-pool
//!   run, for every `KvDtype` × drafter (off / ngram) combination, with
//!   [`BlockPool::assert_consistent`] walked after *every* scheduling
//!   round. This is the end-to-end claim: swap-out/swap-in (plus the
//!   f32 re-prefill fallback, plus speculative rollback riding on top)
//!   is invisible in the output.
//! * **Pool level** — random interleavings of
//!   extend / truncate / fork / checkpoint+rollback / suspend / resume
//!   / cache churn against a **mirror pool** that applies the same
//!   mutation history without ever suspending: at the end, every
//!   sequence's dequantized K/V must match the mirror bit-for-bit.
//!   Churn evicts cached blocks while sequences are swapped, so the
//!   resume attach-miss and re-prefill paths are exercised for real.
//!
//! Runs on tiny in-memory models — no artifacts needed, always on.

use sdq::coordinator::batcher::{BatchPolicy, Batcher};
use sdq::coordinator::scheduler::Scheduler;
use sdq::coordinator::{assert_bit_identical, Request, Response};
use sdq::kv::{BlockPool, BlockTable, KvDtype, KvScratch, Snapshot, KV_BLOCK_TOKENS};
use sdq::model::generate::KvCache;
use sdq::model::testutil::tiny_model;
use sdq::model::{Arch, Model, ModelConfig};
use sdq::spec::SpecPolicy;
use sdq::util::rng::Rng;

// ---------------------------------------------------------------------
// Scheduler-level stress
// ---------------------------------------------------------------------

/// Seeded random workload: ragged prompts (a third sharing a one-block
/// prefix), decode budgets long enough that every sequence crosses a
/// block boundary mid-decode (what makes swap pressure inevitable on a
/// 3–4-block pool), one sampled request riding along.
fn random_requests(rng: &mut Rng, n: u64) -> Vec<Request> {
    let prefix: Vec<u8> = (0..KV_BLOCK_TOKENS as u8).map(|j| 120 + j).collect();
    (0..n)
        .map(|i| {
            // Every third request shares the prefix; the first two are
            // always short-prompt, so at least one concurrent pair forms
            // at any budget ≥ 2 blocks and swap pressure is structural,
            // not a seed lottery.
            let mut prompt = if i % 3 == 2 { prefix.clone() } else { Vec::new() };
            let extra = 2 + rng.below(9);
            prompt.extend((0..extra).map(|_| rng.below(120) as u8));
            let max_new = 15 + rng.below(4);
            let r = Request::new(i, prompt, max_new);
            // One sampled request per batch: its RNG stream must survive
            // swap-out/swap-in untouched.
            if i == n - 1 {
                r.with_temperature(0.7)
            } else {
                r
            }
        })
        .collect()
}

/// Drive a scheduler round-by-round with pool invariants checked after
/// every round; returns id-sorted responses + metrics.
fn run_rounds(
    model: &Model,
    policy: BatchPolicy,
    spec: Option<SpecPolicy>,
    reqs: Vec<Request>,
) -> (Vec<Response>, sdq::coordinator::metrics::Metrics) {
    let mut sched = Scheduler::with_spec(model, policy, spec);
    let mut batcher = Batcher::new();
    for r in reqs {
        batcher.enqueue(r);
    }
    let mut out = Vec::new();
    let mut rounds = 0;
    while sched.has_work(&batcher) {
        out.extend(sched.round(&mut batcher));
        sched.pool().assert_consistent();
        rounds += 1;
        assert!(rounds < 4000, "scheduler failed to drain (livelock?)");
    }
    assert_eq!(sched.pool().referenced_blocks(), 0, "retired sequences leaked blocks");
    assert_eq!(sched.swapped(), 0, "swapped sequences stranded at drain");
    out.sort_by_key(|r| r.id);
    (out, sched.metrics)
}

/// The headline stress property: for random workloads under a pool
/// 2–4 blocks tight, preemptive serving emits bit-identical greedy
/// tokens to an unconstrained pool — for every `KvDtype` × drafter.
#[test]
fn stress_preemption_bit_exact_every_dtype_and_drafter() {
    let blk_budget =
        |model: &Model, blocks: usize| blocks * KvCache::bytes_for_tokens(&model.cfg, 1);
    for seed in 0..3u64 {
        let arch = if seed % 2 == 0 { Arch::Gpt } else { Arch::Llama };
        let model = tiny_model(arch, 70 + seed);
        let mut rng = Rng::seed_from_u64(0xC0FFEE ^ seed);
        let n = 6 + rng.below(3) as u64;
        let reqs = random_requests(&mut rng, n);
        let budget_blocks = 3 + rng.below(2); // 3..=4 blocks
        let max_active = 4 + rng.below(4);
        for dtype in [KvDtype::F32, KvDtype::Int8, KvDtype::Fp8E4M3, KvDtype::Int4Outlier] {
            for drafter in ["off", "ngram"] {
                let mk_spec = || (drafter == "ngram").then(|| SpecPolicy::ngram(3));
                let roomy = BatchPolicy {
                    kv_dtype: Some(dtype),
                    max_active,
                    ..Default::default()
                };
                let tight = BatchPolicy {
                    kv_budget_bytes: blk_budget(&model, budget_blocks),
                    preempt: true,
                    ..roomy
                };
                let ctx = format!(
                    "seed {seed} / {arch:?} / {dtype:?} / {drafter} / {budget_blocks} blocks"
                );
                let (want, _) = run_rounds(&model, roomy, mk_spec(), reqs.clone());
                let (got, m) = run_rounds(&model, tight, mk_spec(), reqs.clone());
                assert_bit_identical(&ctx, &got, &want);
                assert_eq!(m.requests_completed, n, "{ctx}: dropped requests");
                assert!(m.preemptions > 0, "{ctx}: pressure workload never preempted");
                assert_eq!(m.resumes, m.preemptions, "{ctx}: swap-out without swap-in");
                if dtype != KvDtype::F32 {
                    assert_eq!(
                        m.resume_reprefill_tokens, 0,
                        "{ctx}: quantized resume must install bytes, never re-prefill"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Pool-level randomized interleaving vs a never-swapping mirror
// ---------------------------------------------------------------------

fn pool_cfg() -> ModelConfig {
    ModelConfig {
        name: "preempt-stress".into(),
        arch: Arch::Gpt,
        d_model: 8,
        n_layer: 2,
        n_head: 2,
        d_ff: 16,
        vocab: 256,
        max_seq: 32,
        eps: 1e-5,
        rope_theta: 10000.0,
        kv_dtype: KvDtype::F32,
    }
}

const BT: usize = 4;
const D: usize = 8;
const MAX_LANE_TOKENS: usize = 20;
const MAX_LANES: usize = 5;

fn stress_pools(dtype: KvDtype) -> (BlockPool, BlockPool) {
    let c = pool_cfg();
    let bb = |blocks: usize| {
        blocks * BlockPool::with_params(&c, 1, BT, dtype).block_bytes()
    };
    // The stress pool is sized so the worst-case *referenced* set (all
    // lanes at max length plus a churn table) always fits — preemption
    // pressure in this test comes from cache churn and the op mix, not
    // from admission control, which the scheduler-level stress covers.
    let stress_blocks = MAX_LANES * MAX_LANE_TOKENS.div_ceil(BT) + 3;
    let stress = BlockPool::with_params(&c, bb(stress_blocks), BT, dtype);
    let mirror = BlockPool::with_params(&c, bb(512), BT, dtype);
    (stress, mirror)
}

/// Deterministic row writer (same convention as the pool's own unit
/// tests): layer `li`'s K row for token `t` is `t + 0.5·li` everywhere,
/// V its negation — so replayed writes are bit-identical by value.
fn write_tokens(p: &mut BlockPool, t: &mut BlockTable, toks: &[u8]) {
    p.prepare_tokens(t, toks.len());
    for (j, tok) in toks.iter().enumerate() {
        let pos = t.len() + j;
        for li in 0..2 {
            let k = vec![(*tok as f32) + li as f32 * 0.5; D];
            let v = vec![-((*tok as f32) + li as f32 * 0.5); D];
            p.write_row(t, li, pos, &k, &v);
        }
    }
    p.commit(t, toks);
}

/// One stressed sequence: its table in the stress pool (or a snapshot
/// while swapped) and its twin in the mirror pool.
struct Lane {
    table: Option<BlockTable>,
    snap: Option<Snapshot>,
    mirror: BlockTable,
    len: usize,
}

impl Lane {
    /// Swap the lane back in (no-op if resident), replaying any rows
    /// the re-prefill fallback reports missing — the pool-level
    /// equivalent of the scheduler's resume forward.
    fn ensure_resident(&mut self, p: &mut BlockPool) -> &mut BlockTable {
        if let Some(snap) = self.snap.take() {
            let (mut tb, ready) = p.resume(&snap);
            if ready < snap.len() {
                let missing = snap.tokens()[ready..].to_vec();
                write_tokens(p, &mut tb, &missing);
            }
            assert_eq!(tb.len(), self.len, "resume rebuilt the wrong length");
            self.table = Some(tb);
        }
        self.table.as_mut().expect("resident lane")
    }
}

#[test]
fn stress_pool_interleavings_match_never_swapping_mirror() {
    for dtype in [KvDtype::F32, KvDtype::Int8, KvDtype::Fp8E4M3, KvDtype::Int4Outlier] {
        for seed in 0..6u64 {
            let ctx = format!("{dtype:?} seed {seed}");
            let mut rng = Rng::seed_from_u64(0xBADD00D ^ (seed * 1013));
            let (mut p, mut m) = stress_pools(dtype);
            let mut lanes: Vec<Lane> = Vec::new();
            // Seed two lanes with a shared first block so fork/COW and
            // dedup paths engage immediately.
            for _ in 0..2 {
                let toks: Vec<u8> = (0..BT as u8 + 2).map(|j| 10 + j).collect();
                let mut t = BlockTable::new(pool_cfg().max_seq);
                let mut c = BlockTable::new(pool_cfg().max_seq);
                write_tokens(&mut p, &mut t, &toks);
                write_tokens(&mut m, &mut c, &toks);
                lanes.push(Lane { table: Some(t), snap: None, mirror: c, len: toks.len() });
            }
            for _op in 0..60 {
                let li = rng.below(lanes.len());
                match rng.below(10) {
                    // extend 1..=4 tokens
                    0..=2 => {
                        let lane = &mut lanes[li];
                        let r = (1 + rng.below(4)).min(MAX_LANE_TOKENS - lane.len);
                        if r == 0 {
                            continue;
                        }
                        let toks: Vec<u8> = (0..r).map(|_| rng.below(180) as u8).collect();
                        let t = lane.ensure_resident(&mut p);
                        write_tokens(&mut p, t, &toks);
                        write_tokens(&mut m, &mut lane.mirror, &toks);
                        lane.len += r;
                    }
                    // truncate to a random shorter length
                    3 => {
                        let lane = &mut lanes[li];
                        if lane.len == 0 {
                            continue;
                        }
                        let new_len = rng.below(lane.len + 1);
                        let t = lane.ensure_resident(&mut p);
                        p.truncate(t, new_len);
                        m.truncate(&mut lane.mirror, new_len);
                        lane.len = new_len;
                    }
                    // fork into a new lane
                    4 => {
                        if lanes.len() >= MAX_LANES {
                            continue;
                        }
                        let (t_fork, m_fork, len) = {
                            let lane = &mut lanes[li];
                            let t = lane.ensure_resident(&mut p);
                            (p.fork(t), m.fork(&lane.mirror), lane.len)
                        };
                        lanes.push(Lane { table: Some(t_fork), snap: None, mirror: m_fork, len });
                    }
                    // speculative cycle: checkpoint, extend, roll back
                    5 => {
                        let lane = &mut lanes[li];
                        let r = (1 + rng.below(3)).min(MAX_LANE_TOKENS - lane.len);
                        if r == 0 {
                            continue;
                        }
                        let toks: Vec<u8> = (0..r).map(|_| 190 + rng.below(60) as u8).collect();
                        let t = lane.ensure_resident(&mut p);
                        let cp = p.checkpoint(t);
                        write_tokens(&mut p, t, &toks);
                        p.rollback(t, cp);
                        let cm = m.checkpoint(&lane.mirror);
                        write_tokens(&mut m, &mut lane.mirror, &toks);
                        m.rollback(&mut lane.mirror, cm);
                    }
                    // suspend (stress pool only)
                    6..=7 => {
                        let lane = &mut lanes[li];
                        if let Some(t) = lane.table.take() {
                            lane.snap = Some(p.suspend(t));
                        }
                    }
                    // resume (stress pool only)
                    8 => {
                        lanes[li].ensure_resident(&mut p);
                    }
                    // cache churn: a stranger allocates and retires,
                    // evicting cached blocks under swapped lanes
                    _ => {
                        let n = 4 + rng.below(9);
                        let toks: Vec<u8> = (0..n).map(|_| 200 + rng.below(56) as u8).collect();
                        let mut t = BlockTable::new(pool_cfg().max_seq);
                        write_tokens(&mut p, &mut t, &toks);
                        p.release(t);
                    }
                }
                p.assert_consistent();
                m.assert_consistent();
            }
            // Swap everything back in and compare against the mirror.
            let mut scr_p = KvScratch::new();
            let mut scr_m = KvScratch::new();
            for (i, lane) in lanes.iter_mut().enumerate() {
                lane.ensure_resident(&mut p);
                let lt = lane.table.as_ref().expect("resumed above");
                assert_eq!(lt.tokens(), lane.mirror.tokens(), "{ctx} lane {i}: history drifted");
                for layer in 0..2 {
                    let (kp, vp) = p.layer_view(lt, layer, lane.len, &mut scr_p);
                    let (km, vm) = m.layer_view(&lane.mirror, layer, lane.len, &mut scr_m);
                    assert_eq!(kp, km, "{ctx} lane {i} layer {layer}: K drifted from mirror");
                    assert_eq!(vp, vm, "{ctx} lane {i} layer {layer}: V drifted from mirror");
                }
            }
            for lane in lanes {
                p.release(lane.table.expect("all resumed above"));
                m.release(lane.mirror);
            }
            p.assert_consistent();
            m.assert_consistent();
            assert_eq!(p.referenced_blocks(), 0, "{ctx}: stress pool leaked");
            assert_eq!(m.referenced_blocks(), 0, "{ctx}: mirror pool leaked");
        }
    }
}
