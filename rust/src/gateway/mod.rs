//! Streaming serving gateway: the async front-end over the
//! [`Scheduler`].
//!
//! The engine so far was driven synchronously — enqueue a batch, call
//! [`Scheduler::round`] until drained, collect [`Response`]s. This
//! module turns that into a *system*: requests arrive at any time, get
//! admitted through a bounded queue with **backpressure** and three
//! **priority classes**, a continuous-batching loop drives one
//! scheduler round per iteration and **streams every new token** to its
//! client the moment the round that produced it retires, and a client
//! that disconnects or explicitly cancels gets its KV reclaimed
//! **mid-flight** through the same release/[`Snapshot`] teardown
//! retirement uses — a full cancel storm leaves the pool at zero
//! resident blocks (test-pinned).
//!
//! Two invariants carry over from every prior subsystem:
//!
//! * **Bit-identity.** Per-request greedy output depends only on
//!   (model, prompt, KV dtype) — fused batching, speculation, and
//!   preemption are all already pinned bit-identical to the simple
//!   path — so the gateway's arrival timing, admission order, and
//!   cancellations of *other* requests cannot perturb a surviving
//!   stream. `tests/gateway.rs` pins streamed tokens against a
//!   synchronous [`Scheduler`] run of the same workload.
//! * **Exact teardown.** Cancellation at every stage (gateway class
//!   queue → [`Batcher`] queue → active → swapped) reclaims exactly
//!   what the stage holds: nothing, nothing, the block table, the
//!   off-pool snapshot.
//!
//! The HTTP/SSE surface lives in [`http`] (hand-rolled on
//! `std::net::TcpListener` — the crate's only dependency is `anyhow`,
//! and the protocol subset SSE needs is small); this module is the
//! transport-independent core the in-process bench
//! (`benches/latency.rs`) drives directly.
//!
//! [`Response`]: crate::coordinator::Response
//! [`Snapshot`]: crate::kv::Snapshot

pub mod http;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::metrics::{Metrics, PRIORITY_CLASSES};
use crate::coordinator::request::{InFlight, Request};
use crate::coordinator::scheduler::Scheduler;
use crate::model::Model;
use crate::spec::SpecPolicy;
use crate::swap::SwapConfig;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Admission priority class. Lower value = served first: each loop
/// iteration feeds the scheduler's admission queue interactive →
/// standard → batch, so under contention interactive requests reach
/// prefill first. Within a class, FIFO (no starvation: admission order
/// inside the scheduler is still arrival order once enqueued).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Interactive = 0,
    Standard = 1,
    Batch = 2,
}

impl Priority {
    pub const ALL: [Priority; PRIORITY_CLASSES] =
        [Priority::Interactive, Priority::Standard, Priority::Batch];

    pub fn tag(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<Priority> {
        Priority::ALL.into_iter().find(|p| p.tag() == s)
    }
}

/// What a client submits. The gateway assigns the request id (returned
/// on the [`StreamHandle`]) and, unless the client pins a `seed`,
/// derives the sampling seed from it, so ids are unique by
/// construction and replayable: a synchronous reference run that
/// enqueues the same prompts with ids in submission order reproduces
/// the gateway's output exactly.
#[derive(Clone, Debug)]
pub struct GatewayRequest {
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// 0.0 = greedy (the bit-identity-pinned path).
    pub temperature: f32,
    /// Client-pinned sampling seed. `None` falls back to the
    /// server-assigned request id, which is unique per submission —
    /// reproducible only within one gateway run. Pin it to make
    /// sampled completions replayable across runs and replicas.
    pub seed: Option<u64>,
    pub priority: Priority,
}

impl GatewayRequest {
    /// Greedy request at standard priority.
    pub fn greedy(prompt: Vec<u8>, max_new_tokens: usize) -> Self {
        GatewayRequest {
            prompt,
            max_new_tokens,
            temperature: 0.0,
            seed: None,
            priority: Priority::Standard,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }
}

/// Per-token stream events, in order: zero or more `Token`s, then
/// exactly one `Done`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamEvent {
    /// One generated token; `index` is its 0-based position in the
    /// completion (a client can detect gaps, though none can occur).
    Token { index: usize, token: u8 },
    /// Terminal event. For a completed request `tokens` is the full
    /// final token vector (always equal to the concatenated `Token`
    /// stream — asserted by tests); for a cancelled request it is
    /// empty and the client keeps whatever prefix it streamed.
    Done { cancelled: bool, tokens: Vec<u8> },
}

/// Client side of one submitted request. Dropping the handle without
/// draining it is a **disconnect**: the loop notices the dead channel
/// at the next token it tries to deliver and reclaims the request's KV
/// exactly as an explicit [`StreamHandle::cancel`] would.
pub struct StreamHandle {
    /// Gateway-assigned request id (also the `/v1/cancel/<id>` key).
    pub id: u64,
    rx: Receiver<StreamEvent>,
    cancel: Arc<AtomicBool>,
}

/// Everything a fully-drained stream produced.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    pub id: u64,
    /// Tokens received incrementally, in order.
    pub streamed: Vec<u8>,
    /// Final token vector from the `Done` event (empty if cancelled).
    pub final_tokens: Vec<u8>,
    pub cancelled: bool,
}

impl StreamHandle {
    /// Assemble a handle around an existing channel — the router wraps
    /// its forwarding channel this way so a client holds one handle for
    /// the stream's whole life even as the sequence hops engines.
    pub(crate) fn attach(id: u64, rx: Receiver<StreamEvent>, cancel: Arc<AtomicBool>) -> Self {
        StreamHandle { id, rx, cancel }
    }

    /// Disassemble (router side of [`StreamHandle::attach`]).
    pub(crate) fn into_parts(self) -> (u64, Receiver<StreamEvent>, Arc<AtomicBool>) {
        (self.id, self.rx, self.cancel)
    }

    /// Request mid-flight cancellation; the loop acts on it within one
    /// scheduling round. Idempotent.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// Next event; `None` once the gateway is gone.
    pub fn recv(&self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<StreamEvent> {
        self.rx.recv_timeout(d).ok()
    }

    /// Block until `Done` (or the channel dies), collecting the stream.
    pub fn drain(self) -> StreamOutcome {
        let mut streamed = Vec::new();
        loop {
            match self.rx.recv() {
                Ok(StreamEvent::Token { token, .. }) => streamed.push(token),
                Ok(StreamEvent::Done { cancelled, tokens }) => {
                    return StreamOutcome {
                        id: self.id,
                        streamed,
                        final_tokens: tokens,
                        cancelled,
                    }
                }
                // Gateway torn down mid-stream: treat as cancelled.
                Err(_) => {
                    return StreamOutcome {
                        id: self.id,
                        streamed,
                        final_tokens: Vec::new(),
                        cancelled: true,
                    }
                }
            }
        }
    }
}

/// Why a submit was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission queue at capacity — backpressure; retry later.
    QueueFull,
    /// Gateway already shut down.
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "gateway admission queue full"),
            SubmitError::ShutDown => write!(f, "gateway shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// What the HTTP surface ([`http::serve`]) needs from a serving
/// backend. Implemented by the single-engine [`GatewayHandle`] and the
/// multi-replica [`crate::router::RouterHandle`], so the same
/// hand-rolled HTTP front end serves both.
pub trait Frontend: Clone + Send + 'static {
    fn submit(&self, req: GatewayRequest) -> Result<StreamHandle, SubmitError>;
    fn cancel(&self, id: u64) -> bool;
    fn metrics_json(&self) -> String;
}

/// Gateway tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct GatewayOpts {
    /// Max requests accepted but not yet admitted into the scheduler;
    /// submits beyond it are rejected ([`SubmitError::QueueFull`]).
    pub queue_capacity: usize,
    /// Artificial pause after every scheduling round. Zero (default)
    /// for production; the CI smoke test and demos raise it so tiny
    /// models stream slowly enough for a curl to cancel mid-flight.
    pub round_delay: Duration,
}

impl Default for GatewayOpts {
    fn default() -> Self {
        GatewayOpts { queue_capacity: 256, round_delay: Duration::ZERO }
    }
}

/// Final state handed back by [`Gateway::shutdown`], after the loop
/// drained every live request and walked the pool invariants.
#[derive(Clone, Debug)]
pub struct Drained {
    pub metrics: Metrics,
    /// Blocks still referenced by sequences at shutdown — 0 unless the
    /// loop leaked (test-asserted).
    pub referenced_blocks: usize,
    /// Blocks resident (referenced + cached reusable prefixes).
    pub blocks_in_use: usize,
}

/// A sequence suspended on one engine for resumption on another: the
/// complete generation state ([`Scheduler::extract`]'s [`InFlight`]
/// fields plus the KV snapshot serialized through [`crate::kv::wire`])
/// *and* the loop-side stream state. Because the original
/// [`StreamEvent`] sender rides along, the destination engine keeps
/// writing into the very channel the client is already reading — a
/// mid-stream migration is invisible to the consumer except for the
/// token indices continuing where the source stopped.
///
/// Metrics accounting splits across engines: the source counted the
/// submit/admit, the destination counts the completion; each side also
/// bumps its own `migrations_out` / `migrations_in`.
#[derive(Debug)]
pub struct MigratedSeq {
    prompt: Vec<u8>,
    max_new_tokens: usize,
    temperature: f32,
    /// Original sampling seed — survives the id reassignment so the
    /// continuation is bit-identical to an unmigrated run.
    seed: u64,
    generated: Vec<u8>,
    preempt_count: u32,
    rng_state: [u64; 4],
    submitted: Instant,
    started: Option<Instant>,
    first_token_at: Option<Instant>,
    /// KV snapshot in [`crate::kv::wire`] format (geometry-checked by
    /// the destination pool before anything is mutated).
    wire: Vec<u8>,
    prio: Priority,
    tx: Sender<StreamEvent>,
    cancel: Arc<AtomicBool>,
    watermark: usize,
    first_token: bool,
    last_emit: Instant,
}

impl MigratedSeq {
    /// Serialized KV payload size (what actually crosses engines).
    pub fn kv_bytes(&self) -> usize {
        self.wire.len()
    }

    /// Tokens generated so far (prefill done ⇒ ≥ 1).
    pub fn tokens_done(&self) -> usize {
        self.generated.len()
    }
}

enum Msg {
    Submit {
        id: u64,
        req: GatewayRequest,
        tx: Sender<StreamEvent>,
        cancel: Arc<AtomicBool>,
        submitted: Instant,
    },
    /// Suspend a live decoded-at-least-once request and hand it out.
    /// Replies `None` if the id is unknown, still queued (nothing to
    /// ship yet), doomed, or the engine runs the legacy path.
    MigrateOut { id: u64, resp: Sender<Option<Box<MigratedSeq>>> },
    /// Adopt a sequence suspended elsewhere. Replies `Err(seq)` —
    /// returning the sequence intact for re-injection at the source —
    /// if this engine cannot host it (legacy mode or mismatched pool
    /// geometry).
    MigrateIn {
        seq: Box<MigratedSeq>,
        #[allow(clippy::type_complexity)]
        resp: Sender<std::result::Result<u64, Box<MigratedSeq>>>,
    },
    Shutdown,
}

/// State shared between the loop thread and every [`GatewayHandle`].
struct Shared {
    capacity: usize,
    /// Requests accepted but not yet admitted into the scheduler
    /// (gateway class queues + batcher queue) — the backpressure gauge.
    depth: AtomicUsize,
    depth_peak: AtomicUsize,
    rejected: AtomicU64,
    next_id: AtomicU64,
    /// Cancel flags by live request id (for cancel-by-id over HTTP).
    cancels: Mutex<HashMap<u64, Arc<AtomicBool>>>,
    /// Serialized metrics snapshot, refreshed every loop iteration.
    snapshot: Mutex<String>,
    /// Content digests of every cached prefix chain in this engine's
    /// pool (refreshed every loop iteration) — the router's
    /// prefix-affinity routing signal.
    digests: Mutex<Vec<u64>>,
    /// Pool block granularity in tokens (set once at loop start).
    block_tokens: AtomicUsize,
}

/// Cheap, cloneable submitter — one per connection thread.
#[derive(Clone)]
pub struct GatewayHandle {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
}

impl GatewayHandle {
    /// Submit a request; returns its stream or rejects under
    /// backpressure. The depth charge is taken here (atomically against
    /// capacity) and released by the loop when the request leaves the
    /// waiting stage, so concurrent submitters can never oversubscribe
    /// the queue.
    pub fn submit(&self, req: GatewayRequest) -> Result<StreamHandle, SubmitError> {
        let cap = self.shared.capacity;
        if self
            .shared
            .depth
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                (d < cap).then_some(d + 1)
            })
            .is_err()
        {
            self.shared.rejected.fetch_add(1, Ordering::SeqCst);
            return Err(SubmitError::QueueFull);
        }
        self.shared
            .depth_peak
            .fetch_max(self.shared.depth.load(Ordering::SeqCst), Ordering::SeqCst);
        let id = self.shared.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        self.shared.cancels.lock().unwrap().insert(id, cancel.clone());
        let msg =
            Msg::Submit { id, req, tx, cancel: cancel.clone(), submitted: Instant::now() };
        if self.tx.send(msg).is_err() {
            self.shared.depth.fetch_sub(1, Ordering::SeqCst);
            self.shared.cancels.lock().unwrap().remove(&id);
            return Err(SubmitError::ShutDown);
        }
        Ok(StreamHandle { id, rx, cancel })
    }

    /// Flag a live request for cancellation by id (the HTTP
    /// `/v1/cancel/<id>` path). `false` if the id is not live.
    pub fn cancel(&self, id: u64) -> bool {
        match self.shared.cancels.lock().unwrap().get(&id) {
            Some(flag) => {
                flag.store(true, Ordering::SeqCst);
                true
            }
            None => false,
        }
    }

    /// Latest metrics snapshot as a JSON string (refreshed once per
    /// scheduling round).
    pub fn metrics_json(&self) -> String {
        self.shared.snapshot.lock().unwrap().clone()
    }

    /// Current admission-queue depth (accepted, not yet admitted).
    pub fn queue_depth(&self) -> usize {
        self.shared.depth.load(Ordering::SeqCst)
    }

    /// Content digests of every cached prefix chain this engine's pool
    /// could currently serve (refreshed once per scheduling round).
    /// Compare against [`crate::kv::prompt_digests`] of an incoming
    /// prompt to score prefix affinity.
    pub fn prefix_digests(&self) -> Vec<u64> {
        self.shared.digests.lock().unwrap().clone()
    }

    /// Pool block granularity in tokens.
    pub fn block_tokens(&self) -> usize {
        self.shared.block_tokens.load(Ordering::SeqCst)
    }

    /// Suspend live request `id` and hand back its complete migration
    /// state, or `None` if it is unknown, not yet decoding, doomed, or
    /// the engine runs the legacy (non-paged) path. On success the
    /// request is *gone* from this engine — its stream channel rides in
    /// the returned [`MigratedSeq`].
    pub fn migrate_out(&self, id: u64) -> Option<MigratedSeq> {
        let (rtx, rrx) = channel();
        self.tx.send(Msg::MigrateOut { id, resp: rtx }).ok()?;
        rrx.recv().ok().flatten().map(|b| *b)
    }

    /// Adopt a sequence suspended on another engine; returns the fresh
    /// engine-local id. `Err(Some(seq))` hands the sequence back intact
    /// when this engine cannot host it (re-inject at the source);
    /// `Err(None)` means the loop died mid-handoff and the sequence is
    /// lost (its clients see a dead channel).
    pub fn migrate_in(&self, seq: MigratedSeq) -> std::result::Result<u64, Option<MigratedSeq>> {
        let (rtx, rrx) = channel();
        if let Err(send_err) = self.tx.send(Msg::MigrateIn { seq: Box::new(seq), resp: rtx }) {
            let Msg::MigrateIn { seq, .. } = send_err.0 else { unreachable!() };
            return Err(Some(*seq));
        }
        match rrx.recv() {
            Ok(Ok(id)) => Ok(id),
            Ok(Err(seq)) => Err(Some(*seq)),
            Err(_) => Err(None),
        }
    }
}

impl Frontend for GatewayHandle {
    fn submit(&self, req: GatewayRequest) -> Result<StreamHandle, SubmitError> {
        GatewayHandle::submit(self, req)
    }

    fn cancel(&self, id: u64) -> bool {
        GatewayHandle::cancel(self, id)
    }

    fn metrics_json(&self) -> String {
        GatewayHandle::metrics_json(self)
    }
}

/// The running gateway. Owns the loop thread; [`Gateway::shutdown`]
/// drains and returns [`Drained`]. Dropping without shutdown also
/// joins (drain, then exit) so tests can't leak the worker.
pub struct Gateway {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
    worker: Option<JoinHandle<Drained>>,
}

impl Gateway {
    /// Start the continuous-batching loop on its own thread. The model
    /// moves into the thread; the scheduler borrows it there (same
    /// ownership shape as [`crate::coordinator::Engine`]).
    pub fn start(
        model: Model,
        policy: BatchPolicy,
        spec: Option<SpecPolicy>,
        opts: GatewayOpts,
    ) -> Gateway {
        Gateway::start_with_swap(model, policy, spec, opts, SwapConfig::default())
    }

    /// [`Gateway::start`] plus a spill-tier configuration for the
    /// scheduler's preemption path (see [`crate::swap`]). The default
    /// keeps every preempted snapshot resident.
    pub fn start_with_swap(
        model: Model,
        policy: BatchPolicy,
        spec: Option<SpecPolicy>,
        opts: GatewayOpts,
        swap: SwapConfig,
    ) -> Gateway {
        let (tx, rx) = channel::<Msg>();
        let shared = Arc::new(Shared {
            capacity: opts.queue_capacity.max(1),
            depth: AtomicUsize::new(0),
            depth_peak: AtomicUsize::new(0),
            rejected: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            cancels: Mutex::new(HashMap::new()),
            snapshot: Mutex::new(String::from("{}")),
            digests: Mutex::new(Vec::new()),
            block_tokens: AtomicUsize::new(0),
        });
        let worker_shared = shared.clone();
        let worker = std::thread::spawn(move || {
            let mut sched = Scheduler::with_spec(&model, policy, spec);
            sched.set_swap(swap);
            gateway_loop(&mut sched, opts, rx, &worker_shared)
        });
        Gateway { tx, shared, worker: Some(worker) }
    }

    pub fn handle(&self) -> GatewayHandle {
        GatewayHandle { tx: self.tx.clone(), shared: self.shared.clone() }
    }

    /// Drain every live request (cancel flags keep working during the
    /// drain), verify pool invariants, and return the final metrics.
    pub fn shutdown(mut self) -> Drained {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker.take().expect("shutdown twice").join().expect("gateway worker panicked")
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

/// Per-live-request loop-side state (the watermark is how streaming
/// stays incremental: tokens past it are new this round).
struct Entry {
    prio: Priority,
    submitted: Instant,
    tx: Sender<StreamEvent>,
    cancel: Arc<AtomicBool>,
    /// Tokens already streamed.
    watermark: usize,
    /// Last event delivery (inter-token latency clock).
    last_emit: Instant,
    first_token: bool,
    /// Seen inside the scheduler (depth charge released).
    admitted: bool,
    /// Stream send failed — client disconnected; cancel next round.
    dead: bool,
}

/// The continuous-batching loop: drain messages → apply cancellations →
/// feed the batcher in priority order → one scheduler round → stream
/// new tokens → retire → refresh the metrics snapshot.
fn gateway_loop(
    sched: &mut Scheduler,
    opts: GatewayOpts,
    rx: Receiver<Msg>,
    shared: &Shared,
) -> Drained {
    // Normalized by the scheduler (legacy mode drops preempt/spec).
    let policy = sched.policy;
    let mut batcher = Batcher::new();
    let mut live: HashMap<u64, Entry> = HashMap::new();
    let mut classq: [VecDeque<(u64, Request)>; PRIORITY_CLASSES] = Default::default();
    let mut shutdown = false;
    shared.block_tokens.store(sched.pool().block_tokens(), Ordering::SeqCst);
    loop {
        // `live` ⊆ {class queues ∪ batcher ∪ scheduler}, so empty-live
        // ⇔ nothing to drive: block for a message instead of spinning.
        let idle = live.is_empty()
            && classq.iter().all(|q| q.is_empty())
            && !sched.has_work(&batcher);
        if idle {
            if shutdown {
                break;
            }
            match rx.recv() {
                Ok(msg) => apply_msg(msg, sched, &mut live, &mut classq, &mut shutdown, shared),
                // Every handle and the Gateway itself are gone.
                Err(_) => break,
            }
        }
        while let Ok(msg) = rx.try_recv() {
            apply_msg(msg, sched, &mut live, &mut classq, &mut shutdown, shared);
        }

        // Cancellations: explicit flags and disconnected streams.
        let doomed: Vec<u64> = live
            .iter()
            .filter(|(_, e)| e.dead || e.cancel.load(Ordering::SeqCst))
            .map(|(id, _)| *id)
            .collect();
        for id in doomed {
            cancel_one(id, sched, &mut batcher, &mut classq, &mut live, shared);
        }

        // Feed the batcher in priority order, keeping its FIFO queue no
        // deeper than one prefill burst so class order stays in charge.
        'feed: while batcher.waiting() < policy.max_prefill_per_round.max(1) {
            for q in classq.iter_mut() {
                if let Some((_id, req)) = q.pop_front() {
                    batcher.enqueue(req);
                    continue 'feed;
                }
            }
            break;
        }

        let responses =
            if sched.has_work(&batcher) { sched.round(&mut batcher) } else { Vec::new() };
        let now = Instant::now();

        // Stream progress. Two phases (collect, then emit) so the
        // scheduler's shared borrow ends before metrics are updated.
        let mut deltas: Vec<(u64, Vec<u8>)> = Vec::new();
        sched.for_each_progress(|id, toks| {
            if let Some(e) = live.get(&id) {
                deltas.push((id, toks[e.watermark.min(toks.len())..].to_vec()));
            }
        });
        for (id, delta) in deltas {
            if let Some(e) = live.get_mut(&id) {
                emit_delta(e, &delta, now, &mut sched.metrics, shared);
            }
        }

        // Retirements: final delta (admitted-and-finished in the same
        // round never appeared in `for_each_progress`), then `Done`.
        for r in responses {
            if let Some(mut e) = live.remove(&r.id) {
                let delta = r.tokens.get(e.watermark..).unwrap_or(&[]).to_vec();
                emit_delta(&mut e, &delta, now, &mut sched.metrics, shared);
                sched.metrics.class_completed[e.prio as usize] += 1;
                if !e.dead {
                    let _ =
                        e.tx.send(StreamEvent::Done { cancelled: false, tokens: r.tokens });
                }
                shared.cancels.lock().unwrap().remove(&r.id);
            }
        }

        refresh_snapshot(sched, shared, live.len());
        if !opts.round_delay.is_zero() {
            std::thread::sleep(opts.round_delay);
        }
    }

    sched.pool().assert_consistent();
    refresh_snapshot(sched, shared, live.len());
    Drained {
        referenced_blocks: sched.pool().referenced_blocks(),
        blocks_in_use: sched.pool().blocks_in_use(),
        metrics: sched.metrics.clone(),
    }
}

fn apply_msg(
    msg: Msg,
    sched: &mut Scheduler,
    live: &mut HashMap<u64, Entry>,
    classq: &mut [VecDeque<(u64, Request)>; PRIORITY_CLASSES],
    shutdown: &mut bool,
    shared: &Shared,
) {
    match msg {
        Msg::Submit { id, req, tx, cancel, submitted } => {
            let prio = req.priority;
            sched.metrics.requests_submitted += 1;
            sched.metrics.class_submitted[prio as usize] += 1;
            let mut r = Request::new(id, req.prompt, req.max_new_tokens)
                .with_temperature(req.temperature);
            if let Some(seed) = req.seed {
                r = r.with_seed(seed);
            }
            live.insert(
                id,
                Entry {
                    prio,
                    submitted,
                    tx,
                    cancel,
                    watermark: 0,
                    last_emit: submitted,
                    first_token: true,
                    admitted: false,
                    dead: false,
                },
            );
            classq[prio as usize].push_back((id, r));
        }
        Msg::MigrateOut { id, resp } => {
            // Doomed streams stay here for the cancel sweep; requests
            // still in the class/batcher queues have no KV to ship and
            // are cheaper to leave where they are.
            let eligible = sched.policy.batched_decode
                && live
                    .get(&id)
                    .map(|e| !e.dead && !e.cancel.load(Ordering::SeqCst))
                    .unwrap_or(false);
            let out = if eligible { sched.extract(id) } else { None }.map(|(f, snap)| {
                let wire = sched.pool().snapshot_to_wire(&snap, true);
                let e = live.remove(&id).expect("extracted id was live");
                shared.cancels.lock().unwrap().remove(&id);
                Box::new(MigratedSeq {
                    prompt: f.req.prompt,
                    max_new_tokens: f.req.max_new_tokens,
                    temperature: f.req.temperature,
                    seed: f.req.seed,
                    generated: f.generated,
                    preempt_count: f.preempt_count,
                    rng_state: f.rng.state(),
                    submitted: e.submitted,
                    started: f.started,
                    first_token_at: f.first_token,
                    wire,
                    prio: e.prio,
                    tx: e.tx,
                    cancel: e.cancel,
                    watermark: e.watermark,
                    first_token: e.first_token,
                    last_emit: e.last_emit,
                })
            });
            let _ = resp.send(out);
        }
        Msg::MigrateIn { seq, resp } => {
            // Validate before mutating anything so a refusal hands the
            // sequence back untouched.
            let snap = if sched.policy.batched_decode {
                sched.pool().snapshot_from_wire(&seq.wire).ok()
            } else {
                None
            };
            match snap {
                None => {
                    let _ = resp.send(Err(seq));
                }
                Some(snap) => {
                    let id = shared.next_id.fetch_add(1, Ordering::SeqCst);
                    let s = *seq;
                    let req = Request::new(id, s.prompt, s.max_new_tokens)
                        .with_temperature(s.temperature)
                        .with_seed(s.seed);
                    let mut f = InFlight::new(req);
                    f.submitted = s.submitted;
                    f.started = s.started;
                    f.first_token = s.first_token_at;
                    f.generated = s.generated;
                    f.preempt_count = s.preempt_count;
                    f.rng = Rng::from_state(s.rng_state);
                    shared.cancels.lock().unwrap().insert(id, s.cancel.clone());
                    live.insert(
                        id,
                        Entry {
                            prio: s.prio,
                            submitted: s.submitted,
                            tx: s.tx,
                            cancel: s.cancel,
                            watermark: s.watermark,
                            last_emit: s.last_emit,
                            first_token: s.first_token,
                            // The source engine took the depth charge
                            // and counted the admission — don't repeat
                            // either here.
                            admitted: true,
                            dead: false,
                        },
                    );
                    sched.inject(f, snap);
                    let _ = resp.send(Ok(id));
                }
            }
        }
        Msg::Shutdown => *shutdown = true,
    }
}

/// Stage-aware cancellation: scheduler (active/swapped) → batcher queue
/// → gateway class queue. Exactly one stage holds the request.
fn cancel_one(
    id: u64,
    sched: &mut Scheduler,
    batcher: &mut Batcher,
    classq: &mut [VecDeque<(u64, Request)>; PRIORITY_CLASSES],
    live: &mut HashMap<u64, Entry>,
    shared: &Shared,
) {
    let Some(e) = live.remove(&id) else { return };
    if sched.cancel(id) {
        // requests_cancelled / tokens_cancelled / cancel_freed_blocks
        // were counted by the scheduler.
    } else if batcher.cancel(id).is_some() {
        sched.metrics.requests_cancelled += 1;
    } else {
        for q in classq.iter_mut() {
            if let Some(i) = q.iter().position(|(qid, _)| *qid == id) {
                q.remove(i);
                break;
            }
        }
        sched.metrics.requests_cancelled += 1;
    }
    sched.metrics.class_cancelled[e.prio as usize] += 1;
    if !e.admitted {
        shared.depth.fetch_sub(1, Ordering::SeqCst);
    }
    if !e.dead {
        let _ = e.tx.send(StreamEvent::Done { cancelled: true, tokens: Vec::new() });
    }
    shared.cancels.lock().unwrap().remove(&id);
}

/// Deliver `delta` to one stream: releases the depth charge on first
/// sight, records client-observed TTFT / inter-token latency, marks the
/// stream dead on send failure (disconnect).
fn emit_delta(e: &mut Entry, delta: &[u8], now: Instant, m: &mut Metrics, shared: &Shared) {
    if !e.admitted {
        e.admitted = true;
        shared.depth.fetch_sub(1, Ordering::SeqCst);
        m.class_admitted[e.prio as usize] += 1;
        m.class_queue_wait[e.prio as usize] += now.duration_since(e.submitted);
    }
    for (i, &t) in delta.iter().enumerate() {
        if !e.dead && e.tx.send(StreamEvent::Token { index: e.watermark + i, token: t }).is_err()
        {
            e.dead = true;
        }
        if e.first_token {
            e.first_token = false;
            m.stream_ttft.record(now.duration_since(e.submitted));
        } else {
            // Tokens landing in the same round record ~0 gaps — that is
            // what the client sees when a speculative burst arrives.
            m.inter_token.record(now.duration_since(e.last_emit));
        }
        e.last_emit = now;
        m.class_tokens[e.prio as usize] += 1;
    }
    e.watermark += delta.len();
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Serialize the metrics the HTTP `/metrics` endpoint (and the CI smoke
/// step's reclaim assertion) reads. Every value is a JSON number — the
/// rate helpers guarantee 0.0-not-NaN cold. Also folds the submit-side
/// atomics (rejections, peak depth) into `sched.metrics`, so the
/// `Drained` record carries them too.
fn refresh_snapshot(sched: &mut Scheduler, shared: &Shared, live_streams: usize) {
    *shared.digests.lock().unwrap() = sched.pool().prefix_digests();
    sched.metrics.requests_rejected = shared.rejected.load(Ordering::SeqCst);
    sched.metrics.queue_depth_peak =
        sched.metrics.queue_depth_peak.max(shared.depth_peak.load(Ordering::SeqCst) as u64);
    let m = &sched.metrics;
    let classes = Json::Arr(
        (0..PRIORITY_CLASSES)
            .map(|c| {
                Json::obj(vec![
                    ("class", Json::Str(Priority::ALL[c].tag().to_string())),
                    ("submitted", Json::from(m.class_submitted[c] as usize)),
                    ("admitted", Json::from(m.class_admitted[c] as usize)),
                    ("completed", Json::from(m.class_completed[c] as usize)),
                    ("cancelled", Json::from(m.class_cancelled[c] as usize)),
                    ("tokens", Json::from(m.class_tokens[c] as usize)),
                    ("mean_queue_wait_ms", Json::Num(m.class_mean_queue_wait_ms(c))),
                ])
            })
            .collect(),
    );
    let depth = shared.depth.load(Ordering::SeqCst);
    let obj = Json::obj(vec![
        ("requests_submitted", Json::from(m.requests_submitted as usize)),
        ("requests_completed", Json::from(m.requests_completed as usize)),
        ("requests_cancelled", Json::from(m.requests_cancelled as usize)),
        ("requests_rejected", Json::from(m.requests_rejected as usize)),
        ("tokens_generated", Json::from(m.tokens_generated as usize)),
        ("tokens_cancelled", Json::from(m.tokens_cancelled as usize)),
        ("cancel_freed_blocks", Json::from(m.cancel_freed_blocks as usize)),
        ("queue_depth", Json::from(depth)),
        ("queue_depth_peak", Json::from(m.queue_depth_peak as usize)),
        ("live_streams", Json::from(live_streams)),
        ("preemptions", Json::from(m.preemptions as usize)),
        ("resumes", Json::from(m.resumes as usize)),
        ("spills", Json::from(m.spills as usize)),
        ("spilled_bytes", Json::from(m.spilled_bytes as usize)),
        ("restores", Json::from(m.restores as usize)),
        ("reprefill_drops", Json::from(m.reprefill_drops as usize)),
        ("spill_codec_ratio", Json::Num(m.spill_codec_ratio())),
        ("restore_mean_ms", Json::Num(m.restore_mean_ms())),
        ("migrations_out", Json::from(m.migrations_out as usize)),
        ("migrations_in", Json::from(m.migrations_in as usize)),
        ("pool_referenced_blocks", Json::from(sched.pool().referenced_blocks())),
        ("pool_blocks_in_use", Json::from(sched.pool().blocks_in_use())),
        ("cancellation_rate", Json::Num(m.cancellation_rate())),
        ("rejection_rate", Json::Num(m.rejection_rate())),
        ("stream_ttft_p50_ms", Json::Num(ms(m.stream_ttft.quantile(0.5)))),
        ("stream_ttft_p99_ms", Json::Num(ms(m.stream_ttft.quantile(0.99)))),
        ("inter_token_p50_ms", Json::Num(ms(m.inter_token.quantile(0.5)))),
        ("inter_token_p99_ms", Json::Num(ms(m.inter_token.quantile(0.99)))),
        ("classes", classes),
    ]);
    *shared.snapshot.lock().unwrap() = obj.to_string();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;
    use crate::model::Arch;

    #[test]
    fn streams_match_generate_and_done_event() {
        let model = tiny_model(Arch::Gpt, 71);
        let want: Vec<Vec<u8>> = (0..3u8)
            .map(|i| model.generate(&[65 + i; 4], 6, 0.0, 0))
            .collect();
        let gw = Gateway::start(
            model,
            BatchPolicy::default(),
            None,
            GatewayOpts::default(),
        );
        let h = gw.handle();
        let streams: Vec<StreamHandle> = (0..3u8)
            .map(|i| h.submit(GatewayRequest::greedy(vec![65 + i; 4], 6)).unwrap())
            .collect();
        for (i, s) in streams.into_iter().enumerate() {
            let out = s.drain();
            assert!(!out.cancelled);
            assert_eq!(out.streamed, want[i], "streamed tokens must be bit-identical");
            assert_eq!(out.final_tokens, out.streamed, "Done must echo the stream");
        }
        let d = gw.shutdown();
        assert_eq!(d.referenced_blocks, 0);
        assert_eq!(d.metrics.requests_completed, 3);
        assert_eq!(d.metrics.requests_cancelled, 0);
        assert_eq!(d.metrics.stream_ttft.count(), 3);
        // 3 requests × 6 tokens: everything after each first token gaps.
        assert_eq!(d.metrics.inter_token.count(), 15);
    }

    #[test]
    fn cancel_and_disconnect_reclaim_blocks() {
        let model = tiny_model(Arch::Gpt, 72);
        // A small round delay keeps the doomed streams in flight long
        // enough that the cancels land mid-generation, not after.
        let opts = GatewayOpts { round_delay: Duration::from_millis(5), ..Default::default() };
        let gw = Gateway::start(model, BatchPolicy::default(), None, opts);
        let h = gw.handle();
        let keep = h.submit(GatewayRequest::greedy(vec![65; 4], 5)).unwrap();
        let explicit = h.submit(GatewayRequest::greedy(vec![66; 4], 400)).unwrap();
        let dropped = h.submit(GatewayRequest::greedy(vec![67; 4], 400)).unwrap();
        // Wait until the doomed streams actually started, so the cancel
        // exercises the mid-flight (active-sequence) path.
        assert!(explicit.recv().is_some());
        assert!(dropped.recv().is_some());
        explicit.cancel();
        drop(dropped); // disconnect
        let out = keep.drain();
        assert!(!out.cancelled);
        assert_eq!(out.streamed.len(), 5, "survivor must finish untouched");
        let ex = explicit.drain();
        assert!(ex.cancelled, "explicit cancel must end with a cancelled Done");
        let d = gw.shutdown();
        assert_eq!(d.referenced_blocks, 0, "cancelled KV must be reclaimed");
        assert_eq!(d.metrics.requests_cancelled, 2);
        assert_eq!(d.metrics.requests_completed, 1);
        assert!(d.metrics.cancel_freed_blocks >= 1);
        assert!(d.metrics.tokens_cancelled >= 2);
    }

    #[test]
    fn backpressure_rejects_above_capacity() {
        let model = tiny_model(Arch::Gpt, 73);
        // A plug request + a long round delay pin the loop in its
        // inter-round sleep, so the flood below races only the
        // submit-side depth atomic — deterministic backpressure.
        let opts = GatewayOpts {
            queue_capacity: 2,
            round_delay: Duration::from_millis(100),
        };
        let gw = Gateway::start(model, BatchPolicy::default(), None, opts);
        let h = gw.handle();
        let plug = h.submit(GatewayRequest::greedy(vec![90; 3], 6)).unwrap();
        std::thread::sleep(Duration::from_millis(40)); // loop is now asleep
        let mut oks = Vec::new();
        let mut rejected = 0;
        for i in 0..5u8 {
            match h.submit(GatewayRequest::greedy(vec![65 + i; 3], 2)) {
                Ok(s) => oks.push(s),
                Err(SubmitError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        // The sleeping loop cannot release any depth charge mid-flood,
        // so exactly `queue_capacity` submits fit.
        assert_eq!(oks.len(), 2);
        assert_eq!(rejected, 3);
        for s in oks {
            assert!(!s.drain().cancelled);
        }
        assert!(!plug.drain().cancelled);
        let d = gw.shutdown();
        assert_eq!(d.metrics.requests_rejected, 3);
        assert_eq!(d.metrics.queue_depth_peak, 2);
        // 3 accepted (plug + 2), 3 rejected → half of arrivals refused.
        assert!((d.metrics.rejection_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn snapshot_json_parses_and_counts() {
        let model = tiny_model(Arch::Llama, 74);
        let gw = Gateway::start(
            model,
            BatchPolicy::default(),
            None,
            GatewayOpts::default(),
        );
        let h = gw.handle();
        let s = h.submit(GatewayRequest::greedy(vec![70; 3], 4)).unwrap();
        assert!(!s.drain().cancelled);
        // `Done` is delivered just before the retiring round's snapshot
        // refresh, so poll briefly instead of assuming instant currency.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let snap = Json::parse(&h.metrics_json()).expect("snapshot must be valid JSON");
            if snap.get("requests_completed").and_then(|v| v.as_usize()) == Some(1) {
                assert_eq!(
                    snap.get("pool_referenced_blocks").and_then(|v| v.as_usize()),
                    Some(0)
                );
                let classes =
                    snap.get("classes").and_then(|v| v.as_arr()).expect("classes array");
                assert_eq!(classes.len(), PRIORITY_CLASSES);
                break;
            }
            assert!(Instant::now() < deadline, "snapshot never recorded the completion");
            std::thread::sleep(Duration::from_millis(2));
        }
        gw.shutdown();
    }
}
