//! KV-cached incremental decoding (the serving path).

use crate::util::rng::Rng;

use super::ops::*;
use super::{Arch, Model};
use crate::data::embed;
use crate::tensor::{matmul, Matrix};

/// Per-request KV cache: one K and one V buffer per layer, `[len, d]`
/// prefix valid. K is stored pre-RoPE; rotation is applied at attention
/// time from absolute positions (keeps cache layout format-agnostic).
#[derive(Clone, Debug)]
pub struct KvCache {
    pub k: Vec<Matrix>,
    pub v: Vec<Matrix>,
    pub len: usize,
    max_seq: usize,
}

impl KvCache {
    pub fn new(model: &Model) -> Self {
        let d = model.cfg.d_model;
        let ms = model.cfg.max_seq;
        KvCache {
            k: (0..model.cfg.n_layer).map(|_| Matrix::zeros(ms, d)).collect(),
            v: (0..model.cfg.n_layer).map(|_| Matrix::zeros(ms, d)).collect(),
            len: 0,
            max_seq: ms,
        }
    }

    /// Remaining capacity in tokens.
    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    /// Approximate resident bytes (for the coordinator's memory manager).
    pub fn bytes(&self) -> usize {
        self.k.iter().map(|m| m.len() * 4).sum::<usize>() * 2
    }
}

impl Model {
    /// Process `tokens` (batch = 1) on top of `cache`, appending to it.
    /// Returns logits `[tokens.len(), vocab]`.
    pub fn forward_cached(&self, tokens: &[u8], cache: &mut KvCache) -> Matrix {
        let n = tokens.len();
        let past = cache.len;
        assert!(past + n <= self.cfg.max_seq, "KV cache overflow");
        let d = self.cfg.d_model;
        let mut x = embed(tokens, &self.tok_emb);
        if let Some(pe) = &self.pos_emb {
            for i in 0..n {
                let row = x.row_mut(i);
                for (v, p) in row.iter_mut().zip(pe.row(past + i)) {
                    *v += *p;
                }
            }
        }
        for (li, blk) in self.blocks.iter().enumerate() {
            let mut h = x.clone();
            self.norm1(blk, &mut h);
            let mut q = Matrix::zeros(n, d);
            let mut k_new = Matrix::zeros(n, d);
            let mut v_new = Matrix::zeros(n, d);
            blk.q.lin.forward_into(&h, &mut q);
            blk.k.lin.forward_into(&h, &mut k_new);
            blk.v.lin.forward_into(&h, &mut v_new);
            // Append to cache.
            for i in 0..n {
                cache.k[li].row_mut(past + i).copy_from_slice(k_new.row(i));
                cache.v[li].row_mut(past + i).copy_from_slice(v_new.row(i));
            }
            let kv_len = past + n;
            let k_full = Matrix::from_vec(
                kv_len,
                d,
                cache.k[li].data[..kv_len * d].to_vec(),
            );
            let v_full = Matrix::from_vec(
                kv_len,
                d,
                cache.v[li].data[..kv_len * d].to_vec(),
            );
            let attn = self.attention(&q, &k_full, &v_full, 1, n, past);
            let mut o_out = Matrix::zeros(n, d);
            blk.o.lin.forward_into(&attn, &mut o_out);
            add_inplace(&mut x, &o_out);

            let mut h = x.clone();
            self.norm2(blk, &mut h);
            let mut a = Matrix::zeros(n, self.cfg.d_ff);
            blk.ff1.lin.forward_into(&h, &mut a);
            match self.cfg.arch {
                Arch::Gpt => map_inplace(&mut a, gelu),
                Arch::Llama => {
                    let ff3 = blk.ff3.as_ref().expect("llama gate");
                    let mut g = Matrix::zeros(h.rows, self.cfg.d_ff);
                    ff3.lin.forward_into(&h, &mut g);
                    map_inplace(&mut a, silu);
                    mul_inplace(&mut a, &g);
                }
            }
            let mut m_out = Matrix::zeros(n, d);
            blk.ff2.lin.forward_into(&a, &mut m_out);
            add_inplace(&mut x, &m_out);
        }
        cache.len += n;
        match self.cfg.arch {
            Arch::Gpt => layernorm(&mut x, &self.lnf_g, self.lnf_b.as_deref(), self.cfg.eps),
            Arch::Llama => rmsnorm(&mut x, &self.lnf_g, self.cfg.eps),
        }
        matmul(&x, &self.tok_emb)
    }

    /// Greedy / temperature sampling from the last row of `logits`.
    pub fn sample(&self, logits: &Matrix, temperature: f32, rng: &mut Rng) -> u8 {
        let row = logits.row(logits.rows - 1);
        if temperature <= 0.0 {
            // Greedy.
            let mut best = 0;
            let mut bv = f32::NEG_INFINITY;
            for (i, v) in row.iter().enumerate() {
                if *v > bv {
                    bv = *v;
                    best = i;
                }
            }
            return best as u8;
        }
        let max = row.iter().fold(f32::NEG_INFINITY, |m, v| m.max(*v));
        let probs: Vec<f32> = row.iter().map(|v| ((v - max) / temperature).exp()).collect();
        let sum: f32 = probs.iter().sum();
        let mut u = rng.range_f32(0.0, sum);
        for (i, p) in probs.iter().enumerate() {
            u -= p;
            if u <= 0.0 {
                return i as u8;
            }
        }
        255
    }

    /// Generate `max_new` tokens after `prompt` (batch = 1).
    pub fn generate(&self, prompt: &[u8], max_new: usize, temperature: f32, seed: u64) -> Vec<u8> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut cache = KvCache::new(self);
        let budget = max_new.min(self.cfg.max_seq.saturating_sub(prompt.len()));
        let mut out = Vec::with_capacity(budget);
        let mut logits = self.forward_cached(prompt, &mut cache);
        for _ in 0..budget {
            let t = self.sample(&logits, temperature, &mut rng);
            out.push(t);
            if cache.remaining() == 0 {
                break;
            }
            logits = self.forward_cached(&[t], &mut cache);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::tiny_model;
    use super::super::Arch;
    use super::*;

    #[test]
    fn cached_matches_full_forward() {
        for arch in [Arch::Gpt, Arch::Llama] {
            let m = tiny_model(arch, 7);
            let tokens: Vec<u8> = (5..21).collect();
            let full = m.forward(&tokens, 1, 16, None);
            // Incremental: prefill 10, then 6 single steps.
            let mut cache = KvCache::new(&m);
            let mut last = m.forward_cached(&tokens[..10], &mut cache);
            for (i, t) in tokens[10..].iter().enumerate() {
                // check logits for position 9+i match the full pass
                let pos = 9 + i;
                let fr = full.row(pos);
                let cr = last.row(last.rows - 1);
                for (a, b) in fr.iter().zip(cr) {
                    assert!((a - b).abs() < 1e-3, "{arch:?} pos {pos}: {a} vs {b}");
                }
                last = m.forward_cached(&[*t], &mut cache);
            }
            assert_eq!(cache.len, 16);
        }
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let m = tiny_model(Arch::Gpt, 8);
        let a = m.generate(b"hello ", 10, 0.0, 1);
        let b = m.generate(b"hello ", 10, 0.0, 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
    }

    #[test]
    fn generation_respects_max_seq() {
        let m = tiny_model(Arch::Llama, 9);
        let prompt = vec![1u8; 60];
        let out = m.generate(&prompt, 100, 0.5, 3);
        assert!(out.len() <= m.cfg.max_seq - 60);
    }

    #[test]
    fn cache_accounting() {
        let m = tiny_model(Arch::Gpt, 10);
        let mut cache = KvCache::new(&m);
        assert_eq!(cache.remaining(), 64);
        m.forward_cached(&[1, 2, 3], &mut cache);
        assert_eq!(cache.len, 3);
        assert!(cache.bytes() > 0);
    }
}
