//! Prefix-aware multi-engine router with cross-engine sequence
//! migration.
//!
//! One [`Gateway`] is one engine: one scheduler loop, one
//! [`crate::kv::BlockPool`]. This module runs **N replicas** of that
//! engine behind a single submit surface and adds the two scheduling
//! moves a fleet has that a single engine does not:
//!
//! * **Prefix-aware routing.** Each replica publishes the content
//!   digests of every cached prefix chain in its pool
//!   ([`GatewayHandle::prefix_digests`], refreshed once per scheduling
//!   round). A new prompt is digested block-by-block
//!   ([`crate::kv::prompt_digests`]) and routed to the replica holding
//!   the longest cached leading run — turning the pool's
//!   content-addressed prefix cache from a per-engine optimization
//!   into a fleet-level placement signal. With no cached prefix
//!   anywhere, the prompt falls to the least-loaded replica
//!   (round-robin on ties).
//! * **Mid-stream migration.** A live sequence can be suspended on one
//!   replica and resumed on another without the client noticing:
//!   [`GatewayHandle::migrate_out`] extracts the generation state plus
//!   the KV snapshot serialized through [`crate::kv::wire`],
//!   [`GatewayHandle::migrate_in`] geometry-checks and adopts it, and
//!   because the stream channel rides inside the
//!   [`crate::gateway::MigratedSeq`] the
//!   destination keeps writing into the very channel the client is
//!   reading. Greedy output is bit-identical to an unmigrated run
//!   (pinned in `tests/migration.rs`); sampled requests stay exact too
//!   because the RNG state and original seed migrate with the
//!   sequence.
//!
//! [`RouterOpts::migrate_after`] turns the second move into a policy:
//! every stream migrates once after that many generated tokens.
//! `migrate_after = 1` is **prefill→decode disaggregation** — the
//! routed replica serves the prefill (ideally on a cached prefix) and
//! the first token, then the decode tail moves to the least-loaded
//! peer.
//!
//! Replicas run without speculation ([`crate::spec::SpecPolicy`] holds
//! a boxed drafter and cannot be cloned per replica); compose spec
//! with single-[`Gateway`] serving where it matters.
//!
//! The router is transport-independent and implements
//! [`Frontend`], so the hand-rolled HTTP/SSE surface
//! ([`crate::gateway::http::serve`]) serves a fleet exactly as it
//! serves one engine (`examples/serve.rs --replicas N`).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::coordinator::batcher::BatchPolicy;
use crate::gateway::{
    Drained, Frontend, Gateway, GatewayHandle, GatewayOpts, GatewayRequest, StreamEvent,
    StreamHandle, SubmitError,
};
use crate::kv::prompt_digests;
use crate::model::Model;
use crate::swap::{SwapConfig, SwapDir};
use crate::util::json::Json;

/// Fleet-level policy knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct RouterOpts {
    /// Migrate each stream once, to the least-loaded peer, after this
    /// many generated tokens. `Some(1)` is prefill→decode
    /// disaggregation; `None` (default) never migrates.
    pub migrate_after: Option<usize>,
}

/// Where one public stream currently lives.
struct Route {
    replica: usize,
    local: u64,
    /// The cancel flag shared with the engine-side entry — it travels
    /// inside [`crate::gateway::MigratedSeq`], so flagging it reaches
    /// the sequence wherever it currently runs.
    cancel: Arc<AtomicBool>,
}

struct RouterInner {
    handles: Vec<GatewayHandle>,
    /// Public id → current placement. Entries are removed by the
    /// stream's forwarder thread when the stream ends.
    map: Mutex<HashMap<u64, Route>>,
    next_public: AtomicU64,
    migrations: AtomicU64,
    /// Round-robin cursor for the no-affinity tiebreak.
    rr: AtomicUsize,
    migrate_after: Option<usize>,
}

/// The running fleet. Owns the replica [`Gateway`]s;
/// [`Router::shutdown`] drains each and returns their [`Drained`]
/// records in replica order.
pub struct Router {
    gateways: Vec<Gateway>,
    inner: Arc<RouterInner>,
}

/// Cheap, cloneable fleet submitter (the [`Frontend`] the HTTP surface
/// serves).
#[derive(Clone)]
pub struct RouterHandle {
    inner: Arc<RouterInner>,
}

impl Router {
    /// Start `replicas` engine replicas of `model`. Each replica gets
    /// its own scheduler thread and pool; `swap` (if any) is cloned
    /// per replica with a **private** spill subdirectory
    /// (`<dir>/replica-<i>`), because spill files are keyed by
    /// engine-local request ids, which collide across replicas.
    pub fn start(
        model: &Model,
        replicas: usize,
        policy: BatchPolicy,
        opts: GatewayOpts,
        ropts: RouterOpts,
        swap: Option<SwapConfig>,
    ) -> crate::Result<Router> {
        anyhow::ensure!(replicas >= 1, "router needs at least one replica");
        let mut gateways = Vec::with_capacity(replicas);
        for i in 0..replicas {
            let cfg = match &swap {
                None => SwapConfig::default(),
                Some(c) => SwapConfig {
                    dir: match &c.dir {
                        None => None,
                        Some(d) => Some(SwapDir::new(d.path().join(format!("replica-{i}")))?),
                    },
                    ..c.clone()
                },
            };
            gateways.push(Gateway::start_with_swap(model.clone(), policy, None, opts, cfg));
        }
        let handles = gateways.iter().map(|g| g.handle()).collect();
        let inner = Arc::new(RouterInner {
            handles,
            map: Mutex::new(HashMap::new()),
            next_public: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            migrate_after: ropts.migrate_after,
        });
        Ok(Router { gateways, inner })
    }

    pub fn handle(&self) -> RouterHandle {
        RouterHandle { inner: self.inner.clone() }
    }

    /// Drain every replica and return their final states in replica
    /// order. Forwarder threads exit on their own once the replica
    /// channels close.
    pub fn shutdown(self) -> Vec<Drained> {
        self.gateways.into_iter().map(|g| g.shutdown()).collect()
    }
}

impl RouterHandle {
    /// Route and submit: longest cached prefix run wins, otherwise the
    /// least-loaded replica (round-robin on ties). The returned handle
    /// carries a fleet-wide public id; the stream survives any number
    /// of migrations underneath it.
    pub fn submit(&self, req: GatewayRequest) -> Result<StreamHandle, SubmitError> {
        let inner = &self.inner;
        let ri = inner.route(&req.prompt);
        let local = inner.handles[ri].submit(req)?;
        let public = inner.next_public.fetch_add(1, Ordering::SeqCst);
        let (lid, lrx, cancel) = local.into_parts();
        inner.map.lock().unwrap().insert(
            public,
            Route { replica: ri, local: lid, cancel: cancel.clone() },
        );
        let (ctx, crx) = channel();
        let fwd = inner.clone();
        let fwd_cancel = cancel.clone();
        std::thread::spawn(move || forward(&fwd, public, lrx, ctx, fwd_cancel));
        Ok(StreamHandle::attach(public, crx, cancel))
    }

    /// Flag a live stream for cancellation by public id; reaches the
    /// sequence on whichever replica currently runs it.
    pub fn cancel(&self, public: u64) -> bool {
        match self.inner.map.lock().unwrap().get(&public) {
            Some(r) => {
                r.cancel.store(true, Ordering::SeqCst);
                true
            }
            None => false,
        }
    }

    /// Completed cross-replica migrations.
    pub fn migrations(&self) -> u64 {
        self.inner.migrations.load(Ordering::SeqCst)
    }

    /// Fleet metrics: the router's own counters plus every replica's
    /// latest snapshot (under `"engines"`, in replica order).
    pub fn metrics_json(&self) -> String {
        let inner = &self.inner;
        let mut engines = Vec::new();
        let mut referenced = 0usize;
        for h in &inner.handles {
            let j = Json::parse(&h.metrics_json()).unwrap_or_else(|_| Json::obj(Vec::new()));
            if let Some(n) = j.get("pool_referenced_blocks").and_then(|v| v.as_usize()) {
                referenced += n;
            }
            engines.push(j);
        }
        Json::obj(vec![
            ("replicas", Json::from(inner.handles.len())),
            ("migrations", Json::from(inner.migrations.load(Ordering::SeqCst) as usize)),
            ("live_streams", Json::from(inner.map.lock().unwrap().len())),
            ("pool_referenced_blocks_total", Json::from(referenced)),
            ("engines", Json::Arr(engines)),
        ])
        .to_string()
    }
}

impl Frontend for RouterHandle {
    fn submit(&self, req: GatewayRequest) -> Result<StreamHandle, SubmitError> {
        RouterHandle::submit(self, req)
    }

    fn cancel(&self, id: u64) -> bool {
        RouterHandle::cancel(self, id)
    }

    fn metrics_json(&self) -> String {
        RouterHandle::metrics_json(self)
    }
}

impl RouterInner {
    /// Pick the replica for a new prompt.
    fn route(&self, prompt: &[u8]) -> usize {
        let mut best_i = 0usize;
        let mut best_score = 0usize;
        for (i, h) in self.handles.iter().enumerate() {
            let bt = h.block_tokens();
            if bt == 0 {
                continue;
            }
            let want = prompt_digests(prompt, bt);
            if want.is_empty() {
                break;
            }
            let have: HashSet<u64> = h.prefix_digests().into_iter().collect();
            let score = want.iter().take_while(|d| have.contains(d)).count();
            if score > best_score {
                best_score = score;
                best_i = i;
            }
        }
        if best_score > 0 {
            return best_i;
        }
        let n = self.handles.len();
        let start = self.rr.fetch_add(1, Ordering::SeqCst) % n;
        (0..n)
            .map(|k| (start + k) % n)
            .min_by_key(|&i| self.handles[i].queue_depth())
            .unwrap_or(0)
    }

    /// Move one live stream to the least-loaded other replica. A
    /// refusal at either end leaves the stream running where it was
    /// (the destination hands the sequence back intact on failure and
    /// it re-injects at the source).
    fn try_migrate(&self, public: u64) {
        let n = self.handles.len();
        if n < 2 {
            return;
        }
        let placed = {
            let m = self.map.lock().unwrap();
            m.get(&public).map(|r| (r.replica, r.local))
        };
        let Some((src, lid)) = placed else { return };
        let dst = (0..n)
            .filter(|&i| i != src)
            .min_by_key(|&i| self.handles[i].queue_depth())
            .expect("n >= 2 leaves at least one peer");
        let Some(seq) = self.handles[src].migrate_out(lid) else { return };
        match self.handles[dst].migrate_in(seq) {
            Ok(new_lid) => {
                if let Some(r) = self.map.lock().unwrap().get_mut(&public) {
                    r.replica = dst;
                    r.local = new_lid;
                }
                self.migrations.fetch_add(1, Ordering::SeqCst);
            }
            Err(Some(seq)) => {
                if let Ok(new_lid) = self.handles[src].migrate_in(seq) {
                    if let Some(r) = self.map.lock().unwrap().get_mut(&public) {
                        r.local = new_lid;
                    }
                }
            }
            // Destination loop died mid-handoff; the stream channel
            // died with it and the client sees a disconnect.
            Err(None) => {}
        }
    }
}

/// Per-stream forwarder: ferries events from the replica-side channel
/// to the client, counts tokens to trigger the one scheduled
/// migration, propagates client disconnects as cancellation, and
/// retires the routing entry when the stream ends. Migration does
/// *not* re-plumb this channel — the destination engine inherits the
/// replica-side sender, so `rx` keeps producing across the hop.
fn forward(
    inner: &RouterInner,
    public: u64,
    rx: Receiver<StreamEvent>,
    ctx: Sender<StreamEvent>,
    cancel: Arc<AtomicBool>,
) {
    let after = inner.migrate_after;
    let mut seen = 0usize;
    let mut tried = false;
    loop {
        // Replica gone (shutdown mid-stream): dropping `ctx` tells the
        // client.
        let Ok(ev) = rx.recv() else { break };
        let done = matches!(ev, StreamEvent::Done { .. });
        if matches!(ev, StreamEvent::Token { .. }) {
            seen += 1;
        }
        if ctx.send(ev).is_err() {
            // Client disconnected: the shared flag reaches the
            // sequence on whichever replica runs it.
            cancel.store(true, Ordering::SeqCst);
            break;
        }
        if done {
            break;
        }
        if !tried && matches!(after, Some(a) if seen >= a) {
            tried = true;
            inner.try_migrate(public);
        }
    }
    inner.map.lock().unwrap().remove(&public);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::tiny_model;
    use crate::model::Arch;
    use std::time::{Duration, Instant};

    #[test]
    fn prefix_affinity_routes_to_warm_replica() {
        let model = tiny_model(Arch::Gpt, 81);
        let router = Router::start(
            &model,
            2,
            BatchPolicy::default(),
            GatewayOpts::default(),
            RouterOpts::default(),
            None,
        )
        .unwrap();
        let h = router.handle();
        // Long enough to span at least one full KV block.
        let prompt = vec![65u8; 40];
        let s = h.submit(GatewayRequest::greedy(prompt.clone(), 4)).unwrap();
        assert!(!s.drain().cancelled);
        // Find the replica that served it (metrics refresh just after
        // `Done` is delivered, so poll briefly) — its published digests
        // now hold the frozen prompt prefix.
        let completed = |i: usize| {
            Json::parse(&router.inner.handles[i].metrics_json())
                .ok()
                .and_then(|j| j.get("requests_completed").and_then(|v| v.as_usize()))
                .unwrap_or(0)
        };
        let deadline = Instant::now() + Duration::from_secs(5);
        let first = loop {
            if let Some(i) = (0..2).find(|&i| completed(i) == 1) {
                break i;
            }
            assert!(Instant::now() < deadline, "completion never surfaced");
            std::thread::sleep(Duration::from_millis(2));
        };
        let rh = &router.inner.handles[first];
        assert!(!rh.prefix_digests().is_empty(), "finished prefix must be cached");
        // Same prompt again: affinity must pick the warm replica.
        let s2 = h.submit(GatewayRequest::greedy(prompt, 4)).unwrap();
        assert!(!s2.drain().cancelled);
        let deadline = Instant::now() + Duration::from_secs(5);
        while completed(first) != 2 {
            assert!(Instant::now() < deadline, "second request must hit the warm replica");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(completed(1 - first), 0, "cold replica must stay idle");
        let m = Json::parse(&h.metrics_json()).unwrap();
        assert_eq!(m.get("replicas").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(m.get("migrations").and_then(|v| v.as_usize()), Some(0));
        for d in router.shutdown() {
            assert_eq!(d.referenced_blocks, 0);
        }
    }
}
