//! Cycle-level simulated flexible N:M sparse tensor core.
//!
//! The paper assumes a *futuristic, flexible* N:M structured-sparse
//! tensor core (§3.1, Liu et al. 2021; Jeong et al. 2023) that delivers
//! `M/N×` throughput on N:M operands, and low-bit arithmetic that scales
//! throughput by `16/bits` (§3.2). This simulator models such a core at
//! tile granularity — MAC slots, metadata-decode overhead, per-tile
//! scale-factor application — so the *achieved* throughput (with its
//! sparsity tax) can be compared against the paper's idealized analytic
//! model (an ablation the paper itself motivates by citing Wu et al.'s
//! "sparsity tax").


use crate::sdq::config::{CompressionConfig, Stages};
use crate::sdq::nm::NmPattern;

/// Hardware description of the simulated tensor core.
#[derive(Clone, Copy, Debug)]
pub struct TensorCoreSpec {
    /// fp16 MAC lanes per cycle (dense peak).
    pub fp16_macs_per_cycle: u64,
    /// Tile shape the core consumes per pass: (tm, tn, tk).
    pub tile: (usize, usize, usize),
    /// Cycles to decode N:M index metadata per tile (sparsity tax).
    pub meta_decode_cycles: u64,
    /// Cycles to apply per-vector scale factors per tile (quant tax).
    pub scale_apply_cycles: u64,
    /// Pipeline fill cycles per GEMM launch.
    pub launch_cycles: u64,
    /// Clock in GHz (for wall-clock estimates).
    pub clock_ghz: f64,
}

impl Default for TensorCoreSpec {
    /// Roughly one A100 SM-pair worth of tensor core (order-of-magnitude;
    /// only *ratios* matter for the evaluation).
    fn default() -> Self {
        TensorCoreSpec {
            fp16_macs_per_cycle: 512,
            tile: (64, 64, 64),
            meta_decode_cycles: 4,
            scale_apply_cycles: 2,
            launch_cycles: 100,
            clock_ghz: 1.4,
        }
    }
}

/// One GEMM operand-pass description: pattern + operand bit width.
#[derive(Clone, Copy, Debug)]
pub struct GemmPass {
    pub pattern: NmPattern,
    pub bits: u32,
}

/// Simulation result for a GEMM (possibly multiple passes for SDQ).
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    pub cycles: u64,
    pub dense_fp16_cycles: u64,
    /// Achieved speedup vs dense fp16 on the same core.
    pub speedup: f64,
    /// Idealized analytic speedup (no taxes).
    pub analytic_speedup: f64,
    /// 1 - achieved/analytic: the sparsity+quant tax.
    pub tax: f64,
}

impl TensorCoreSpec {
    /// MAC slots per cycle at `bits`-wide operands (§3.2: area-for-width
    /// tradeoff, 16/bits scaling as in Ampere int8/int4 tensor cores).
    pub fn macs_per_cycle(&self, bits: u32) -> u64 {
        self.fp16_macs_per_cycle * 16 / bits.max(1) as u64
    }

    /// Simulate one pass of `[t×k]·[o×k]ᵀ` with an N:M weight operand.
    pub fn simulate_pass(&self, t: usize, k: usize, o: usize, pass: GemmPass) -> u64 {
        let (tm, tn, tk) = self.tile;
        let tiles_m = t.div_ceil(tm) as u64;
        let tiles_n = o.div_ceil(tn) as u64;
        let tiles_k = k.div_ceil(tk) as u64;
        let tiles = tiles_m * tiles_n * tiles_k;
        // Stored MAC slots per tile: the core executes N/M of the dense
        // MACs, padded slots included (packed layout executes exactly
        // tile_macs · N/M slots).
        let tile_macs = (tm * tn * tk) as u64;
        let stored = tile_macs * pass.pattern.n as u64 / pass.pattern.m as u64;
        let mac_cycles_num = stored * tiles;
        let mpc = self.macs_per_cycle(pass.bits);
        let compute = mac_cycles_num.div_ceil(mpc);
        let meta = if pass.pattern.is_dense() { 0 } else { self.meta_decode_cycles * tiles };
        let scale = if pass.bits < 16 { self.scale_apply_cycles * tiles } else { 0 };
        self.launch_cycles + compute + meta + scale
    }

    /// Simulate a full configuration on one GEMM shape. SDQ runs two
    /// passes (outlier + inlier), everything else one.
    pub fn simulate(&self, cfg: &CompressionConfig, t: usize, k: usize, o: usize) -> SimResult {
        let dense_pass = GemmPass { pattern: NmPattern::new(1, 1), bits: 16 };
        let dense_cycles = self.simulate_pass(t, k, o, dense_pass);
        let cycles = match &cfg.stages {
            Stages::Dense => dense_cycles,
            Stages::SparsifyOnly(sp) => {
                self.simulate_pass(t, k, o, GemmPass { pattern: sp.pattern, bits: 16 })
            }
            Stages::QuantOnly { weight_fmt, act_fmt, .. } => {
                let bits = match act_fmt {
                    Some(a) => weight_fmt.bits().max(a.bits()),
                    None => 16, // weight-only: fp16 compute (§2.3)
                };
                self.simulate_pass(
                    t,
                    k,
                    o,
                    GemmPass { pattern: NmPattern::new(1, 1), bits },
                )
            }
            Stages::Sdq { decompose, .. } => {
                let o_pass = GemmPass {
                    pattern: decompose.outlier_pattern,
                    bits: decompose.outlier_fmt.bits(),
                };
                let i_pass = GemmPass {
                    pattern: decompose.inlier_pattern,
                    bits: decompose.inlier_fmt.bits(),
                };
                // Launch once; passes share the output accumulator.
                self.simulate_pass(t, k, o, o_pass) + self.simulate_pass(t, k, o, i_pass)
                    - self.launch_cycles
            }
        };
        let analytic = cfg.effective_throughput();
        let speedup = dense_cycles as f64 / cycles as f64;
        SimResult {
            cycles,
            dense_fp16_cycles: dense_cycles,
            speedup,
            analytic_speedup: analytic,
            tax: 1.0 - speedup / analytic,
        }
    }

    /// Wall-clock estimate for `cycles`.
    pub fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_ghz * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TensorCoreSpec {
        TensorCoreSpec::default()
    }

    #[test]
    fn dense_fp16_is_reference() {
        let cfg: CompressionConfig = "Dense-WA16".parse().unwrap();
        let r = spec().simulate(&cfg, 512, 4096, 4096);
        assert!((r.speedup - 1.0).abs() < 1e-9);
        assert!(r.tax.abs() < 1e-9);
    }

    #[test]
    fn int8_dual_quant_close_to_2x() {
        let cfg: CompressionConfig = "Q-VSQuant-WAint8".parse().unwrap();
        let r = spec().simulate(&cfg, 512, 4096, 4096);
        assert!(r.speedup > 1.8 && r.speedup <= 2.0, "{}", r.speedup);
    }

    #[test]
    fn sdq_achieves_near_4x_with_small_tax() {
        let cfg: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
        let r = spec().simulate(&cfg, 512, 4096, 4096);
        assert!((r.analytic_speedup - 4.0).abs() < 1e-9);
        assert!(r.speedup > 3.2, "achieved {} too far from analytic", r.speedup);
        assert!(r.tax < 0.2, "sparsity tax {} too large", r.tax);
    }

    #[test]
    fn small_gemms_pay_larger_tax() {
        let cfg: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
        let big = spec().simulate(&cfg, 512, 4096, 4096);
        let small = spec().simulate(&cfg, 8, 256, 256);
        assert!(small.tax > big.tax, "small {} vs big {}", small.tax, big.tax);
    }

    #[test]
    fn weight_only_quant_runs_at_fp16_speed() {
        let cfg: CompressionConfig = "Q-VSQuant-Wint4".parse().unwrap();
        let r = spec().simulate(&cfg, 512, 4096, 4096);
        assert!((r.speedup - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sparsity_only_2x() {
        let cfg: CompressionConfig = "S-Wanda-4:8".parse().unwrap();
        let r = spec().simulate(&cfg, 512, 4096, 4096);
        assert!(r.speedup > 1.8 && r.analytic_speedup == 2.0);
    }
}
