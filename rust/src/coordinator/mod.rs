//! L3 serving coordinator.
//!
//! A vLLM-style inference front end over the compressed model:
//! request queue → block-budget admission → prefix attach + batched
//! multi-prompt prefill → fused ragged decode rounds (optionally
//! **speculative**: draft → fused verify → accept/rollback, see
//! [`crate::spec`]) → responses with latency metrics. KV memory lives
//! in the shared [`crate::kv::BlockPool`] (prefix sharing,
//! copy-on-write, LRU eviction, speculative rollback, and — under
//! `BatchPolicy::preempt` — swap-out/swap-in of whole sequences so
//! admission can oversubscribe the pool, see [`scheduler`]); the legacy
//! per-sequence chunked-cache path survives as the benchmark baseline
//! (`BatchPolicy::batched_decode = false`).
//! Python is never on this path; the model weights come from
//! `artifacts/` and the compute is either the native Rust engine
//! ([`crate::model`]) or the AOT PJRT executable ([`crate::runtime`]).
//!
//! * [`request`] — request/response types.
//! * [`batcher`] — admission queue and batch formation policy.
//! * [`scheduler`] — the continuous-batching prefill/decode loop.
//! * [`metrics`] — counters + latency histograms + pool stats.
//! * [`engine`] — ties them together behind a thread-safe handle.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;

pub use engine::Engine;
pub use request::{assert_bit_identical, Request, Response};
