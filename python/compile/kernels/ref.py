"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Everything here is the *specification*: the Pallas kernels in
`sdq_matmul.py` must match these under interpret=True (asserted by
`python/tests/test_kernel.py`, including hypothesis sweeps), and the Rust
pipeline mirrors the same math.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import formats


def act_quant(x, fmt: str, qvec: int):
    """Dynamic per-Q-vector activation fake-quant: max-abs fp32 scales
    (mirror of `fake_quant_dynamic` in rust). `x: [t, k]`, qvec | k."""
    t, k = x.shape
    assert k % qvec == 0
    g = x.reshape(t, k // qvec, qvec)
    max_abs = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = max_abs / formats.MAX_VALUE[fmt]
    q = formats.quantize(jnp.where(scale > 0, g / scale, 0.0), fmt) * scale
    q = jnp.where(max_abs > 0, q, 0.0)
    return q.reshape(t, k)


def weight_fake_quant(w, fmt: str, qvec: int, scale_fmt: str = "fp8-e4m3"):
    """Two-level VS-Quant weight fake-quant (mirror of `quantize_tensor` →
    `dequantize` in rust). `w: [o, k]`, Q-vectors along k."""
    codes, scales = quantize_weight_codes(w, fmt, qvec, scale_fmt)
    return dequant(codes, scales, qvec)


def quantize_weight_codes(w, fmt: str, qvec: int, scale_fmt: str = "fp8-e4m3"):
    """Split VS-Quant into (codes, scales) — the representation the Pallas
    kernel consumes. Returns codes `[o, k]` (grid values) and combined
    per-vector scales `[o, k/qvec]` (ratio_q · chan)."""
    o, k = w.shape
    assert k % qvec == 0
    g = w.reshape(o, k // qvec, qvec)
    max_abs = jnp.max(jnp.abs(g), axis=-1)
    raw = max_abs / formats.MAX_VALUE[fmt]
    chan = jnp.max(raw, axis=-1, keepdims=True)
    chan = jnp.where(chan > 0, chan, 1.0)
    ratio = raw / chan
    ratio_q = jnp.where(ratio > 0, formats.quantize(ratio, scale_fmt), 0.0)
    scales = ratio_q * chan  # [o, k/qvec]
    s = scales[..., None]
    codes = formats.quantize(jnp.where(s > 0, g / s, 0.0), fmt)
    return codes.reshape(o, k), scales


def dequant(codes, scales, qvec: int):
    """Inverse of `quantize_weight_codes`."""
    o, k = codes.shape
    g = codes.reshape(o, k // qvec, qvec) * scales[..., None]
    return g.reshape(o, k)


def nm_mask(w, n: int, m: int):
    """Top-|w| N:M mask along the last dim (ties to lower index)."""
    o, k = w.shape
    g = jnp.abs(w).reshape(o, k // m, m)
    # rank by descending magnitude; stable tie-break on index
    order = jnp.argsort(-g, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)
    return (rank < n).reshape(o, k)


def decompose_local_outliers(w, n_out: int, m: int):
    """N:M local outlier extraction by magnitude (§4): returns
    (outliers, inliers) with disjoint support summing to `w`."""
    mask = nm_mask(w, n_out, m)
    mask = mask & (w != 0.0)
    return jnp.where(mask, w, 0.0), jnp.where(mask, 0.0, w)


def sdq_matmul_ref(
    x,
    wo_codes,
    wo_scales,
    wi_codes,
    wi_scales,
    *,
    qvec: int,
    outlier_fmt: str = "int8",
    inlier_fmt: str = "fp4",
):
    """Reference decomposed dual-quantized GEMM (Fig. 8):

        Y = Q_o(X) · Wo_deqᵀ + Q_i(X) · Wi_deqᵀ

    with dynamic activation quantization per path."""
    wo = dequant(wo_codes, wo_scales, qvec)
    wi = dequant(wi_codes, wi_scales, qvec)
    xo = act_quant(x, outlier_fmt, qvec)
    xi = act_quant(x, inlier_fmt, qvec)
    return xo @ wo.T + xi @ wi.T


def dual_quant_matmul_ref(x, w_codes, w_scales, *, qvec: int, fmt: str):
    """Reference single-path dual-quantized GEMM (Q-VSQuant-WA rows)."""
    w = dequant(w_codes, w_scales, qvec)
    xq = act_quant(x, fmt, qvec)
    return xq @ w.T
