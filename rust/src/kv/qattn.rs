//! Quantized-domain attention kernels: compute over raw KV codes.
//!
//! The scratch route ([`super::BlockPool::layer_views`]) services a
//! quantized pool by dequantizing every resident block's K/V rows into
//! an fp32 [`super::KvScratch`] arena each layer, then attending over
//! the borrowed fp32 segments. At int8's 4× residency that staging copy
//! — write `rows × d` floats, read them straight back — is pure memory
//! traffic: the decode itself is one multiply per element.
//!
//! This module is the fused alternative ([`super::BlockPool::
//! layer_code_views`] hands out [`QuantSeg`]s): attention streams the
//! 1-byte codes directly and decodes **in register**, inside the dot /
//! accumulate loops, with the block's per-layer scale applied per
//! element. No scratch write, no fp32 re-read — the win the pool's
//! `dequant_bytes_avoided` counter measures.
//!
//! # Bit-exactness
//!
//! These kernels are bit-identical to dequantize-then-attend for *both*
//! quantized dtypes, which is what lets the serving path switch over
//! without disturbing any pinned logits:
//!
//! * each element decodes as `fl(raw(code) · scale)` — exactly the op
//!   `KvStore::dequant_into` applies (int8: `code as f32`, exact; fp8:
//!   a 256-entry table of the pure [`super::fp8_e4m3_decode`]);
//! * [`dot_head`] then replays [`crate::tensor::dot`]'s exact
//!   schedule (32-lane accumulator array, pairwise tree reduction,
//!   scalar tail) over the decoded values, and [`axpy_head`] replays
//!   attention's elementwise `out += w · v`.
//!
//! Same inputs, same ops, same order ⇒ same f32 bits. The property
//! tests in `tests/qattn.rs` pin this against the scratch route under
//! random block boundaries, amax growth, COW forks and truncation.
//!
//! The issue's `score_blk = scale_k · Σ q·code` factoring (hoisting the
//! scale out of the partial dot) is mathematically equal for int8 but
//! *not* bit-equal under f32 rounding; decoding in register keeps the
//! fusion win while staying on the dequantize path's exact bit pattern.

use std::sync::OnceLock;

use super::store::{fp8_e4m3_decode, KvDtype};

/// One block's worth of raw K or V codes for one layer, plus the
/// effective decode scale (`amax / code_max`). `codes` is `rows × d`
/// bytes, row-major, exactly the slab layout `KvStore` keeps.
#[derive(Clone, Copy, Debug)]
pub struct QuantSeg<'a> {
    pub codes: &'a [u8],
    pub scale: f32,
}

/// 256-entry decode table for fp8-e4m3 codes. [`fp8_e4m3_decode`] is a
/// pure function of the byte, so a table lookup is bit-identical to
/// calling it — it just drops the per-element branch chain.
fn fp8_lut() -> &'static [f32; 256] {
    static LUT: OnceLock<[f32; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0.0f32; 256];
        for (b, slot) in t.iter_mut().enumerate() {
            *slot = fp8_e4m3_decode(b as u8);
        }
        t
    })
}

/// Decode one raw code byte (scale not yet applied).
#[inline]
pub fn raw_decode(dtype: KvDtype, b: u8) -> f32 {
    match dtype {
        KvDtype::Int8 => (b as i8) as f32,
        KvDtype::Fp8E4M3 => fp8_lut()[b as usize],
        KvDtype::F32 => unreachable!("f32 pools read zero-copy, not via codes"),
    }
}

/// Dot product of an fp32 query head slice against a quantized K head
/// slice, decoding in register. Bit-identical to
/// `dot(q, dequantized_k_row)` — see the module docs.
#[inline]
pub fn dot_head(q: &[f32], codes: &[u8], scale: f32, dtype: KvDtype) -> f32 {
    match dtype {
        KvDtype::Int8 => dot_head_raw(q, codes, scale, |b| (b as i8) as f32),
        KvDtype::Fp8E4M3 => {
            let lut = fp8_lut();
            dot_head_raw(q, codes, scale, |b| lut[b as usize])
        }
        KvDtype::F32 => unreachable!("f32 pools read zero-copy, not via codes"),
    }
}

/// The [`crate::tensor::dot`] schedule — 32 independent
/// accumulators, pairwise tree reduction, scalar tail — replayed over
/// `fl(raw(code) · scale)` elements. Any change here must stay in
/// lockstep with `dot` or the bit-exactness pins break.
#[inline]
fn dot_head_raw(x: &[f32], codes: &[u8], scale: f32, raw: impl Fn(u8) -> f32) -> f32 {
    debug_assert_eq!(x.len(), codes.len());
    let n = x.len();
    const W: usize = 32;
    let mut acc = [0.0f32; W];
    let chunks = n / W;
    for i in 0..chunks {
        let xi = &x[i * W..i * W + W];
        let yi = &codes[i * W..i * W + W];
        for l in 0..W {
            acc[l] += xi[l] * (raw(yi[l]) * scale);
        }
    }
    let mut width = W / 2;
    while width > 0 {
        for l in 0..width {
            acc[l] += acc[l + width];
        }
        width /= 2;
    }
    let mut s = acc[0];
    for i in chunks * W..n {
        s += x[i] * (raw(codes[i]) * scale);
    }
    s
}

/// `out[l] += w · decode(codes[l])` — the score·V accumulation with the
/// V decode fused in. Bit-identical to the fp32 path's
/// `out += w · v_row` over a dequantized row.
#[inline]
pub fn axpy_head(out: &mut [f32], w: f32, codes: &[u8], scale: f32, dtype: KvDtype) {
    match dtype {
        KvDtype::Int8 => {
            for (o, &b) in out.iter_mut().zip(codes) {
                *o += w * ((b as i8) as f32 * scale);
            }
        }
        KvDtype::Fp8E4M3 => {
            let lut = fp8_lut();
            for (o, &b) in out.iter_mut().zip(codes) {
                *o += w * (lut[b as usize] * scale);
            }
        }
        KvDtype::F32 => unreachable!("f32 pools read zero-copy, not via codes"),
    }
}

/// Decode a head slice into `dst` (`dst[l] = decode(codes[l])`) — used
/// to fill the per-head K panel that RoPE rotates in place. Same
/// per-element op as `KvStore::dequant_into`, so the panel holds the
/// same bits the scratch route would have copied in.
#[inline]
pub fn decode_head_into(dst: &mut [f32], codes: &[u8], scale: f32, dtype: KvDtype) {
    debug_assert_eq!(dst.len(), codes.len());
    match dtype {
        KvDtype::Int8 => {
            for (o, &b) in dst.iter_mut().zip(codes) {
                *o = (b as i8) as f32 * scale;
            }
        }
        KvDtype::Fp8E4M3 => {
            let lut = fp8_lut();
            for (o, &b) in dst.iter_mut().zip(codes) {
                *o = lut[b as usize] * scale;
            }
        }
        KvDtype::F32 => unreachable!("f32 pools read zero-copy, not via codes"),
    }
}

/// Head-column slice of a quantized row: the code analogue of the fp32
/// path's `seg_head`. `r` is the absolute row over the concatenated
/// segments (`seg_tokens` rows per segment), `col0..col0+dh` the head
/// columns.
#[inline]
pub fn seg_head_codes<'a>(
    segs: &[QuantSeg<'a>],
    seg_tokens: usize,
    d: usize,
    col0: usize,
    dh: usize,
    r: usize,
) -> (&'a [u8], f32) {
    let seg = &segs[r / seg_tokens];
    (&seg.codes[(r % seg_tokens) * d + col0..][..dh], seg.scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;

    fn codes_and_floats(dtype: KvDtype, n: usize, seed: u64) -> (Vec<u8>, Vec<f32>, f32) {
        let mut s = seed;
        let mut next = move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
            (s >> 33) as u32
        };
        let scale = 0.0173f32;
        let codes: Vec<u8> = (0..n)
            .map(|_| {
                let b: i32 = match dtype {
                    KvDtype::Int8 => (next() % 255) as i32 - 127,
                    _ => {
                        // Any non-NaN fp8 byte pattern.
                        let mut b = (next() % 256) as i32;
                        if b & 0x7f == 0x7f {
                            b &= !0x08;
                        }
                        b
                    }
                };
                b as u8
            })
            .collect();
        let deq: Vec<f32> = codes.iter().map(|&b| raw_decode(dtype, b) * scale).collect();
        (codes, deq, scale)
    }

    #[test]
    fn fp8_lut_matches_decoder() {
        for b in 0..=255u8 {
            assert_eq!(fp8_lut()[b as usize].to_bits(), fp8_e4m3_decode(b).to_bits());
        }
    }

    #[test]
    fn dot_head_bit_matches_dequant_then_dot() {
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            // 67 exercises two 32-lane chunks plus the scalar tail.
            for n in [8usize, 32, 67] {
                let (codes, deq, scale) = codes_and_floats(dtype, n, 7 + n as u64);
                let q: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
                let fused = dot_head(&q, &codes, scale, dtype);
                let reference = dot(&q, &deq);
                assert_eq!(fused.to_bits(), reference.to_bits(), "{dtype:?} n={n}");
            }
        }
    }

    #[test]
    fn axpy_head_bit_matches_dequant_then_axpy() {
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let n = 24;
            let (codes, deq, scale) = codes_and_floats(dtype, n, 99);
            let mut fused: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
            let mut reference = fused.clone();
            axpy_head(&mut fused, 0.625, &codes, scale, dtype);
            for (o, &v) in reference.iter_mut().zip(&deq) {
                *o += 0.625 * v;
            }
            for (a, b) in fused.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?}");
            }
        }
    }

    #[test]
    fn decode_head_matches_reference() {
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let (codes, deq, scale) = codes_and_floats(dtype, 16, 5);
            let mut dst = vec![0.0f32; 16];
            decode_head_into(&mut dst, &codes, scale, dtype);
            for (a, b) in dst.iter().zip(&deq) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn seg_head_codes_walks_segments() {
        let (d, st, dh) = (4, 2, 2);
        let a: Vec<u8> = (0..st * d).map(|i| i as u8).collect();
        let b: Vec<u8> = (0..st * d).map(|i| 100 + i as u8).collect();
        let segs =
            [QuantSeg { codes: &a, scale: 1.0 }, QuantSeg { codes: &b, scale: 2.0 }];
        let (head, sc) = seg_head_codes(&segs, st, d, 2, dh, 3);
        assert_eq!(head, &[106, 107]);
        assert_eq!(sc, 2.0);
    }
}
