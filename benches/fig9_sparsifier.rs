//! Fig. 9 — sensitivity to the sparsification stage: Wanda vs SparseGPT
//! at N:8 for N ∈ {7, 6, 5, 4}, both sparsification-only and as SDQ's
//! stage 1 (outliers fixed at 1:8 int8, inliers (N−1):8 fp4).

use sdq::harness;
use sdq::sdq::config::CompressionConfig;
use sdq::util::bench::Table;

fn main() {
    if !harness::artifacts_ready() {
        return;
    }
    let mname = "gpt-micro"; // paper uses OPT-6.7B
    let model = harness::load_model(mname).expect("model");
    let ds = harness::load_dataset().expect("corpus");
    let ecfg = harness::eval_cfg_for(&model, false);

    let mut table = Table::new(
        &format!("Fig 9: sparsification-stage sensitivity — {mname}"),
        &["N:8", "S-Wanda", "S-SparseGPT", "SDQ-W", "SDQ-S"],
    );
    let dense = harness::eval_config(&model, &ds, &"Dense-WA16".parse().unwrap(), ecfg)
        .unwrap()
        .ppl
        .ppl;
    println!("baseline Dense-WA16 ppl = {dense:.3}");

    for n in [7usize, 6, 5, 4] {
        let mut cells = vec![format!("{n}:8")];
        for cfg_str in [
            format!("S-Wanda-{n}:8"),
            format!("S-SparseGPT-{n}:8"),
            format!("SDQ-W{n}:8-1:8int8-{}:8fp4", n - 1),
            format!("SDQ-S{n}:8-1:8int8-{}:8fp4", n - 1),
        ] {
            let cfg: CompressionConfig = cfg_str.parse().unwrap();
            match harness::eval_config(&model, &ds, &cfg, ecfg) {
                Ok(r) => {
                    eprintln!("  {cfg_str}: {:.3}", r.ppl.ppl);
                    cells.push(format!("{:.3}", r.ppl.ppl));
                }
                Err(e) => cells.push(format!("err {e}")),
            }
        }
        table.row(cells);
    }
    table.print();
    table.save_json("fig9_sparsifier");
    println!("\nExpected shape: SDQ rows track their stage-1 sparsifier; ppl grows as N falls.");
}
