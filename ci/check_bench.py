#!/usr/bin/env python3
"""Bench-regression gate: compare a fresh `BENCH_serving.json` against the
committed `ci/bench_baseline.json`.

Rows are matched on (Config, kv dtype, spec, max_active) — "spec" is
the speculative-decode arm (off | ngram | sdq-draft), distinguishing
rows that share a (Config, kv dtype, max_active) cell. Two metrics are
gated, both with a relative tolerance (default ±25%):

* ``batched tok/s`` — one-sided: the current run must not fall more than
  the tolerance *below* the baseline (getting faster never fails). A
  baseline value of ``null`` means "not yet recorded on CI hardware";
  such rows are reported but do not gate — refresh the baseline with
  ``--update`` from a trusted run to arm the throughput gate.
* ``prefix hit`` — two-sided: the prefix-cache hit rate is a
  deterministic property of the workload, so drift in either direction
  is a behavioral regression (an absolute floor of 0.02 absorbs
  rounding of the printed rate).

A second gate covers the kernel microbenchmarks: `BENCH_hotpath.json`
(from ``cargo bench --bench hotpath -- --smoke``) against
``ci/bench_hotpath_baseline.json``. Hotpath rows key on the ``bench``
name column and gate ``median ms`` one-sided — slower than baseline by
more than the tolerance fails, faster never does. A ``null`` baseline
median is record-only, exactly like a null serving throughput; arm it
with ``--update`` from a trusted run. If the hotpath result file is
absent (e.g. a serving-only invocation) the hotpath gate is skipped
with a note rather than failing.

A third gate covers the streaming gateway's closed-loop latency:
`BENCH_latency.json` (from ``cargo bench --bench latency -- --smoke``)
against ``ci/bench_latency_baseline.json``. Latency rows key on
(Config, kv dtype, spec, preempt, arrival rate) and gate **two**
metrics, both one-sided: ``p99 ttft ms`` (queue wait + first token)
and ``p99 itl ms`` (inter-token gap). Null baselines are record-only
per metric; absent files skip the gate with a note, exactly like the
hotpath table.

The three-table arming flow: every new row lands with null metrics
(committed by the PR that adds the bench case — symmetric coverage
makes CI fail otherwise), CI reports record-only values until someone
runs ``python3 ci/check_bench.py --update`` on trusted hardware and
commits the refreshed baselines, after which the numeric gates arm.
``--update`` skips (with a note) any results file that does not exist,
so a partial bench run can refresh just the tables it produced.

Row coverage is gated **symmetrically** in both tables: a baseline row
missing from the current run fails (a bench case silently disappeared),
and a current row missing from the baseline fails too (a new bench case
was added without recording it — add the row to the baseline file with
a ``null`` metric, or refresh with ``--update``). New rows therefore
always require a one-time baseline touch: commit them with ``null``
metrics (record-only until trusted hardware arms them via
``python3 ci/check_bench.py --update``), never with numbers measured on
a developer machine.

Exit status is non-zero on any failure, which fails the CI job.

Usage:
    python3 ci/check_bench.py [--current BENCH_serving.json]
                              [--baseline ci/bench_baseline.json]
                              [--hotpath-current BENCH_hotpath.json]
                              [--hotpath-baseline ci/bench_hotpath_baseline.json]
                              [--latency-current BENCH_latency.json]
                              [--latency-baseline ci/bench_latency_baseline.json]
                              [--tolerance 0.25]
                              [--update]
"""

import argparse
import json
import os
import sys

# "spec" distinguishes the speculative-decode rows (off | ngram |
# sdq-draft) and "preempt" the preemptive-scheduling rows (off | on)
# that share a (Config, kv dtype, max_active) cell with the plain row;
# legacy baselines without either field key as "off", so pre-spec and
# pre-preemption baselines keep matching current plain rows.
KEY_FIELDS = ("Config", "kv dtype", "spec", "preempt", "max_active")

# The gateway latency table sweeps arrival rate instead of batch width.
LATENCY_KEY_FIELDS = ("Config", "kv dtype", "spec", "preempt", "arrival rate")

# Key fields that default to "off" when a (legacy) row lacks them.
_OFF_DEFAULT = {"spec", "preempt"}


def row_key(row, fields=KEY_FIELDS):
    return tuple(
        str(row.get(k, "off") if k in _OFF_DEFAULT else row.get(k))
        for k in fields
    )


def as_float(value):
    """Parse a metric cell (string, number, or null) to float or None."""
    if value is None:
        return None
    try:
        return float(value)
    except (TypeError, ValueError):
        return None


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("rows")
    if not isinstance(rows, list):
        raise SystemExit(f"{path}: expected a top-level 'rows' array")
    return doc, rows


def gate_hotpath(cur_rows, base_rows, tol, failures, notes):
    """One-sided latency gate on the hotpath microbench table.

    Rows key on the 'bench' name (shapes are identical in smoke and
    full runs). A null baseline median is record-only.
    """
    current = {str(r.get("bench")): r for r in cur_rows}
    # Symmetric coverage: a bench case added without a baseline row is
    # as much a gate escape as one that silently disappeared.
    base_names = {str(b.get("bench")) for b in base_rows}
    for name in current:
        if name not in base_names:
            failures.append(
                f"[hotpath {name}] row missing from baseline — add it to the "
                f"baseline file with a null 'median ms' (or run --update)"
            )
    for base in base_rows:
        name = str(base.get("bench"))
        cur = current.get(name)
        if cur is None:
            failures.append(f"[hotpath {name}] row missing from current results")
            continue
        base_ms = as_float(base.get("median ms"))
        cur_ms = as_float(cur.get("median ms"))
        if base_ms is None:
            notes.append(
                f"[hotpath {name}] latency baseline not yet recorded "
                f"(current: {cur_ms} ms); run with --update on trusted hardware"
            )
        elif cur_ms is None:
            failures.append(f"[hotpath {name}] current median missing/unparseable")
        elif cur_ms > base_ms * (1.0 + tol):
            failures.append(
                f"[hotpath {name}] latency regressed: {cur_ms:.3f} ms > "
                f"{base_ms:.3f} × (1 + {tol:.2f})"
            )
        else:
            notes.append(
                f"[hotpath {name}] latency ok: {cur_ms:.3f} ms "
                f"(baseline {base_ms:.3f})"
            )


def gate_latency(cur_rows, base_rows, tol, failures, notes):
    """One-sided gates on the gateway latency table: 'p99 ttft ms' and
    'p99 itl ms', keyed on LATENCY_KEY_FIELDS. Null baselines are
    record-only per metric, coverage is symmetric (a latency arm that
    appears or disappears without a baseline touch fails)."""
    current = {row_key(r, LATENCY_KEY_FIELDS): r for r in cur_rows}
    base_keys = {row_key(b, LATENCY_KEY_FIELDS) for b in base_rows}
    for k in current:
        if k not in base_keys:
            failures.append(
                f"[latency {' / '.join(k)}] row missing from baseline — add it "
                f"with null p99 metrics (or run --update)"
            )
    for base in base_rows:
        k = row_key(base, LATENCY_KEY_FIELDS)
        label = "latency " + " / ".join(k)
        cur = current.get(k)
        if cur is None:
            failures.append(f"[{label}] row missing from current results")
            continue
        for metric in ("p99 ttft ms", "p99 itl ms"):
            base_ms = as_float(base.get(metric))
            cur_ms = as_float(cur.get(metric))
            if base_ms is None:
                notes.append(
                    f"[{label}] {metric} baseline not yet recorded "
                    f"(current: {cur_ms}); run with --update on trusted hardware"
                )
            elif cur_ms is None:
                failures.append(f"[{label}] current {metric} missing/unparseable")
            elif cur_ms > base_ms * (1.0 + tol):
                failures.append(
                    f"[{label}] {metric} regressed: {cur_ms:.2f} > "
                    f"{base_ms:.2f} × (1 + {tol:.2f})"
                )
            else:
                notes.append(
                    f"[{label}] {metric} ok: {cur_ms:.2f} (baseline {base_ms:.2f})"
                )


def refresh(current, baseline):
    """Rewrite one baseline from its current results file. A missing
    results file is skipped with a note, not a traceback — ``--update``
    after a partial bench run refreshes only the tables that ran."""
    if not os.path.exists(current):
        print(f"{current} absent; baseline {baseline} untouched")
        return False
    cur_doc, cur_rows = load_rows(current)
    with open(baseline, "w") as f:
        json.dump(cur_doc, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"baseline refreshed from {current} ({len(cur_rows)} rows)")
    return True


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_serving.json")
    ap.add_argument("--baseline", default="ci/bench_baseline.json")
    ap.add_argument("--hotpath-current", default="BENCH_hotpath.json")
    ap.add_argument("--hotpath-baseline", default="ci/bench_hotpath_baseline.json")
    ap.add_argument("--latency-current", default="BENCH_latency.json")
    ap.add_argument("--latency-baseline", default="ci/bench_latency_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baselines from the current results instead of comparing",
    )
    args = ap.parse_args()

    if args.update:
        refresh(args.current, args.baseline)
        refresh(args.hotpath_current, args.hotpath_baseline)
        refresh(args.latency_current, args.latency_baseline)
        return 0

    _, cur_rows = load_rows(args.current)
    _, base_rows = load_rows(args.baseline)
    current = {row_key(r): r for r in cur_rows}
    tol = args.tolerance
    failures = []
    notes = []

    # Symmetric coverage (mirrors the per-baseline-row missing check
    # below): every current row must have a baseline row, so new bench
    # cases land with an explicit — initially null — baseline entry.
    base_keys = {row_key(b) for b in base_rows}
    for k, _row in current.items():
        if k not in base_keys:
            failures.append(
                f"[{' / '.join(k)}] row missing from baseline — add it with a "
                f"null 'batched tok/s' (or run --update)"
            )

    for base in base_rows:
        k = row_key(base)
        label = " / ".join(k)
        cur = current.get(k)
        if cur is None:
            failures.append(f"[{label}] row missing from current results")
            continue

        base_tput = as_float(base.get("batched tok/s"))
        cur_tput = as_float(cur.get("batched tok/s"))
        if base_tput is None:
            notes.append(
                f"[{label}] throughput baseline not yet recorded "
                f"(current: {cur_tput}); run with --update on trusted hardware"
            )
        elif cur_tput is None:
            failures.append(f"[{label}] current throughput missing/unparseable")
        elif cur_tput < base_tput * (1.0 - tol):
            failures.append(
                f"[{label}] throughput regressed: {cur_tput:.1f} tok/s < "
                f"{base_tput:.1f} × (1 − {tol:.2f})"
            )
        else:
            notes.append(
                f"[{label}] throughput ok: {cur_tput:.1f} tok/s "
                f"(baseline {base_tput:.1f})"
            )

        base_hit = as_float(base.get("prefix hit"))
        cur_hit = as_float(cur.get("prefix hit"))
        if base_hit is not None:
            allowed = max(tol * abs(base_hit), 0.02)
            if cur_hit is None:
                failures.append(f"[{label}] current prefix hit rate missing")
            elif abs(cur_hit - base_hit) > allowed:
                failures.append(
                    f"[{label}] prefix hit rate drifted: {cur_hit} vs "
                    f"baseline {base_hit} (±{allowed:.3f})"
                )
            else:
                notes.append(
                    f"[{label}] prefix hit ok: {cur_hit} (baseline {base_hit})"
                )

    n_hotpath = 0
    if os.path.exists(args.hotpath_current) and os.path.exists(args.hotpath_baseline):
        _, hp_cur = load_rows(args.hotpath_current)
        _, hp_base = load_rows(args.hotpath_baseline)
        n_hotpath = len(hp_base)
        gate_hotpath(hp_cur, hp_base, tol, failures, notes)
    else:
        notes.append(
            f"hotpath gate skipped ({args.hotpath_current} or "
            f"{args.hotpath_baseline} absent)"
        )

    n_latency = 0
    if os.path.exists(args.latency_current) and os.path.exists(args.latency_baseline):
        _, lat_cur = load_rows(args.latency_current)
        _, lat_base = load_rows(args.latency_baseline)
        n_latency = len(lat_base)
        gate_latency(lat_cur, lat_base, tol, failures, notes)
    else:
        notes.append(
            f"latency gate skipped ({args.latency_current} or "
            f"{args.latency_baseline} absent)"
        )

    for n in notes:
        print("  " + n)
    if failures:
        print(f"\nbench regression gate FAILED ({len(failures)} problem(s)):")
        for f_ in failures:
            print("  " + f_)
        return 1
    print(
        f"\nbench regression gate passed "
        f"({len(base_rows)} serving + {n_hotpath} hotpath + "
        f"{n_latency} latency baseline rows)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
