//! Scoped-thread data parallelism substrate (no external `rayon`).
//!
//! The crate's hot loops are all "independent work per output chunk", so
//! a simple fork-join over `std::thread::scope` covers them. Work is
//! split into one contiguous span per worker; the closure receives the
//! chunk index so callers can recover absolute positions.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads (cached).
pub fn num_threads() -> usize {
    static N: AtomicUsize = AtomicUsize::new(0);
    let n = N.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
    N.store(n, Ordering::Relaxed);
    n
}

/// Parallel iteration over mutable equal-size chunks of `data`:
/// `f(chunk_index, chunk)` for each `chunk_size`-long chunk (last chunk
/// may be short). Chunks are distributed contiguously over workers.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_size: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_size > 0);
    let n_chunks = data.len().div_ceil(chunk_size);
    let workers = num_threads().min(n_chunks.max(1));
    if workers <= 1 || n_chunks <= 1 {
        for (i, c) in data.chunks_mut(chunk_size).enumerate() {
            f(i, c);
        }
        return;
    }
    // Split the chunk range evenly across workers.
    let per = n_chunks.div_ceil(workers);
    let mut spans: Vec<(usize, &mut [T])> = Vec::with_capacity(workers);
    let mut rest = data;
    let mut chunk0 = 0usize;
    while !rest.is_empty() {
        let take = (per * chunk_size).min(rest.len());
        let (head, tail) = rest.split_at_mut(take);
        spans.push((chunk0, head));
        chunk0 += per;
        rest = tail;
    }
    std::thread::scope(|s| {
        for (c0, span) in spans {
            let f = &f;
            s.spawn(move || {
                for (i, c) in span.chunks_mut(chunk_size).enumerate() {
                    f(c0 + i, c);
                }
            });
        }
    });
}

/// Parallel map over an index range: returns `f(0..n)` results in order.
pub fn par_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    par_chunks_mut(&mut out, n.div_ceil(workers), |ci, chunk| {
        let base = ci * n.div_ceil(workers);
        for (j, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(base + j));
        }
    });
    out.into_iter().map(|o| o.expect("filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything() {
        let mut v = vec![0u32; 1003];
        par_chunks_mut(&mut v, 10, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (ci * 10 + j) as u32;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
    }

    #[test]
    fn single_chunk() {
        let mut v = vec![1u8; 5];
        par_chunks_mut(&mut v, 100, |ci, c| {
            assert_eq!(ci, 0);
            for x in c {
                *x = 2;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }

    #[test]
    fn par_map_ordered() {
        let out = par_map(257, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn empty_inputs() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 4, |_, _| panic!("no chunks expected"));
        let out: Vec<u8> = par_map(0, |_| 1u8);
        assert!(out.is_empty());
    }
}
