"""L1 Pallas kernels: the SDQ decomposed dual-quantized GEMM hot spot.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper
targets GPU sparse tensor cores; on TPU the same decomposition maps to

* **BlockSpec tiling** — the HBM↔VMEM schedule that threadblock tiling
  did on GPU. Each grid step stages an activation tile and the packed
  weight tiles (codes + per-Q-vector scales) into VMEM.
* **VPU dequant + MXU matmul** — per-vector scale application and
  activation quantization fuse into the element-wise stage feeding the
  MXU `jnp.dot`, replacing the GPU's tensor-core WMMA with scale fixup.
* **Metadata decode** — the N:M unpack kernel reconstructs the dense
  tile from packed values + indices in VMEM (what the sparse TC's
  metadata decoder does in silicon), then runs the MXU on it.

All kernels run with `interpret=True`: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute. Correctness is pinned
against `ref.py` by `python/tests/test_kernel.py`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import formats

# Default tile sizes (chosen so one (bm×bk) x tile + two (bn×bk) weight
# tiles + scales fit comfortably in ~16 MiB VMEM at f32; see DESIGN.md
# §Perf for the footprint table).
BM, BN, BK = 64, 64, 128


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is ≤ pref and a multiple of 8 when
    possible (lane alignment); falls back to `dim`."""
    if dim % pref == 0:
        return pref
    for cand in (64, 32, 16, 8):
        if cand <= pref and dim % cand == 0:
            return cand
    return dim


def _act_quant_tile(x, fmt: str, qvec: int):
    """Per-Q-vector dynamic activation quantization of a VMEM tile.
    Identical math to ref.act_quant (tile-local == global because the
    K-block size is a multiple of qvec)."""
    bm, bk = x.shape
    g = x.reshape(bm, bk // qvec, qvec)
    max_abs = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = max_abs / formats.MAX_VALUE[fmt]
    q = formats.quantize(jnp.where(scale > 0, g / scale, 0.0), fmt) * scale
    q = jnp.where(max_abs > 0, q, 0.0)
    return q.reshape(bm, bk)


def _dequant_tile(codes, scales, qvec: int):
    """Apply per-Q-vector scales to a codes tile (VPU stage)."""
    bn, bk = codes.shape
    g = codes.reshape(bn, bk // qvec, qvec) * scales[..., None]
    return g.reshape(bn, bk)


def _sdq_kernel(x_ref, woc_ref, wos_ref, wic_ref, wis_ref, o_ref, *, qvec, ofmt, ifmt):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    # Outlier path: int8 activations × int8-coded weights.
    xo = _act_quant_tile(x, ofmt, qvec)
    wo = _dequant_tile(woc_ref[...], wos_ref[...], qvec)
    # Inlier path: fp4 activations × fp4-coded weights.
    xi = _act_quant_tile(x, ifmt, qvec)
    wi = _dequant_tile(wic_ref[...], wis_ref[...], qvec)
    # Two MXU passes sharing the accumulator (Fig. 8).
    acc = jnp.dot(xo, wo.T, preferred_element_type=jnp.float32)
    acc += jnp.dot(xi, wi.T, preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("qvec", "outlier_fmt", "inlier_fmt", "interpret")
)
def sdq_matmul(
    x,
    wo_codes,
    wo_scales,
    wi_codes,
    wi_scales,
    *,
    qvec: int = 16,
    outlier_fmt: str = "int8",
    inlier_fmt: str = "fp4",
    interpret: bool = True,
):
    """Decomposed dual-quantized GEMM: `Y = Q_o(X)·Wo_deqᵀ + Q_i(X)·Wi_deqᵀ`.

    `x: [t, k]`, codes `[o, k]`, scales `[o, k/qvec]` → `[t, o]`.
    """
    t, k = x.shape
    o, _ = wo_codes.shape
    bm = _pick_block(t, BM)
    bn = _pick_block(o, BN)
    bk = _pick_block(k, BK)
    assert bk % qvec == 0, f"K block {bk} must be a multiple of qvec {qvec}"
    grid = (t // bm, o // bn, k // bk)
    sq = bk // qvec
    kernel = functools.partial(
        _sdq_kernel, qvec=qvec, ofmt=outlier_fmt, ifmt=inlier_fmt
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, sq), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, sq), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, o), jnp.float32),
        interpret=interpret,
    )(x, wo_codes, wo_scales, wi_codes, wi_scales)


def _dual_kernel(x_ref, wc_ref, ws_ref, o_ref, *, qvec, fmt):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = _act_quant_tile(x_ref[...], fmt, qvec)
    w = _dequant_tile(wc_ref[...], ws_ref[...], qvec)
    o_ref[...] += jnp.dot(xq, w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("qvec", "fmt", "interpret"))
def dual_quant_matmul(x, w_codes, w_scales, *, qvec: int = 16, fmt: str = "int8",
                      interpret: bool = True):
    """Single-path dual-quantized GEMM (the Q-VSQuant-WA baseline)."""
    t, k = x.shape
    o, _ = w_codes.shape
    bm, bn, bk = _pick_block(t, BM), _pick_block(o, BN), _pick_block(k, BK)
    assert bk % qvec == 0
    grid = (t // bm, o // bn, k // bk)
    kernel = functools.partial(_dual_kernel, qvec=qvec, fmt=fmt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // qvec), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, o), jnp.float32),
        interpret=interpret,
    )(x, w_codes, w_scales)


def _unpack_kernel(vals_ref, idx_ref, x_ref, o_ref, *, m, n):
    """Metadata-decode + MXU: reconstruct the dense (bn, bk) weight tile
    from packed (bn, bk//m*n) values + intra-block indices, then matmul."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = vals_ref[...]
    idx = idx_ref[...]
    bn, slots = vals.shape
    blocks = slots // n
    bk = blocks * m
    # Absolute column of each slot within the tile.
    block_of_slot = jnp.arange(slots) // n
    cols = block_of_slot[None, :] * m + idx
    # Scatter-add into the dense tile (zero-padded slots carry value 0 and
    # index 0 — a harmless duplicate write of +0).
    w = jnp.zeros((bn, bk), jnp.float32)
    rows = jnp.broadcast_to(jnp.arange(bn)[:, None], (bn, slots))
    w = w.at[rows, cols].add(vals)
    o_ref[...] += jnp.dot(x_ref[...], w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n", "m", "k", "interpret"))
def nm_spmm(packed_vals, packed_idx, x, *, n: int, m: int, k: int,
            interpret: bool = True):
    """Packed N:M structured SpMM: `Y = X · unpack(vals, idx)ᵀ`.

    `packed_vals/idx: [o, k//m*n]` (ELLPACK layout from the Rust packer),
    `x: [t, k]` → `[t, o]`.
    """
    t, _ = x.shape
    o, slots = packed_vals.shape
    assert slots == k // m * n
    bm = _pick_block(t, BM)
    bn = _pick_block(o, BN)
    bk = _pick_block(k, BK)
    assert bk % m == 0
    bslots = bk // m * n
    grid = (t // bm, o // bn, k // bk)
    kernel = functools.partial(_unpack_kernel, m=m, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bslots), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bslots), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, o), jnp.float32),
        interpret=interpret,
    )(packed_vals, packed_idx, x)


def _quant_kernel(x_ref, o_ref, *, qvec, fmt):
    o_ref[...] = _act_quant_tile(x_ref[...], fmt, qvec)


@functools.partial(jax.jit, static_argnames=("qvec", "fmt", "interpret"))
def act_quantize(x, *, qvec: int = 16, fmt: str = "int8", interpret: bool = True):
    """Fused dynamic activation quantize-dequantize kernel."""
    t, k = x.shape
    bm = _pick_block(t, BM)
    bk = _pick_block(k, BK)
    assert bk % qvec == 0
    kernel = functools.partial(_quant_kernel, qvec=qvec, fmt=fmt)
    return pl.pallas_call(
        kernel,
        grid=(t // bm, k // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, k), jnp.float32),
        interpret=interpret,
    )(x)


def pack_nm(w, n: int, m: int):
    """Pack an N:M-sparse weight matrix into ELLPACK (vals, idx) — the
    python mirror of `rust/src/sdq/packed.rs` (build-time only)."""
    import numpy as np

    w = np.asarray(w)
    o, k = w.shape
    assert k % m == 0
    blocks = k // m
    vals = np.zeros((o, blocks * n), np.float32)
    idx = np.zeros((o, blocks * n), np.int32)
    for r in range(o):
        for b in range(blocks):
            blk = w[r, b * m : (b + 1) * m]
            nz = np.nonzero(blk)[0]
            assert len(nz) <= n, f"row {r} block {b} violates {n}:{m}"
            for s, c in enumerate(nz):
                vals[r, b * n + s] = blk[c]
                idx[r, b * n + s] = c
    return jnp.asarray(vals), jnp.asarray(idx)
