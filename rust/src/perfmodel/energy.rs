//! Energy model (extension): pJ-per-token estimates per configuration.
//!
//! The paper motivates low-bit arithmetic by area/power efficiency
//! (§2.3, citing Horowitz ISSCC'14). This module turns that argument
//! into numbers: per-MAC energy from the Horowitz 45 nm table (scaled
//! for narrow integer/minifloat datapaths), plus DRAM weight-fetch
//! energy from the §3.3 bits-per-weight accounting. Used by the
//! `compress_sweep` example and the ablation discussion in DESIGN.md.

use crate::formats::NumFormat;
use crate::perfmodel::bits_per_weight;
use crate::sdq::config::{CompressionConfig, Stages};

/// Energy cost table in picojoules (45 nm, Horowitz ISSCC'14 anchors;
/// narrow widths extrapolated quadratically for multipliers).
#[derive(Clone, Copy, Debug)]
pub struct EnergySpec {
    /// fp32 accumulate (add) energy (fp16 operand paths).
    pub acc_fp32_pj: f64,
    /// fp16 accumulate energy (low-bit minifloat tensor-core paths).
    pub acc_fp16_pj: f64,
    /// int32 accumulate energy (integer datapaths).
    pub acc_int32_pj: f64,
    /// DRAM fetch energy per bit.
    pub dram_pj_per_bit: f64,
}

impl Default for EnergySpec {
    fn default() -> Self {
        // 0.9 pJ fp32 add, 0.1 pJ int32 add, 640 pJ / 64-bit DRAM access.
        EnergySpec { acc_fp32_pj: 0.9, acc_fp16_pj: 0.4, acc_int32_pj: 0.1, dram_pj_per_bit: 10.0 }
    }
}

impl EnergySpec {
    /// Multiplier energy for a format (pJ). Anchors: fp16 1.1, fp32 3.7,
    /// int8 0.2, int32 3.1; integer/minifloat mult energy scales roughly
    /// quadratically with mantissa-path width.
    pub fn mult_pj(&self, fmt: NumFormat) -> f64 {
        match fmt {
            NumFormat::Fp32 => 3.7,
            NumFormat::Fp16 => 1.1,
            NumFormat::Fp8E4M3 | NumFormat::Fp8E5M2 | NumFormat::UFp8E6M2 => 0.30,
            NumFormat::Fp4E2M1 => 0.10,
            NumFormat::Int(b) => 0.2 * (b as f64 / 8.0).powi(2),
        }
    }

    /// Accumulator energy paired with a multiply at this format: integer
    /// paths accumulate int32, minifloat tensor-core paths fp16, and
    /// fp16/fp32 operands accumulate fp32.
    pub fn acc_pj(&self, fmt: NumFormat) -> f64 {
        match fmt {
            NumFormat::Int(_) => self.acc_int32_pj,
            NumFormat::Fp4E2M1 | NumFormat::Fp8E4M3 | NumFormat::Fp8E5M2
            | NumFormat::UFp8E6M2 => self.acc_fp16_pj,
            NumFormat::Fp16 | NumFormat::Fp32 => self.acc_fp32_pj,
        }
    }

    /// MAC energy (mult + accumulate).
    pub fn mac_pj(&self, fmt: NumFormat) -> f64 {
        self.mult_pj(fmt) + self.acc_pj(fmt)
    }
}

/// Per-token energy decomposition for one configuration over a model's
/// linear layers.
#[derive(Clone, Copy, Debug)]
pub struct EnergyEstimate {
    /// Compute energy (executed MACs only — sparse HW skips the rest).
    pub compute_pj: f64,
    /// DRAM weight-fetch energy (bits-per-weight × params).
    pub memory_pj: f64,
}

impl EnergyEstimate {
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.memory_pj
    }
}

/// Estimate energy per generated token for a model with `params` total
/// linear-layer parameters under `cfg` (one MAC per parameter per token).
pub fn energy_per_token(spec: &EnergySpec, cfg: &CompressionConfig, params: f64) -> EnergyEstimate {
    let compute_pj = match &cfg.stages {
        Stages::Dense => params * spec.mac_pj(NumFormat::Fp16),
        Stages::SparsifyOnly(sp) => params * sp.pattern.density() * spec.mac_pj(NumFormat::Fp16),
        Stages::QuantOnly { weight_fmt, act_fmt, .. } => {
            let fmt = match act_fmt {
                Some(a) if a.bits() >= weight_fmt.bits() => *a,
                Some(_) => *weight_fmt,
                None => NumFormat::Fp16, // weight-only: fp16 compute
            };
            params * spec.mac_pj(fmt)
        }
        Stages::Sdq { decompose, .. } => {
            let o = decompose.outlier_pattern.density() * spec.mac_pj(decompose.outlier_fmt);
            let i = decompose.inlier_pattern.density() * spec.mac_pj(decompose.inlier_fmt);
            params * (o + i)
        }
    };
    let memory_pj = params * bits_per_weight(cfg) * spec.dram_pj_per_bit;
    EnergyEstimate { compute_pj, memory_pj }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(cfg: &str) -> EnergyEstimate {
        let cfg: CompressionConfig = cfg.parse().unwrap();
        energy_per_token(&EnergySpec::default(), &cfg, 1e6)
    }

    #[test]
    fn orderings_follow_the_paper() {
        let dense = e("Dense-WA16");
        let int8 = e("Q-VSQuant-WAint8");
        let sdq = e("SDQ-W7:8-1:8int8-6:8fp4");
        // Both low-bit paths cut compute + memory far below dense fp16.
        // (SDQ's advantage over int8-dual is *throughput* at equal
        // quality, not per-MAC energy — the paper's §3 framing.)
        assert!(int8.compute_pj < 0.5 * dense.compute_pj);
        assert!(sdq.compute_pj < 0.33 * dense.compute_pj, "{} vs {}", sdq.compute_pj, dense.compute_pj);
        assert!(int8.memory_pj < dense.memory_pj);
        assert!(sdq.memory_pj < dense.memory_pj);
        assert!(sdq.total_pj() < 0.5 * dense.total_pj());
    }

    #[test]
    fn weight_only_saves_memory_not_compute() {
        let dense = e("Dense-WA16");
        let w4 = e("Q-VSQuant-Wfp4");
        assert!((w4.compute_pj - dense.compute_pj).abs() < 1e-9);
        assert!(w4.memory_pj < 0.4 * dense.memory_pj);
    }

    #[test]
    fn mult_energy_monotone_in_width() {
        let s = EnergySpec::default();
        assert!(s.mult_pj(NumFormat::Int(4)) < s.mult_pj(NumFormat::Int(8)));
        assert!(s.mult_pj(NumFormat::Int(8)) < s.mult_pj(NumFormat::Fp16));
        assert!(s.mult_pj(NumFormat::Fp4E2M1) < s.mult_pj(NumFormat::Fp8E4M3));
    }
}
