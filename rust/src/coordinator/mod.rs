//! L3 serving coordinator.
//!
//! A vLLM-router-style inference front end over the compressed model:
//! request queue → admission → continuous-batching scheduler → per-token
//! decode rounds → responses with latency metrics. Python is never on
//! this path; the model weights come from `artifacts/` and the compute
//! is either the native Rust engine ([`crate::model`]) or the AOT
//! PJRT executable ([`crate::runtime`]).
//!
//! * [`request`] — request/response types.
//! * [`batcher`] — admission queue and batch formation policy.
//! * [`scheduler`] — the continuous-batching decode loop.
//! * [`metrics`] — counters + latency histograms.
//! * [`engine`] — ties them together behind a thread-safe handle.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;

pub use engine::Engine;
pub use request::{Request, Response};
