//! Serving metrics: counters and latency histograms.

use std::time::Duration;

/// Fixed-bucket latency histogram (log-spaced, µs to minutes).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Bucket upper bounds in µs.
    bounds: Vec<u64>,
    counts: Vec<u64>,
    sum_us: u64,
    n: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        // 1µs … ~134s in ×2 steps
        let bounds: Vec<u64> = (0..28).map(|i| 1u64 << i).collect();
        let len = bounds.len();
        Histogram { bounds, counts: vec![0; len + 1], sum_us: 0, n: 0 }
    }
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self.bounds.partition_point(|b| *b < us);
        self.counts[idx] += 1;
        self.sum_us += us;
        self.n += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> Duration {
        if self.n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.n)
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.n == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                let us = if i < self.bounds.len() { self.bounds[i] } else { u64::MAX / 2 };
                return Duration::from_micros(us);
            }
        }
        Duration::from_micros(*self.bounds.last().unwrap())
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub decode_rounds: u64,
    pub ttft: Histogram,
    pub total_latency: Histogram,
    /// Wall time the engine spent serving (for throughput).
    pub serve_time: Duration,
}

impl Metrics {
    /// End-to-end generation throughput.
    pub fn tokens_per_second(&self) -> f64 {
        if self.serve_time.is_zero() {
            return f64::NAN;
        }
        self.tokens_generated as f64 / self.serve_time.as_secs_f64()
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} tokens={} tput={:.1} tok/s ttft_mean={:.1}ms ttft_p99={:.1}ms \
             total_mean={:.1}ms",
            self.requests_completed,
            self.tokens_generated,
            self.tokens_per_second(),
            self.ttft.mean().as_secs_f64() * 1e3,
            self.ttft.quantile(0.99).as_secs_f64() * 1e3,
            self.total_latency.mean().as_secs_f64() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let mut h = Histogram::default();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(10));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
    }

    #[test]
    fn throughput_math() {
        let mut m = Metrics::default();
        m.tokens_generated = 100;
        m.serve_time = Duration::from_secs(2);
        assert!((m.tokens_per_second() - 50.0).abs() < 1e-9);
        assert!(m.summary().contains("tokens=100"));
    }
}
