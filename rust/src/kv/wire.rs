//! Versioned wire/disk format for [`Snapshot`] — the serialization
//! layer the spill tier ([`crate::swap`]) and the cross-engine
//! migration path ([`crate::router`]) share.
//!
//! A suspended sequence's KV state already lives in plain owned byte
//! buffers (codes + per-block-per-layer scales + purity taint, see
//! [`Snapshot`]); this module turns it into a self-describing byte
//! stream and back **byte-exactly**, so [`BlockPool::resume`] after
//! [`decode`] is bit-identical to resuming the in-memory snapshot —
//! the property every migrated or spilled sequence's bit-identity
//! rests on.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic "SDQW" · version u16 · dtype u8 · flags u8
//! n_layer u32 · block_tokens u32 · d u32          (block geometry)
//! len u64 · max_tokens u64 · owned_from u64
//! token history (u64 count + bytes)
//! store count u64, then per owned block:
//!   taint u8
//!   f32:  K slab · V slab              (verbatim f32 LE)
//!   quantized: K codes · V codes       (raw, or RLE-framed if flags&1;
//!              int4 slabs are the packed nibble bytes)
//!              K amax · V amax         (one f32 per layer, verbatim)
//!   int4 only, per side then per layer: outlier side-table
//!              (u16 count · per entry: row u16 · d exact f32s)
//! checksum u64 (FNV-1a over everything above)
//! ```
//!
//! The optional codec (flag bit 0) is a byte-oriented run-length code
//! applied to the **quantized code slabs only** — they are the bulk of
//! the bytes and entropy-friendly (unwritten tail rows are runs of
//! zero codes; Double Compression, arXiv 2502.15443, motivates going
//! further). Each slab is framed with a method byte so RLE is only
//! kept when it actually shrinks the slab; scales and f32 rows pass
//! through verbatim. Decoding rejects a bad magic, an unknown version,
//! and a checksum mismatch with distinct errors, and validates every
//! structural invariant (`tokens.len() == len`, store count vs.
//! geometry, f32-never-tainted) before a [`Snapshot`] is rebuilt.
//!
//! The module also provides [`prompt_digests`]: the chained FNV-1a
//! digests of a token stream at each block boundary, the portable
//! content address [`BlockPool::prefix_digests`] exposes for
//! prefix-aware routing (pool-local `BlockKey`s embed slot ids and
//! generations, so they cannot leave the process).

use anyhow::{bail, ensure};

use super::pool::{BlockPool, Snapshot};
use super::store::{outlier_cap, KvDtype, KvStore};

/// Format magic: "SDQ wire".
pub const MAGIC: [u8; 4] = *b"SDQW";
/// Current (and only) format version.
pub const VERSION: u16 = 1;

/// FNV-1a 64 offset basis — the seed for [`fnv1a`] digest chains.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `bytes` into a running FNV-1a 64 digest. Byte-sequential, so
/// folding block-by-block equals hashing the concatenated stream —
/// what makes per-block prefix digests composable.
pub(crate) fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Digest of each block-aligned prefix of `tokens`: entry `i` is the
/// FNV-1a digest of `tokens[..(i + 1) * block_tokens]`. Matching a
/// prompt's digests against [`BlockPool::prefix_digests`] counts how
/// many leading blocks a replica already holds.
pub fn prompt_digests(tokens: &[u8], block_tokens: usize) -> Vec<u64> {
    let full = tokens.len() / block_tokens;
    let mut out = Vec::with_capacity(full);
    let mut h = FNV_OFFSET;
    for bi in 0..full {
        h = fnv1a(h, &tokens[bi * block_tokens..(bi + 1) * block_tokens]);
        out.push(h);
    }
    out
}

/// Geometry and codec accounting recovered from a wire header — the
/// caller validates it against the receiving pool
/// ([`BlockPool::snapshot_from_wire`]) and feeds the byte counts into
/// the codec-ratio metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireInfo {
    pub dtype: KvDtype,
    pub n_layer: usize,
    pub block_tokens: usize,
    pub d: usize,
    /// Quantized code-slab bytes before the codec (0 for f32 streams).
    pub raw_slab_bytes: u64,
    /// The same slabs as stored on the wire.
    pub encoded_slab_bytes: u64,
}

fn dtype_tag(d: KvDtype) -> u8 {
    match d {
        KvDtype::F32 => 0,
        KvDtype::Fp8E4M3 => 1,
        KvDtype::Int8 => 2,
        KvDtype::Int4Outlier => 3,
    }
}

fn dtype_from_tag(t: u8) -> anyhow::Result<KvDtype> {
    match t {
        0 => Ok(KvDtype::F32),
        1 => Ok(KvDtype::Fp8E4M3),
        2 => Ok(KvDtype::Int8),
        3 => Ok(KvDtype::Int4Outlier),
        _ => bail!("unknown kv dtype tag {t}"),
    }
}

// ---- primitive writers / reader ----

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "wire stream truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> anyhow::Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

// ---- RLE codec (quantized code slabs) ----

/// Byte RLE: (run u8 ∈ 1..=255, value u8) pairs. Worst case 2×, which
/// the per-slab method byte below guards against.
fn rle_encode(bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let v = bytes[i];
        let mut run = 1usize;
        while run < 255 && i + run < bytes.len() && bytes[i + run] == v {
            run += 1;
        }
        out.push(run as u8);
        out.push(v);
        i += run;
    }
    out
}

fn rle_decode(enc: &[u8], expect_len: usize) -> anyhow::Result<Vec<u8>> {
    ensure!(enc.len() % 2 == 0, "RLE slab has a dangling half-pair");
    let mut out = Vec::with_capacity(expect_len);
    for pair in enc.chunks_exact(2) {
        let (run, v) = (pair[0] as usize, pair[1]);
        ensure!(run > 0, "RLE run of zero");
        ensure!(out.len() + run <= expect_len, "RLE slab overruns its block");
        out.resize(out.len() + run, v);
    }
    ensure!(out.len() == expect_len, "RLE slab underruns its block");
    Ok(out)
}

const SLAB_RAW: u8 = 0;
const SLAB_RLE: u8 = 1;

/// Write one quantized code slab with method framing, keeping the RLE
/// form only when it is strictly smaller. Returns the framed payload
/// size (for the codec-ratio counters).
fn put_code_slab(out: &mut Vec<u8>, slab: &[u8], codec: bool) -> u64 {
    if !codec {
        out.extend_from_slice(slab);
        return slab.len() as u64;
    }
    let rle = rle_encode(slab);
    if rle.len() < slab.len() {
        out.push(SLAB_RLE);
        put_u64(out, rle.len() as u64);
        let n = rle.len() as u64;
        out.extend_from_slice(&rle);
        1 + 8 + n
    } else {
        out.push(SLAB_RAW);
        put_u64(out, slab.len() as u64);
        out.extend_from_slice(slab);
        1 + 8 + slab.len() as u64
    }
}

fn read_code_slab(r: &mut Reader<'_>, elems: usize, codec: bool) -> anyhow::Result<Vec<u8>> {
    if !codec {
        return Ok(r.take(elems)?.to_vec());
    }
    let method = r.u8()?;
    let n = r.u64()? as usize;
    let payload = r.take(n)?;
    match method {
        SLAB_RAW => {
            ensure!(n == elems, "raw slab length {n} != {elems}");
            Ok(payload.to_vec())
        }
        SLAB_RLE => rle_decode(payload, elems),
        m => bail!("unknown slab method {m}"),
    }
}

// ---- encode / decode ----

/// Serialize `snap` under the given block geometry. Callers normally go
/// through [`BlockPool::snapshot_to_wire`], which supplies the pool's
/// own geometry.
pub fn encode(
    snap: &Snapshot,
    n_layer: usize,
    block_tokens: usize,
    d: usize,
    codec: bool,
) -> Vec<u8> {
    encode_ex(snap, n_layer, block_tokens, d, codec).0
}

/// [`encode`] plus the codec accounting: (raw quantized-slab bytes,
/// framed bytes as stored) — the `codec_raw_bytes` /
/// `codec_encoded_bytes` metrics the spill tier reports.
pub fn encode_ex(
    snap: &Snapshot,
    n_layer: usize,
    block_tokens: usize,
    d: usize,
    codec: bool,
) -> (Vec<u8>, u64, u64) {
    let mut out = Vec::with_capacity(64 + snap.bytes);
    out.extend_from_slice(&MAGIC);
    put_u16(&mut out, VERSION);
    out.push(dtype_tag(snap.dtype));
    out.push(if codec { 1 } else { 0 });
    put_u32(&mut out, n_layer as u32);
    put_u32(&mut out, block_tokens as u32);
    put_u32(&mut out, d as u32);
    put_u64(&mut out, snap.len as u64);
    put_u64(&mut out, snap.max_tokens as u64);
    put_u64(&mut out, snap.owned_from as u64);
    put_u64(&mut out, snap.tokens.len() as u64);
    out.extend_from_slice(&snap.tokens);
    put_u64(&mut out, snap.stores.len() as u64);
    let (mut raw, mut enc) = (0u64, 0u64);
    for (store, tainted) in &snap.stores {
        out.push(*tainted as u8);
        match store {
            KvStore::F32 { k, v } => {
                put_f32s(&mut out, k);
                put_f32s(&mut out, v);
            }
            KvStore::Q8 { k, v, k_amax, v_amax, .. } => {
                raw += (k.len() + v.len()) as u64;
                enc += put_code_slab(&mut out, k, codec);
                enc += put_code_slab(&mut out, v, codec);
                put_f32s(&mut out, k_amax);
                put_f32s(&mut out, v_amax);
            }
            KvStore::Q4 { k, v, k_amax, v_amax, k_out, v_out } => {
                raw += (k.len() + v.len()) as u64;
                enc += put_code_slab(&mut out, k, codec);
                enc += put_code_slab(&mut out, v, codec);
                put_f32s(&mut out, k_amax);
                put_f32s(&mut out, v_amax);
                // Outlier side-tables ride behind the slabs verbatim:
                // tiny (bounded by `outlier_cap` per slab) and exact
                // f32, so no codec framing.
                for table in k_out.iter().chain(v_out.iter()) {
                    put_u16(&mut out, table.len() as u16);
                    for (row, vals) in table {
                        put_u16(&mut out, *row);
                        put_f32s(&mut out, vals);
                    }
                }
            }
        }
    }
    let sum = fnv1a(FNV_OFFSET, &out);
    put_u64(&mut out, sum);
    (out, raw, enc)
}

/// Decode a wire stream back into a [`Snapshot`] plus the geometry it
/// was captured under. Magic and version are checked first (so a
/// version bump reports as such, not as corruption), then the trailing
/// checksum over everything before it, then every structural
/// invariant.
pub fn decode(bytes: &[u8]) -> anyhow::Result<(Snapshot, WireInfo)> {
    ensure!(bytes.len() >= MAGIC.len() + 2 + 8, "wire stream shorter than header");
    ensure!(bytes[..4] == MAGIC, "bad magic: not an SDQW snapshot stream");
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    ensure!(version == VERSION, "unsupported wire version {version} (expected {VERSION})");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().unwrap());
    let got = fnv1a(FNV_OFFSET, body);
    ensure!(got == want, "wire checksum mismatch (corrupt stream)");

    let mut r = Reader { buf: body, pos: 6 };
    let dtype = dtype_from_tag(r.u8()?)?;
    let flags = r.u8()?;
    ensure!(flags <= 1, "unknown wire flags {flags:#x}");
    let codec = flags & 1 != 0;
    let n_layer = r.u32()? as usize;
    let block_tokens = r.u32()? as usize;
    let d = r.u32()? as usize;
    ensure!(n_layer > 0 && block_tokens > 0 && d > 0, "degenerate block geometry");
    let len = r.u64()? as usize;
    let max_tokens = r.u64()? as usize;
    let owned_from = r.u64()? as usize;
    ensure!(len <= max_tokens, "len {len} exceeds table capacity {max_tokens}");
    let n_tokens = r.u64()? as usize;
    ensure!(n_tokens == len, "token history length {n_tokens} != len {len}");
    let tokens = r.take(n_tokens)?.to_vec();

    let blocks = len.div_ceil(block_tokens);
    if dtype == KvDtype::F32 {
        ensure!(owned_from == len / block_tokens, "f32 snapshot must own exactly the tail");
    } else {
        ensure!(owned_from == 0, "quantized snapshot must own every block");
    }
    let n_stores = r.u64()? as usize;
    ensure!(n_stores == blocks - owned_from, "store count {n_stores} != {}", blocks - owned_from);

    let elems = n_layer * block_tokens * d;
    let (mut raw, mut enc) = (0u64, 0u64);
    let mut stores = Vec::with_capacity(n_stores);
    for _ in 0..n_stores {
        let taint = match r.u8()? {
            0 => false,
            1 => true,
            t => bail!("bad taint byte {t}"),
        };
        let store = if dtype == KvDtype::F32 {
            ensure!(!taint, "f32 blocks are never tainted");
            KvStore::F32 { k: r.f32s(elems)?, v: r.f32s(elems)? }
        } else if dtype == KvDtype::Int4Outlier {
            // Packed nibble slabs: the framed unit is the byte count,
            // not the element count.
            let slab_bytes = n_layer * block_tokens * d.div_ceil(2);
            let before = r.pos;
            let k = read_code_slab(&mut r, slab_bytes, codec)?;
            let v = read_code_slab(&mut r, slab_bytes, codec)?;
            raw += 2 * slab_bytes as u64;
            enc += (r.pos - before) as u64;
            let k_amax = r.f32s(n_layer)?;
            let v_amax = r.f32s(n_layer)?;
            let cap = outlier_cap(block_tokens);
            let mut read_tables = |r: &mut Reader<'_>| -> anyhow::Result<Vec<Vec<(u16, Vec<f32>)>>> {
                let mut sides = Vec::with_capacity(n_layer);
                for _ in 0..n_layer {
                    let n = r.u16()? as usize;
                    ensure!(n <= cap, "outlier table of {n} exceeds cap {cap}");
                    let mut table = Vec::with_capacity(n);
                    let mut prev: Option<u16> = None;
                    for _ in 0..n {
                        let row = r.u16()?;
                        ensure!((row as usize) < block_tokens, "outlier row {row} out of block");
                        ensure!(prev.is_none_or(|p| p < row), "outlier rows must be sorted");
                        prev = Some(row);
                        table.push((row, r.f32s(d)?));
                    }
                    sides.push(table);
                }
                Ok(sides)
            };
            let k_out = read_tables(&mut r)?;
            let v_out = read_tables(&mut r)?;
            KvStore::Q4 { k, v, k_amax, v_amax, k_out, v_out }
        } else {
            let before = r.pos;
            let k = read_code_slab(&mut r, elems, codec)?;
            let v = read_code_slab(&mut r, elems, codec)?;
            raw += 2 * elems as u64;
            enc += (r.pos - before) as u64;
            KvStore::Q8 { dtype, k, v, k_amax: r.f32s(n_layer)?, v_amax: r.f32s(n_layer)? }
        };
        stores.push((store, taint));
    }
    ensure!(r.pos == body.len(), "trailing bytes after snapshot payload");

    let bytes_held = stores.len() * BlockPool::block_bytes_for(n_layer, block_tokens, d, dtype);
    let snap = Snapshot {
        dtype,
        len,
        max_tokens,
        tokens,
        owned_from,
        stores,
        bytes: bytes_held,
    };
    let info = WireInfo {
        dtype,
        n_layer,
        block_tokens,
        d,
        raw_slab_bytes: raw,
        encoded_slab_bytes: enc,
    };
    Ok((snap, info))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::table::BlockTable;
    use crate::model::{Arch, ModelConfig};
    use crate::util::rng::Rng;

    const ALL_DTYPES: [KvDtype; 4] =
        [KvDtype::F32, KvDtype::Fp8E4M3, KvDtype::Int8, KvDtype::Int4Outlier];

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "wire-test".into(),
            arch: Arch::Gpt,
            d_model: 8,
            n_layer: 2,
            n_head: 2,
            d_ff: 16,
            vocab: 256,
            max_seq: 64,
            eps: 1e-5,
            rope_theta: 10000.0,
            kv_dtype: KvDtype::F32,
        }
    }

    fn pool_dt(budget: usize, dtype: KvDtype) -> BlockPool {
        let c = cfg();
        let bb = BlockPool::block_bytes_for(c.n_layer, 4, c.d_model, dtype);
        BlockPool::with_params(&c, budget * bb, 4, dtype)
    }

    /// Feed `toks` through a table the way the model does (prepare /
    /// write_row / commit), with per-position row values that exercise
    /// amax growth on quantized stores.
    fn run_tokens(p: &mut BlockPool, t: &mut BlockTable, toks: &[u8]) {
        p.prepare_tokens(t, toks.len());
        for (j, tok) in toks.iter().enumerate() {
            let pos = t.len() + j;
            for li in 0..2 {
                let val = *tok as f32 * 0.37 + li as f32 * 0.5;
                let row = vec![val; 8];
                let vrow = vec![-val; 8];
                p.write_row(t, li, pos, &row, &vrow);
            }
        }
        p.commit(t, toks);
    }

    fn round_trip(pool: &BlockPool, snap: &Snapshot, codec: bool) -> Snapshot {
        let wire = pool.snapshot_to_wire(snap, codec);
        let back = pool.snapshot_from_wire(&wire).expect("decode");
        assert_eq!(&back, snap, "wire round-trip must be byte-exact (codec={codec})");
        back
    }

    #[test]
    fn round_trip_plain_and_partial_tail() {
        for dtype in ALL_DTYPES {
            for n in [4usize, 8, 11] {
                // block-aligned and mid-block tails
                let toks: Vec<u8> = (10..10 + n as u8).collect();
                let mut p = pool_dt(16, dtype);
                let mut t = BlockTable::new(64);
                run_tokens(&mut p, &mut t, &toks);
                let snap = p.suspend(t);
                for codec in [false, true] {
                    let back = round_trip(&p, &snap, codec);
                    // Resuming the decoded snapshot on a fresh pool is
                    // bit-identical to resuming the original.
                    let mut pa = pool_dt(16, dtype);
                    let mut pb = pool_dt(16, dtype);
                    let (ta, ra) = pa.resume(&snap);
                    let (tb, rb) = pb.resume(&back);
                    assert_eq!(ra, rb, "{dtype:?}/{n}: resume ready count diverged");
                    assert_eq!(ta.tokens(), tb.tokens());
                    pa.assert_consistent();
                    pb.assert_consistent();
                    pa.release(ta);
                    pb.release(tb);
                }
            }
        }
    }

    #[test]
    fn round_trip_tainted_mid_block_truncation() {
        // Quantized mid-block truncate taints the tail slab; the taint
        // must survive the wire so a resumed block stays out of the
        // dedup index.
        for dtype in [KvDtype::Fp8E4M3, KvDtype::Int8, KvDtype::Int4Outlier] {
            let mut p = pool_dt(16, dtype);
            let mut t = BlockTable::new(64);
            run_tokens(&mut p, &mut t, &(20..31).collect::<Vec<u8>>()); // 11 tokens
            p.truncate(&mut t, 6); // mid-block cut → tainted tail
            let snap = p.suspend(t);
            assert!(snap.stores.iter().any(|(_, taint)| *taint), "{dtype:?}: expected a taint");
            for codec in [false, true] {
                round_trip(&p, &snap, codec);
            }
        }
    }

    #[test]
    fn round_trip_cow_forked_snapshot() {
        for dtype in ALL_DTYPES {
            let mut p = pool_dt(32, dtype);
            let mut a = BlockTable::new(64);
            run_tokens(&mut p, &mut a, &(40..50).collect::<Vec<u8>>());
            let mut b = p.fork(&a);
            // Diverge the fork (copy-on-write on the shared tail).
            run_tokens(&mut p, &mut b, &[91, 92, 93]);
            let snap = p.suspend(b);
            for codec in [false, true] {
                round_trip(&p, &snap, codec);
            }
            p.release(a);
        }
    }

    #[test]
    fn randomized_round_trip_across_shapes() {
        let mut rng = Rng::seed_from_u64(0x5d9_1ce);
        for _ in 0..60 {
            let dtype = ALL_DTYPES[rng.below(4)];
            let mut p = pool_dt(32, dtype);
            let mut t = BlockTable::new(64);
            let n = 1 + rng.below(20);
            let toks: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            run_tokens(&mut p, &mut t, &toks);
            // Random mid-flight truncation (possibly mid-block → taint
            // on quantized), keeping at least one token.
            if rng.bool(0.5) && t.len() > 2 {
                let cut = 1 + rng.below(t.len() - 1);
                p.truncate(&mut t, cut);
            }
            let t = if rng.bool(0.3) {
                let fork = p.fork(&t);
                p.release(t);
                fork
            } else {
                t
            };
            let snap = p.suspend(t);
            let codec = rng.bool(0.5);
            round_trip(&p, &snap, codec);
        }
    }

    #[test]
    fn round_trip_int4_with_populated_outlier_tables() {
        // One spiked row per block forces a side-table entry (bt=4 →
        // cap 1); the table must survive the wire byte-exactly under
        // both framings.
        let mut p = pool_dt(16, KvDtype::Int4Outlier);
        let mut t = BlockTable::new(64);
        let toks: Vec<u8> = (30..41).collect(); // 11 tokens, mid-block tail
        p.prepare_tokens(&mut t, toks.len());
        for (j, tok) in toks.iter().enumerate() {
            for li in 0..2 {
                // Every 4th position spikes 60× over the running amax,
                // tripping the outlier residual test on the old grid.
                let base = *tok as f32 * 0.11 + 0.3;
                let val = if j % 4 == 2 { base * 60.0 } else { base };
                p.write_row(&mut t, li, j, &vec![val; 8], &vec![-val; 8]);
            }
        }
        p.commit(&mut t, &toks);
        let snap = p.suspend(t);
        let has_outliers = snap.stores.iter().any(|(s, _)| match s {
            KvStore::Q4 { k_out, v_out, .. } => {
                k_out.iter().chain(v_out.iter()).any(|t| !t.is_empty())
            }
            _ => false,
        });
        assert!(has_outliers, "spiked rows failed to populate a side-table");
        for codec in [false, true] {
            round_trip(&p, &snap, codec);
        }
    }

    #[test]
    fn codec_shrinks_sparse_slabs_and_reports_sizes() {
        // A mostly-empty quantized block (1 token written, 3 rows of
        // zero codes per slab) is RLE-friendly; the framed size must
        // shrink and the decode side must report matching accounting.
        let mut p = pool_dt(8, KvDtype::Int8);
        let mut t = BlockTable::new(64);
        run_tokens(&mut p, &mut t, &[7]);
        let snap = p.suspend(t);
        let (wire, raw, enc) = {
            let plain = p.snapshot_to_wire(&snap, false);
            let (wire, raw, enc) = super::encode_ex(&snap, 2, 4, 8, true);
            assert!(wire.len() < plain.len(), "codec failed to shrink a sparse slab");
            (wire, raw, enc)
        };
        assert!(enc < raw, "framed bytes {enc} not below raw {raw}");
        let (back, info) = decode(&wire).expect("decode");
        assert_eq!(back, snap);
        assert_eq!(info.raw_slab_bytes, raw);
        assert_eq!(info.encoded_slab_bytes, enc);
        assert_eq!(info.dtype, KvDtype::Int8);
    }

    #[test]
    fn corrupted_checksum_rejected() {
        let mut p = pool_dt(8, KvDtype::Int8);
        let mut t = BlockTable::new(64);
        run_tokens(&mut p, &mut t, &[1, 2, 3, 4, 5]);
        let snap = p.suspend(t);
        let wire = p.snapshot_to_wire(&snap, true);
        // Flip one payload byte (past the header, before the checksum).
        let mut bad = wire.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        let err = decode(&bad).expect_err("corrupt stream must not decode");
        assert!(err.to_string().contains("checksum"), "unexpected error: {err}");
        // Truncation is also caught (the checksum covers length).
        let err = decode(&wire[..wire.len() - 3]).expect_err("truncated stream must not decode");
        assert!(err.to_string().contains("checksum"), "unexpected error: {err}");
    }

    #[test]
    fn wrong_version_and_magic_rejected() {
        let mut p = pool_dt(8, KvDtype::F32);
        let mut t = BlockTable::new(64);
        run_tokens(&mut p, &mut t, &[9, 9, 9]);
        let snap = p.suspend(t);
        let wire = p.snapshot_to_wire(&snap, false);
        let mut vbad = wire.clone();
        vbad[4] = 0xfe; // version field
        let err = decode(&vbad).expect_err("future version must be rejected");
        assert!(err.to_string().contains("version"), "unexpected error: {err}");
        let mut mbad = wire;
        mbad[0] = b'X';
        let err = decode(&mbad).expect_err("foreign magic must be rejected");
        assert!(err.to_string().contains("magic"), "unexpected error: {err}");
    }

    #[test]
    fn geometry_mismatch_rejected_by_pool() {
        let mut p = pool_dt(8, KvDtype::Int8);
        let mut t = BlockTable::new(64);
        run_tokens(&mut p, &mut t, &[1, 2, 3]);
        let snap = p.suspend(t);
        let wire = p.snapshot_to_wire(&snap, false);
        let other = pool_dt(8, KvDtype::Fp8E4M3);
        let err = other.snapshot_from_wire(&wire).expect_err("dtype mismatch must be rejected");
        assert!(err.to_string().contains("geometry"), "unexpected error: {err}");
    }

    #[test]
    fn prompt_digests_match_pool_prefix_digests() {
        let mut p = pool_dt(16, KvDtype::Int8);
        let prompt: Vec<u8> = (100..120).collect(); // 5 full blocks at bt=4
        let mut t = BlockTable::new(64);
        run_tokens(&mut p, &mut t, &prompt);
        p.release(t); // freeze + cache the chain
        let have: std::collections::HashSet<u64> = p.prefix_digests().into_iter().collect();
        let want = prompt_digests(&prompt, 4);
        assert_eq!(want.len(), 5);
        for (i, dg) in want.iter().enumerate() {
            assert!(have.contains(dg), "prefix digest {i} missing from the pool set");
        }
        // A foreign prompt's digests must not match.
        for dg in prompt_digests(&(200..216).collect::<Vec<u8>>(), 4) {
            assert!(!have.contains(&dg), "foreign digest spuriously present");
        }
    }

    #[test]
    fn rle_round_trips_random_buffers() {
        let mut rng = Rng::seed_from_u64(77);
        for _ in 0..50 {
            let n = rng.below(400);
            // Mix runs and noise.
            let mut buf = Vec::with_capacity(n);
            while buf.len() < n {
                let v = rng.below(256) as u8;
                let run = 1 + rng.below(20).min(n - buf.len() - 1 + 1);
                buf.resize(buf.len() + run, v);
            }
            buf.truncate(n);
            let enc = rle_encode(&buf);
            assert_eq!(rle_decode(&enc, n).unwrap(), buf);
        }
    }
}
