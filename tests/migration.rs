//! Cross-engine migration + tiered KV spill integration tests (PR 9).
//!
//! The claims, pinned end-to-end on tiny in-memory models:
//!
//! * **Migration bit-identity** — suspending a sequence on engine A
//!   ([`Scheduler::extract`]), shipping its KV through the versioned
//!   wire format ([`BlockPool::snapshot_to_wire`] →
//!   [`BlockPool::snapshot_from_wire`]), and resuming on engine B
//!   ([`Scheduler::inject`]) yields byte-identical output to an
//!   unmigrated run — for every `KvDtype`, with mid-block (tainted)
//!   tails and COW-shared prefixes in the workload, at both
//!   migrate-after-1 (prefill→decode handoff) and mid-decode points.
//!   Sampled requests survive too: the RNG state rides along.
//! * **Source reclamation** — after every sequence is extracted or
//!   retired, engine A holds zero referenced blocks.
//! * **Spill byte-exactness** — under preemption pressure with the
//!   disk tier enabled, spill → restore round-trips through
//!   [`sdq::swap::SwapDir`] keep output bit-identical; the f32
//!   reprefill tier does the same by replay.
//! * **Router streaming** — a 2-replica [`Router`] with forced
//!   mid-stream migration delivers exact, gapless streams and leaks no
//!   blocks on either replica.
//!
//! [`Scheduler::extract`]: sdq::coordinator::scheduler::Scheduler::extract
//! [`Scheduler::inject`]: sdq::coordinator::scheduler::Scheduler::inject
//! [`BlockPool::snapshot_to_wire`]: sdq::kv::BlockPool::snapshot_to_wire
//! [`BlockPool::snapshot_from_wire`]: sdq::kv::BlockPool::snapshot_from_wire

use std::collections::HashSet;

use sdq::coordinator::batcher::{BatchPolicy, Batcher};
use sdq::coordinator::metrics::Metrics;
use sdq::coordinator::scheduler::Scheduler;
use sdq::coordinator::{assert_bit_identical, Request, Response};
use sdq::gateway::{GatewayOpts, GatewayRequest};
use sdq::kv::{KvDtype, KV_BLOCK_TOKENS};
use sdq::model::testutil::tiny_model;
use sdq::model::{Arch, Model};
use sdq::router::{Router, RouterOpts};
use sdq::swap::SwapConfig;
use sdq::util::testdir::TempDir;

/// Workload covering the three snapshot shapes at once: short ragged
/// prompts (partial f32 tails / tainted quantized tails at every
/// suspend point), a block-crossing prompt, and a COW pair sharing a
/// one-block prefix. All greedy unless `sampled_last`.
fn workload(sampled_last: bool) -> Vec<Request> {
    let prefix: Vec<u8> = (0..KV_BLOCK_TOKENS as u8).map(|j| 100 + j).collect();
    let mut prompts: Vec<Vec<u8>> = vec![vec![65, 66, 67], vec![70; KV_BLOCK_TOKENS + 5]];
    let mut fork_a = prefix.clone();
    fork_a.extend([1, 2, 3]);
    let mut fork_b = prefix;
    fork_b.extend([4, 5]);
    prompts.push(fork_a);
    prompts.push(fork_b);
    prompts
        .into_iter()
        .enumerate()
        .map(|(i, p)| {
            let r = Request::new(i as u64, p, 9 + i);
            if sampled_last && i == 3 {
                r.with_temperature(0.7)
            } else {
                r
            }
        })
        .collect()
}

/// Drive one scheduler to drain; id-sorted responses + metrics.
fn run_plain(
    model: &Model,
    policy: BatchPolicy,
    swap: Option<SwapConfig>,
    reqs: Vec<Request>,
) -> (Vec<Response>, Metrics) {
    let mut sched = Scheduler::with_spec(model, policy, None);
    if let Some(cfg) = swap {
        sched.set_swap(cfg);
    }
    let mut batcher = Batcher::new();
    for r in reqs {
        batcher.enqueue(r);
    }
    let mut out = Vec::new();
    let mut rounds = 0;
    while sched.has_work(&batcher) {
        out.extend(sched.round(&mut batcher));
        sched.pool().assert_consistent();
        rounds += 1;
        assert!(rounds < 4000, "scheduler failed to drain");
    }
    assert_eq!(sched.pool().referenced_blocks(), 0, "drained engine leaked blocks");
    out.sort_by_key(|r| r.id);
    (out, sched.metrics)
}

/// Drive engine A, migrating every sequence to engine B (through the
/// full wire encode → decode) once it has `migrate_at` tokens; drain B;
/// return the combined id-sorted responses.
fn run_migrated(
    model: &Model,
    policy: BatchPolicy,
    reqs: Vec<Request>,
    migrate_at: usize,
) -> Vec<Response> {
    let n = reqs.len();
    let mut a = Scheduler::with_spec(model, policy, None);
    let mut ba = Batcher::new();
    for r in reqs {
        ba.enqueue(r);
    }
    let mut b = Scheduler::with_spec(model, policy, None);
    let mut bb = Batcher::new();
    let mut done = Vec::new();
    let mut migrated: HashSet<u64> = HashSet::new();
    let mut rounds = 0;
    while a.has_work(&ba) {
        done.extend(a.round(&mut ba));
        a.pool().assert_consistent();
        let mut ready = Vec::new();
        a.for_each_progress(|id, toks| {
            if toks.len() >= migrate_at && !migrated.contains(&id) {
                ready.push(id);
            }
        });
        for id in ready {
            let (f, snap) = a.extract(id).expect("progressing sequence is in flight");
            let bytes = a.pool().snapshot_to_wire(&snap, true);
            let snap_b = b.pool().snapshot_from_wire(&bytes).expect("identical geometry");
            b.inject(f, snap_b);
            migrated.insert(id);
        }
        rounds += 1;
        assert!(rounds < 4000, "engine A failed to drain");
    }
    // The acceptance invariant: once everything is handed off or
    // retired, the source holds nothing.
    assert_eq!(a.pool().referenced_blocks(), 0, "source engine leaked blocks after handoff");
    assert!(!migrated.is_empty(), "workload never reached the migration point");
    assert_eq!(a.metrics.migrations_out, migrated.len() as u64);
    let mut rounds = 0;
    while b.has_work(&bb) {
        done.extend(b.round(&mut bb));
        b.pool().assert_consistent();
        rounds += 1;
        assert!(rounds < 4000, "engine B failed to drain");
    }
    assert_eq!(b.pool().referenced_blocks(), 0, "destination engine leaked blocks");
    assert_eq!(b.metrics.migrations_in, migrated.len() as u64);
    assert_eq!(done.len(), n, "every request must retire exactly once");
    done.sort_by_key(|r| r.id);
    done
}

// ---------------------------------------------------------------------
// Scheduler-level bit-identity
// ---------------------------------------------------------------------

#[test]
fn migration_bit_identical_every_dtype_and_suspend_shape() {
    for (di, dtype) in [KvDtype::F32, KvDtype::Int8, KvDtype::Fp8E4M3, KvDtype::Int4Outlier]
        .into_iter()
        .enumerate()
    {
        let model = tiny_model(if di % 2 == 0 { Arch::Gpt } else { Arch::Llama }, 210 + di as u64);
        let policy = BatchPolicy { kv_dtype: Some(dtype), ..Default::default() };
        let (want, _) = run_plain(&model, policy, None, workload(false));
        // migrate_at 1 = prefill→decode handoff (ship right after the
        // first token); 3 = mid-decode, mid-block for every sequence.
        for migrate_at in [1usize, 3] {
            let got = run_migrated(&model, policy, workload(false), migrate_at);
            assert_bit_identical(&format!("{dtype} migrate@{migrate_at}"), &got, &want);
        }
    }
}

#[test]
fn sampled_rng_stream_survives_migration() {
    let model = tiny_model(Arch::Gpt, 230);
    let policy = BatchPolicy { kv_dtype: Some(KvDtype::F32), ..Default::default() };
    let (want, _) = run_plain(&model, policy, None, workload(true));
    let got = run_migrated(&model, policy, workload(true), 3);
    assert_bit_identical("sampled migration", &got, &want);
}

// ---------------------------------------------------------------------
// Spill tier under preemption pressure
// ---------------------------------------------------------------------

#[test]
fn spill_and_reprefill_tiers_stay_bit_exact_under_pressure() {
    for (di, dtype) in [KvDtype::F32, KvDtype::Int8].into_iter().enumerate() {
        let model = tiny_model(Arch::Gpt, 240 + di as u64);
        let roomy = BatchPolicy { kv_dtype: Some(dtype), ..Default::default() };
        // Block-denominated pressure (dtype-independent: a compressed
        // pool would sail under any fixed byte budget).
        let tight = BatchPolicy {
            kv_budget_bytes: usize::MAX,
            max_resident_blocks: Some(3),
            preempt: true,
            ..roomy
        };
        let (want, _) = run_plain(&model, roomy, None, workload(false));
        let tmp = TempDir::new("migration-spill");
        let cfg = SwapConfig {
            dir: Some(sdq::swap::SwapDir::new(tmp.path().join(format!("d{di}"))).unwrap()),
            resident_budget_bytes: 0,
            ..Default::default()
        };
        let (got, m) = run_plain(&model, tight, Some(cfg), workload(false));
        assert_bit_identical(&format!("{dtype} spill tier"), &got, &want);
        assert!(m.preemptions > 0, "[{dtype}] tight pool never preempted");
        assert!(
            m.spills + m.reprefill_drops > 0,
            "[{dtype}] zero resident budget never left the resident tier"
        );
        assert_eq!(m.restores, m.spills, "every spilled sequence must restore exactly once");
        if dtype == KvDtype::Int8 {
            // Quantized victims may never take the replay tier, and the
            // codec accounting must cover what was framed.
            assert_eq!(m.reprefill_drops, 0, "quantized replay is not bit-exact");
            assert!(m.spills > 0, "quantized victims must spill");
            assert!(m.codec_encoded_bytes <= m.codec_raw_bytes);
            assert!(m.spilled_bytes > 0);
        }
    }
    // No disk tier at all: f32 victims drop to reprefill instead.
    let model = tiny_model(Arch::Gpt, 245);
    let roomy = BatchPolicy { kv_dtype: Some(KvDtype::F32), ..Default::default() };
    let tight = BatchPolicy {
        kv_budget_bytes: usize::MAX,
        max_resident_blocks: Some(3),
        preempt: true,
        ..roomy
    };
    let (want, _) = run_plain(&model, roomy, None, workload(false));
    let cfg = SwapConfig { resident_budget_bytes: 0, ..Default::default() };
    let (got, m) = run_plain(&model, tight, Some(cfg), workload(false));
    assert_bit_identical("f32 reprefill tier", &got, &want);
    assert!(m.preemptions > 0);
    assert!(m.reprefill_drops > 0, "no disk tier: f32 must replay");
    assert_eq!(m.spills, 0);
}

// ---------------------------------------------------------------------
// Router-level streaming migration
// ---------------------------------------------------------------------

#[test]
fn router_migrates_mid_stream_and_streams_stay_exact() {
    let model = tiny_model(Arch::Gpt, 250);
    // Long decodes (24 tokens past a migrate-after of 2) so every
    // forwarder's migration trigger lands while its sequence is still
    // in flight — the tiny model finishes rounds in microseconds.
    let want: Vec<Vec<u8>> =
        (0..4u8).map(|i| model.generate(&[65 + i; 5], 24, 0.0, 0)).collect();
    let router = Router::start(
        &model,
        2,
        BatchPolicy::default(),
        GatewayOpts::default(),
        RouterOpts { migrate_after: Some(2) },
        None,
    )
    .unwrap();
    let h = router.handle();
    let streams: Vec<_> = (0..4u8)
        .map(|i| h.submit(GatewayRequest::greedy(vec![65 + i; 5], 24)).unwrap())
        .collect();
    for (s, want) in streams.into_iter().zip(&want) {
        let out = s.drain();
        assert!(!out.cancelled, "migrated stream must not cancel");
        assert_eq!(&out.streamed, want, "streamed tokens diverged across the hop");
        assert_eq!(out.final_tokens, out.streamed, "Done must echo the gapless stream");
    }
    assert!(h.migrations() >= 1, "migrate_after=2 never migrated any stream");
    let drained = router.shutdown();
    for d in &drained {
        assert_eq!(d.referenced_blocks, 0, "replica leaked blocks");
    }
    let out_total: u64 = drained.iter().map(|d| d.metrics.migrations_out).sum();
    let in_total: u64 = drained.iter().map(|d| d.metrics.migrations_in).sum();
    assert_eq!(out_total, in_total, "every migrate-out must land as a migrate-in");
    assert_eq!(out_total, h.migrations(), "router counter must tally the engines");
}
