//! Analytical performance model (§3, Fig. 4, Fig. 8).
//!
//! The paper evaluates SDQ against a *futuristic flexible N:M sparse
//! tensor core*: N:M sparsity contributes `M/N×` compute throughput,
//! n-bit dual-quantized arithmetic contributes `16/n×` versus fp16
//! (§3.1–3.2). This module implements that model exactly, plus the
//! §3.3 average-bits-per-weight accounting (values + sparsity index
//! metadata + quantization scale metadata) that Fig. 4 plots, and a
//! cycle-level simulated sparse tensor core ([`simtc`]) used to sanity-
//! check the analytical numbers including the sparsity tax.

pub mod energy;
pub mod simtc;


use crate::sdq::config::{CompressionConfig, Stages};
use crate::sdq::nm::NmPattern;

/// Bits-per-element breakdown for a (sparsity, quantization) combination
/// over a reference span of elements — the Fig. 4 bars.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BitsBreakdown {
    /// Value bits per original element.
    pub data: f64,
    /// Sparsity index metadata (Metadata-S) per original element.
    pub metadata_s: f64,
    /// Scale-factor metadata (Metadata-Q) per original element.
    pub metadata_q: f64,
}

impl BitsBreakdown {
    /// Total average bits per original weight element.
    pub fn total(&self) -> f64 {
        self.data + self.metadata_s + self.metadata_q
    }
}

/// §3.3 accounting for one N:M-sparse, `value_bits`-quantized tensor with
/// `scale_bits`-wide scale factors every `qvec` elements (dense layout).
///
/// * data: `N/M · value_bits`
/// * Metadata-S: `N/M · log2(M)` (ELLPACK index per stored value);
///   zero for dense patterns.
/// * Metadata-Q: `scale_bits / qvec`.
pub fn bits_breakdown(
    pattern: NmPattern,
    value_bits: u32,
    scale_bits: u32,
    qvec: usize,
) -> BitsBreakdown {
    let density = pattern.density();
    let idx_bits = if pattern.is_dense() { 0 } else { pattern.index_bits() };
    BitsBreakdown {
        data: density * value_bits as f64,
        metadata_s: density * idx_bits as f64,
        metadata_q: scale_bits as f64 / qvec as f64,
    }
}

/// Average bits per original weight element for a full configuration,
/// including all metadata (§3.3). SDQ stores two tensors (outliers +
/// inliers), each with its own values, indices and scale factors.
pub fn bits_per_weight(cfg: &CompressionConfig) -> f64 {
    let scale_bits = cfg.scale_fmt.bits();
    match &cfg.stages {
        Stages::Dense => 16.0,
        Stages::SparsifyOnly(sp) => {
            // fp16 values, index metadata, no scale factors.
            bits_breakdown(sp.pattern, 16, 0, usize::MAX.min(1 << 30)).data
                + sp.pattern.density() * sp.pattern.index_bits() as f64
        }
        Stages::QuantOnly { weight_fmt, .. } => {
            let dense = NmPattern::new(1, 1);
            bits_breakdown(dense, weight_fmt.bits(), scale_bits, cfg.qvec).total()
        }
        Stages::Sdq { decompose, .. } => {
            let o = bits_breakdown(
                decompose.outlier_pattern,
                decompose.outlier_fmt.bits(),
                scale_bits,
                cfg.qvec,
            );
            let i = bits_breakdown(
                decompose.inlier_pattern,
                decompose.inlier_fmt.bits(),
                scale_bits,
                cfg.qvec,
            );
            o.total() + i.total()
        }
    }
}

/// MAC-level cost model for one GEMM `[t×k]·[o×k]ᵀ` under a config:
/// returns (dense-equivalent MACs, executed MAC-slot cost normalized to
/// fp16 units). `executed / dense` is the inverse effective throughput —
/// Fig. 8's `1/16 + 3/16 = 1/4` arithmetic.
pub fn gemm_cost(cfg: &CompressionConfig, t: usize, k: usize, o: usize) -> (f64, f64) {
    let dense = (t * k * o) as f64;
    let cost = dense / cfg.effective_throughput();
    (dense, cost)
}

/// Model-level roll-up: effective throughput, bits/weight, and weight
/// memory for a set of layer shapes.
#[derive(Clone, Debug)]
pub struct ModelCost {
    pub config: String,
    pub effective_throughput: f64,
    pub bits_per_weight: f64,
    /// Total weight bytes after compression (incl. metadata).
    pub weight_bytes: f64,
    /// Total dense-equivalent MACs per token.
    pub dense_macs_per_token: f64,
    /// Executed fp16-equivalent MAC cost per token.
    pub effective_macs_per_token: f64,
}

/// Roll up cost for a model described by its linear-layer shapes
/// (`[(out, in); L]`), one token per layer pass.
pub fn model_cost(cfg: &CompressionConfig, layer_shapes: &[(usize, usize)]) -> ModelCost {
    let bpw = bits_per_weight(cfg);
    let params: f64 = layer_shapes.iter().map(|(o, i)| (o * i) as f64).sum();
    let dense_macs = params; // one token: MACs == params for linear layers
    let eff = cfg.effective_throughput();
    ModelCost {
        config: cfg.to_string(),
        effective_throughput: eff,
        bits_per_weight: bpw,
        weight_bytes: params * bpw / 8.0,
        dense_macs_per_token: dense_macs,
        effective_macs_per_token: dense_macs / eff,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_examples() {
        // §3.3 worked example: 16-bit→4-bit, scale 16-bit, Q-vector 4:
        // data 4, metadata-Q 16/4 = 4 ⇒ 8 bits/element.
        let b = bits_breakdown(NmPattern::new(1, 1), 4, 16, 4);
        assert_eq!(b.total(), 8.0);

        // 2:4 sparsity: 2 bits/index per stored value ⇒ 4 bits per 4-elem
        // vector ⇒ 1 bit per original element.
        let b = bits_breakdown(NmPattern::new(2, 4), 4, 0, 1 << 30);
        assert!((b.metadata_s - 1.0).abs() < 1e-12);

        // 1:8: 3 bits per stored value ⇒ 3/8 per element.
        let b = bits_breakdown(NmPattern::new(1, 8), 8, 0, 1 << 30);
        assert!((b.metadata_s - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn fig4_crossover_3_4_sparse_beats_dense() {
        // "a 3:4 sparse, 4-bit quantized model can have a higher
        //  bit-per-weight than a dense, 4-bit quantized model"
        // with 32-bit scale factors and Q-vector 16:
        let sparse = bits_breakdown(NmPattern::new(3, 4), 4, 32, 16).total();
        let dense = bits_breakdown(NmPattern::new(1, 1), 4, 32, 16).total();
        assert!(
            sparse > dense,
            "3:4+4b ({sparse}) must exceed dense 4b ({dense})"
        );
    }

    #[test]
    fn bits_per_weight_orderings() {
        let dense: CompressionConfig = "Dense-WA16".parse().unwrap();
        let q8: CompressionConfig = "Q-VSQuant-WAint8".parse().unwrap();
        let q4: CompressionConfig = "Q-VSQuant-WAfp4".parse().unwrap();
        let sdq: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
        let bd = bits_per_weight(&dense);
        let b8 = bits_per_weight(&q8);
        let b4 = bits_per_weight(&q4);
        let bs = bits_per_weight(&sdq);
        assert_eq!(bd, 16.0);
        assert!(b8 < bd && b4 < b8, "{bd} > {b8} > {b4}");
        // SDQ-7:8 stores 1/8·(8+3) + 6/8·(4+3) + 2·8/16 = 1.375+5.25+1 = 7.625
        assert!((bs - 7.625).abs() < 1e-9, "sdq bpw {bs}");
        // SDQ sits between int8 dual quant and fp16
        assert!(bs < bd && bs > b4);
    }

    #[test]
    fn fig8_throughput_decomposition() {
        let sdq: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
        let (dense, cost) = gemm_cost(&sdq, 1, 4096, 4096);
        // 1/8·1/2 + 6/8·1/4 = 1/4 of dense
        assert!((cost / dense - 0.25).abs() < 1e-9);
    }

    #[test]
    fn model_cost_rollup() {
        let cfg: CompressionConfig = "Q-VSQuant-WAint8".parse().unwrap();
        let mc = model_cost(&cfg, &[(64, 64), (128, 64)]);
        assert_eq!(mc.dense_macs_per_token, (64 * 64 + 128 * 64) as f64);
        assert_eq!(mc.effective_macs_per_token, mc.dense_macs_per_token / 2.0);
        assert!(mc.weight_bytes < mc.dense_macs_per_token * 2.0);
    }
}
