//! Property tests for the packed quantized weight plane (`sdq::qmat`):
//! the fused GEMM over real codes must equal dequantize-then-GEMM **to
//! the bit** for every supported format across ragged tile shapes, and
//! the nibble codecs must round-trip their grids exactly.
//!
//! Shape taxonomy (the micro-tile schedule in `tensor/matmul.rs` is
//! KB=256 / CB=64 / TB=16):
//! * `t = 1` — single-row decode, the serving hot case;
//! * `t = 17` — straddles a TB=16 row-tile boundary;
//! * `n = 33, 130` — ragged CB=64 column blocks, and (with small `t`,
//!   `n ≥ 128`) the `par_col_blocks` column-parallel crossover;
//! * `k = 53, 300, 530` — K not a multiple of the q-vector (ragged
//!   last scale group) and K crossing the KB=256 block boundary
//!   mid-group.

use sdq::formats::{NumFormat, FP4_GRID};
use sdq::sdq::qmat::QuantMat;
use sdq::sdq::quantize::{quantize_tensor, VsQuantCfg};
use sdq::tensor::{matmul_into, matmul_q_into, Matrix};
use sdq::util::rng::Rng;

fn rand_matrix(rows: usize, cols: usize, lo: f32, hi: f32, seed: u64) -> Matrix {
    let mut rng = Rng::seed_from_u64(seed);
    Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.range_f32(lo, hi)).collect())
}

fn cfg(fmt: NumFormat, qvec: usize) -> VsQuantCfg {
    VsQuantCfg { fmt, qvec, scale_fmt: NumFormat::Fp8E4M3 }
}

/// The tentpole property: for int8, int4 and fp4 weight planes, the
/// fused `matmul_q_into` over packed codes is bit-identical to
/// dequantizing the same tensor and running the dense `matmul_into` —
/// across every ragged-shape class and q-vector size.
#[test]
fn fused_gemm_bit_identical_to_dequantized_gemm_across_shapes() {
    let fmts = [NumFormat::Int(8), NumFormat::Int(4), NumFormat::Fp4E2M1];
    // (t, k, n): see the module docs for why each shape is here.
    let shapes = [
        (1usize, 300usize, 96usize), // 1-row decode, K crosses KB=256
        (1, 53, 130),                // 1-row + ragged K + ragged CB + col-parallel
        (17, 64, 40),                // TB straddle
        (4, 530, 33),                // two KB blocks + ragged tail everywhere
        (16, 128, 64),               // exactly tile-aligned control
    ];
    for fmt in fmts {
        for qvec in [8usize, 16] {
            for (i, &(t, k, n)) in shapes.iter().enumerate() {
                let seed = 1000 + i as u64;
                let x = rand_matrix(t, k, -2.0, 2.0, seed);
                let w = rand_matrix(n, k, -1.5, 1.5, seed + 77);
                let qt = quantize_tensor(&w, cfg(fmt, qvec));
                let qm = QuantMat::try_from_tensor(&qt)
                    .unwrap_or_else(|| panic!("{fmt} must pack"));
                let deq = qt.dequantize();
                let mut c_ref = Matrix::zeros(t, n);
                matmul_into(&x, &deq, &mut c_ref);
                let mut c_fused = Matrix::zeros(t, n);
                matmul_q_into(&x, &qm, &mut c_fused);
                for (j, (a, b)) in c_fused.data.iter().zip(&c_ref.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{fmt} qvec={qvec} shape {t}x{k}x{n} elem {j}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

/// Outlier-heavy weights (the SDQ decomposition's raison d'être) push
/// scales across many binades — the fused route must stay bit-exact
/// there too, including rows that are entirely zero (scale 0 groups).
#[test]
fn fused_gemm_bit_identical_on_outliers_and_zero_rows() {
    let mut w = rand_matrix(24, 96, -0.05, 0.05, 42);
    let mut rng = Rng::seed_from_u64(43);
    for _ in 0..40 {
        let i = rng.below(w.data.len());
        w.data[i] = rng.range_f32(4.0, 9.0) * if rng.bool(0.5) { 1.0 } else { -1.0 };
    }
    // Two all-zero rows: quantize_tensor gives them zero scales.
    for r in [3usize, 20] {
        for v in w.row_mut(r) {
            *v = 0.0;
        }
    }
    let x = rand_matrix(5, 96, -1.0, 1.0, 44);
    for fmt in [NumFormat::Int(8), NumFormat::Fp4E2M1] {
        let qt = quantize_tensor(&w, cfg(fmt, 16));
        let qm = QuantMat::try_from_tensor(&qt).unwrap();
        let deq = qt.dequantize();
        let mut c_ref = Matrix::zeros(5, 24);
        matmul_into(&x, &deq, &mut c_ref);
        let mut c_fused = Matrix::zeros(5, 24);
        matmul_q_into(&x, &qm, &mut c_fused);
        for (a, b) in c_fused.data.iter().zip(&c_ref.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "{fmt}");
        }
    }
}

/// Packed-nibble fp4 codec round-trip against `NumFormat::Fp4E2M1`'s
/// own grid: quantize a value set that covers every grid point (both
/// signs, plus off-grid values that RNE onto it), pack, and check the
/// decoded plane equals the tensor's codes bit-for-bit — fp4's
/// sign-magnitude nibble preserves even `-0.0`.
#[test]
fn fp4_nibble_codec_roundtrips_the_e2m1_grid() {
    // One row per grid sign, cols cover grid points and midpoints.
    let mut vals = Vec::new();
    for g in FP4_GRID {
        for s in [1.0f32, -1.0] {
            vals.push(g * s); // exact grid points (incl. ±0.0)
            vals.push(g * s * 1.04); // rounds back onto the grid
        }
    }
    while vals.len() % 16 != 0 {
        vals.push(0.25); // fp4 RNE → 0.5 or 0.0 depending on tie rules
    }
    let w = Matrix::from_vec(2, vals.len() / 2, vals);
    let qt = quantize_tensor(&w, cfg(NumFormat::Fp4E2M1, 16));
    // Every code the quantizer emits must be an fp4 grid point.
    for c in &qt.codes {
        assert!(FP4_GRID.contains(&c.abs()), "off-grid code {c}");
    }
    let qm = QuantMat::try_from_tensor(&qt).unwrap();
    let unpacked = qm.dequantize();
    let reference = qt.dequantize();
    for (a, b) in unpacked.data.iter().zip(&reference.data) {
        // Bit equality: sign-magnitude nibbles are fully lossless.
        assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
    }
}

/// Int planes store codes as two's-complement bytes/nibbles, which
/// cannot carry a `-0.0` code — the dequantized views therefore agree
/// under `==` (value equality) while GEMM outputs stay bit-identical
/// (IEEE addition absorbs the zero-sign difference).
#[test]
fn int_dequantize_value_equal_and_range_edges_roundtrip() {
    for (fmt, maxc) in [(NumFormat::Int(8), 127.0f32), (NumFormat::Int(4), 7.0)] {
        // Values engineered to hit the extreme codes ±max.
        let w = rand_matrix(7, 48, -3.0, 3.0, 55);
        let qt = quantize_tensor(&w, cfg(fmt, 16));
        assert!(
            qt.codes.iter().any(|c| c.abs() == maxc),
            "{fmt}: test data never hit the extreme code"
        );
        let qm = QuantMat::try_from_tensor(&qt).unwrap();
        let a = qm.dequantize();
        let b = qt.dequantize();
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(*x, *y, "{fmt}");
        }
    }
}

/// Byte accounting: the packed plane must beat the ≥3.5× (int8) and
/// ≥6× (fp4) dense-traffic cuts the serving metrics advertise, at
/// serving-realistic shapes.
#[test]
fn packed_bytes_ratios_meet_the_advertised_cuts() {
    let w = rand_matrix(384, 384, -1.0, 1.0, 66);
    let dense = 4 * w.len() as f64;
    let q8 = QuantMat::try_from_tensor(&quantize_tensor(&w, cfg(NumFormat::Int(8), 16))).unwrap();
    assert!(q8.scales_are_fp8(), "default e4m3 scales must pack to one byte");
    let r8 = dense / q8.packed_bytes() as f64;
    assert!(r8 >= 3.5, "int8 ratio {r8:.2} < 3.5");
    let q4 =
        QuantMat::try_from_tensor(&quantize_tensor(&w, cfg(NumFormat::Fp4E2M1, 16))).unwrap();
    let r4 = dense / q4.packed_bytes() as f64;
    assert!(r4 >= 6.0, "fp4 ratio {r4:.2} < 6.0");
}
