//! Experiment harness: shared plumbing for the CLI, benches and examples.
//!
//! Locates artifacts, loads models/datasets, runs the calibration pass,
//! applies compression configurations, and evaluates perplexity /
//! zero-shot accuracy — one place for the logic every paper table needs.

use std::path::PathBuf;

use anyhow::{anyhow, Context};

use crate::artifacts::load_weights;
use crate::data::{Split, TokenDataset};
use crate::eval::{perplexity, PplResult};
use crate::model::Model;
use crate::sdq::calib::CalibStats;
use crate::sdq::config::{CompressionConfig, Stages};
use crate::sdq::pipeline::LayerReport;
use crate::Result;

/// Repository root: `$SDQ_ROOT` or the current directory.
pub fn repo_root() -> PathBuf {
    std::env::var_os("SDQ_ROOT").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("."))
}

/// Path to a trained model bundle.
pub fn model_path(name: &str) -> PathBuf {
    repo_root().join("artifacts/models").join(format!("{name}.bin"))
}

/// Load a trained model from `artifacts/models/<name>.bin`.
pub fn load_model(name: &str) -> Result<Model> {
    let path = model_path(name);
    let bundle = load_weights(&path)
        .with_context(|| format!("loading {} (run `make artifacts`)", path.display()))?;
    Model::from_bundle(bundle)
}

/// Model names present under `artifacts/models/` (sorted), optionally
/// filtered by prefix.
pub fn available_models(prefix: &str) -> Vec<String> {
    let dir = repo_root().join("artifacts/models");
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter_map(|e| {
                    let n = e.file_name().to_string_lossy().into_owned();
                    n.strip_suffix(".bin").map(|s| s.to_string())
                })
                // `.sdq.bin` companions are AOT parameter bundles, not models.
                .filter(|n| n.starts_with(prefix) && !n.ends_with(".sdq"))
                .collect()
        })
        .unwrap_or_default();
    names.sort();
    names
}

/// Load the shared corpus dataset.
pub fn load_dataset() -> Result<TokenDataset> {
    let path = repo_root().join("artifacts/corpus.bin");
    TokenDataset::load(&path)
        .map_err(|e| anyhow!("loading corpus {}: {e} (run `make artifacts`)", path.display()))
}

/// Whether a configuration needs Hessian (Gram) calibration.
pub fn needs_gram(cfg: &CompressionConfig) -> bool {
    use crate::sdq::config::SparsifyMethod;
    match &cfg.stages {
        Stages::SparsifyOnly(s) => s.method == SparsifyMethod::SparseGpt,
        Stages::Sdq { sparsify: Some(s), .. } => s.method == SparsifyMethod::SparseGpt,
        Stages::QuantOnly { algo, .. } => *algo == crate::sdq::config::QuantAlgo::Gptq,
        _ => false,
    }
}

/// Run the calibration pass over the validation split.
pub fn calibrate(model: &Model, ds: &TokenDataset, tokens: usize, with_gram: bool) -> CalibStats {
    let mut stats = CalibStats::new(with_gram);
    let seq = (model.cfg.max_seq / 2).max(16);
    let mut seen = 0;
    for (inp, _) in ds.windows(Split::Valid, 4, seq) {
        let b = inp.len() / seq;
        model.forward(&inp, b, seq, Some(&mut stats));
        seen += inp.len();
        if seen >= tokens {
            break;
        }
    }
    stats
}

/// Evaluation knobs (scaled by model size in the benches).
#[derive(Clone, Copy, Debug)]
pub struct EvalCfg {
    pub calib_tokens: usize,
    pub eval_tokens: usize,
    pub batch: usize,
    pub seq: usize,
}

impl Default for EvalCfg {
    fn default() -> Self {
        EvalCfg { calib_tokens: 2048, eval_tokens: 4096, batch: 8, seq: 64 }
    }
}

/// Result of evaluating one compression configuration on one model.
#[derive(Clone, Debug)]
pub struct ConfigEval {
    pub config: String,
    pub ppl: PplResult,
    pub effective_throughput: f64,
    /// Analytic bits/weight from the perf model (format arithmetic).
    pub bits_per_weight: f64,
    /// Actual packed resident bytes across every linear: quantized
    /// codes + scales + N:M sparse metadata (`Model::weight_bytes`) —
    /// the honest size, where `bits_per_weight` is the formula.
    pub weight_bytes: u64,
    /// Dense f32 bytes of the same linears (4 bytes per weight): the
    /// denominator for the real compression ratio.
    pub dense_weight_bytes: u64,
    pub mean_rel_err: f64,
    pub reports: Vec<LayerReport>,
}

/// Compress a *clone* of `base` under `cfg` (calibrating as needed) and
/// evaluate test perplexity. The base model is untouched.
pub fn eval_config(
    base: &Model,
    ds: &TokenDataset,
    cfg: &CompressionConfig,
    ecfg: EvalCfg,
) -> Result<ConfigEval> {
    let mut model = base.clone();
    let calib = calibrate(&model, ds, ecfg.calib_tokens, needs_gram(cfg));
    let reports = model.compress(cfg, &calib)?;
    let weight_bytes = model.weight_bytes();
    let (streamed, avoided) = model.weight_stream_bytes();
    let ppl = perplexity(&model, ds, Split::Test, ecfg.batch, ecfg.seq, ecfg.eval_tokens);
    let mean_rel_err =
        reports.iter().map(|r| r.rel_err).sum::<f64>() / reports.len().max(1) as f64;
    Ok(ConfigEval {
        config: cfg.to_string(),
        ppl,
        effective_throughput: cfg.effective_throughput(),
        bits_per_weight: crate::perfmodel::bits_per_weight(cfg),
        weight_bytes,
        dense_weight_bytes: streamed + avoided,
        mean_rel_err,
        reports,
    })
}

/// The Table-2/3 configuration grid (paper §6.1/§6.2), grouped by
/// effective-throughput category.
pub fn table2_configs() -> Vec<&'static str> {
    vec![
        // 1× (weight-only quantization rows: RTN ≙ VS-Quant W4, GPTQ)
        "Dense-WA16",
        "Q-VSQuant-Wfp4",
        "Q-GPTQ-Wfp4",
        // 2×
        "S-Wanda-4:8",
        "S-SparseGPT-4:8",
        "Q-VSQuant-WAint8",
        "Q-VSQuant-WAfp8",
        // 3.6×
        "SDQ-8:8-1:8int8-7:8fp4",
        // 4×
        "S-Wanda-2:8",
        "S-SparseGPT-2:8",
        "Q-VSQuant-WAint4",
        "Q-VSQuant-WAfp4",
        "SDQ-W3:4-1:4int8-2:4fp4",
        "SDQ-S3:4-1:4int8-2:4fp4",
        "SDQ-W6:8-2:8int8-4:8fp4",
        "SDQ-S6:8-2:8int8-4:8fp4",
        "SDQ-W7:8-1:8int8-6:8fp4",
        "SDQ-S7:8-1:8int8-6:8fp4",
    ]
}

/// Scale evaluation cost down for larger models so table benches finish
/// on one core (documented in EXPERIMENTS.md).
pub fn eval_cfg_for(model: &Model, full: bool) -> EvalCfg {
    let params = model.cfg.param_count();
    let base = EvalCfg::default();
    if full || params < 500_000 {
        base
    } else if params < 2_000_000 {
        EvalCfg { calib_tokens: 1536, eval_tokens: 3072, ..base }
    } else {
        EvalCfg { calib_tokens: 1024, eval_tokens: 2048, ..base }
    }
}

/// Ensure artifacts exist; returns false (and prints a hint) otherwise.
/// Benches use this to no-op gracefully before `make artifacts`.
pub fn artifacts_ready() -> bool {
    let ok = repo_root().join("artifacts/corpus.bin").exists()
        && !available_models("").is_empty();
    if !ok {
        eprintln!(
            "artifacts missing under {} — run `make artifacts` first",
            repo_root().join("artifacts").display()
        );
    }
    ok
}

/// Write a JSON record (used by benches to persist table data).
pub fn save_json(stem: &str, json: &crate::util::json::Json) {
    let dir = repo_root().join("target/bench-results");
    let _ = std::fs::create_dir_all(&dir);
    let _ = std::fs::write(dir.join(format!("{stem}.json")), json.to_string());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_grid_parses() {
        for s in table2_configs() {
            let c: CompressionConfig = s.parse().unwrap_or_else(|e| panic!("{s}: {e}"));
            assert!(c.validate().is_ok(), "{s}");
        }
    }

    #[test]
    fn gram_detection() {
        let s: CompressionConfig = "S-SparseGPT-4:8".parse().unwrap();
        assert!(needs_gram(&s));
        let w: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
        assert!(!needs_gram(&w));
        let sg: CompressionConfig = "SDQ-S7:8-1:8int8-6:8fp4".parse().unwrap();
        assert!(needs_gram(&sg));
    }

    #[test]
    fn model_path_layout() {
        std::env::remove_var("SDQ_ROOT");
        assert!(model_path("gpt-nano").ends_with("artifacts/models/gpt-nano.bin"));
    }
}
