//! HTTP/1.1 + SSE surface over a serving [`Frontend`] — the
//! single-engine [`GatewayHandle`](super::GatewayHandle) or the
//! multi-replica [`crate::router::RouterHandle`].
//!
//! Hand-rolled on [`std::net::TcpListener`], thread-per-connection, no
//! chunked encoding — the crate's only dependency is `anyhow`, and
//! this is the protocol subset per-token streaming actually needs.
//! Routes:
//!
//! | route                  | behavior                                   |
//! |------------------------|--------------------------------------------|
//! | `GET /healthz`         | `200 ok` — readiness probe                 |
//! | `GET /metrics`         | latest JSON metrics snapshot               |
//! | `POST /v1/cancel/<id>` | flag a live request for cancellation       |
//! | `POST /v1/completions` | submit + stream tokens as SSE              |
//!
//! **Keep-alive:** a client that sends `Connection: keep-alive` may
//! pipeline further requests on the same socket after any
//! *non-streaming* response (poll `/metrics`, fire `/v1/cancel/<id>`
//! without a reconnect). The server answers in kind and holds the
//! socket up to [`KEEPALIVE_IDLE`] between requests. Without the
//! header the connection closes after one response (the conservative
//! default for a hand-rolled server), and a completions stream always
//! closes at `[DONE]` — SSE owns the socket until the stream ends, so
//! there is nothing to reuse.
//!
//! The completions body is JSON: `{"prompt": "...}` required;
//! `max_new_tokens` (default 16), `temperature` (default 0.0 =
//! greedy), `seed` (u64; fixes the sampling RNG so non-greedy
//! completions reproduce across runs and replicas — defaults to the
//! server-assigned request id), `priority` (`interactive` |
//! `standard` | `batch`) optional.
//!
//! **Body limits:** requests larger than [`MAX_BODY`] are refused with
//! `413` and an unparseable `Content-Length` with `400`; both close
//! the connection, because the unread (or unknowable) body tail left
//! in the socket would desync the next keep-alive request.
//! The SSE stream opens with `data: {"id":N}` (N is the
//! `/v1/cancel/<id>` key), carries one `data: {"index":i,"token":t}`
//! per token, then a final `data: {"done":true,"cancelled":…,
//! "tokens":[…]}` and a `data: [DONE]` sentinel. A client that goes
//! away mid-stream is detected at the next write and its request is
//! cancelled — the KV-reclaim disconnect path, driven by the CI smoke
//! step with plain `curl`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use super::{Frontend, GatewayRequest, Priority, StreamEvent, SubmitError};
use crate::util::json::Json;

/// How long a keep-alive socket may sit idle between requests before
/// the server closes it.
pub const KEEPALIVE_IDLE: Duration = Duration::from_secs(30);

/// Largest accepted request body. Oversize bodies are refused up
/// front (`413` + close) instead of being read partially — a
/// truncated read leaves the tail in the socket and the next
/// pipelined request parses garbage.
pub const MAX_BODY: usize = 1 << 20;

/// Accept loop: one thread per connection, forever (the process model
/// is "kill the server to stop it" — CI does exactly that).
pub fn serve<F: Frontend>(listener: TcpListener, handle: F) -> std::io::Result<()> {
    for conn in listener.incoming() {
        let stream = conn?;
        let h = handle.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(stream, h);
        });
    }
    Ok(())
}

fn handle_conn<F: Frontend>(mut stream: TcpStream, h: F) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut served = 0u32;
    loop {
        let mut line = String::new();
        // After the first exchange the socket idles between pipelined
        // requests; any read error (timeout, reset, EOF) just closes.
        let n = match reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) if served == 0 => return Err(e),
            Err(_) => return Ok(()),
        };
        if n == 0 {
            return Ok(());
        }
        let mut parts = line.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let path = parts.next().unwrap_or("").to_string();

        let mut content_length = 0usize;
        let mut bad_content_length = false;
        let mut expect_continue = false;
        let mut keep = false;
        loop {
            let mut hl = String::new();
            if reader.read_line(&mut hl)? == 0 {
                break;
            }
            let t = hl.trim();
            if t.is_empty() {
                break;
            }
            let lower = t.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                match v.trim().parse() {
                    Ok(n) => content_length = n,
                    Err(_) => bad_content_length = true,
                }
            } else if lower.starts_with("expect:") && lower.contains("100-continue") {
                expect_continue = true;
            } else if lower.starts_with("connection:") && lower.contains("keep-alive") {
                keep = true;
            }
        }
        if bad_content_length {
            // The number of body bytes on the wire is unknowable; any
            // answer but an error-and-hangup desyncs the stream.
            return respond(
                &mut stream,
                400,
                "application/json",
                "{\"error\":\"bad Content-Length\"}\n",
                false,
            );
        }
        if content_length > MAX_BODY {
            return respond(
                &mut stream,
                413,
                "application/json",
                "{\"error\":\"body exceeds 1 MiB\"}\n",
                false,
            );
        }
        if expect_continue {
            stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
        }
        let mut body = vec![0u8; content_length];
        if !body.is_empty() {
            reader.read_exact(&mut body)?;
        }
        let body = String::from_utf8_lossy(&body).into_owned();

        match (method.as_str(), path.as_str()) {
            ("GET", "/healthz") => respond(&mut stream, 200, "text/plain", "ok\n", keep)?,
            ("GET", "/metrics") => {
                let snap = h.metrics_json();
                respond(&mut stream, 200, "application/json", &(snap + "\n"), keep)?
            }
            ("POST", p) if p.starts_with("/v1/cancel/") => {
                match p["/v1/cancel/".len()..].parse::<u64>() {
                    Ok(id) => {
                        let hit = h.cancel(id);
                        let j = Json::obj(vec![
                            ("id", Json::from(id as usize)),
                            ("cancelled", Json::from(hit)),
                        ]);
                        let status = if hit { 200 } else { 404 };
                        let body = j.to_string() + "\n";
                        respond(&mut stream, status, "application/json", &body, keep)?
                    }
                    Err(_) => respond(
                        &mut stream,
                        400,
                        "application/json",
                        "{\"error\":\"bad id\"}\n",
                        keep,
                    )?,
                }
            }
            // SSE owns the socket until the stream ends — always the
            // last exchange on this connection.
            ("POST", "/v1/completions") => return completions(&mut stream, &h, &body),
            _ => respond(&mut stream, 404, "text/plain", "not found\n", keep)?,
        }
        if !keep {
            return Ok(());
        }
        served += 1;
        if served == 1 {
            // SO_RCVTIMEO is per-socket, so this covers `reader` too.
            stream.set_read_timeout(Some(KEEPALIVE_IDLE))?;
        }
    }
}

fn completions<F: Frontend>(stream: &mut TcpStream, h: &F, body: &str) -> std::io::Result<()> {
    let parsed = match Json::parse(body) {
        Ok(j) => j,
        Err(_) => {
            return respond(
                stream,
                400,
                "application/json",
                "{\"error\":\"invalid JSON\"}\n",
                false,
            )
        }
    };
    let Some(prompt) = parsed.get("prompt").and_then(|v| v.as_str()).map(|s| s.as_bytes().to_vec())
    else {
        return respond(
            stream,
            400,
            "application/json",
            "{\"error\":\"missing prompt\"}\n",
            false,
        );
    };
    let req = GatewayRequest {
        prompt,
        max_new_tokens: parsed.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(16),
        temperature: parsed.get("temperature").and_then(|v| v.as_f64()).unwrap_or(0.0) as f32,
        seed: parsed.get("seed").and_then(|v| v.as_f64()).map(|s| s as u64),
        priority: parsed
            .get("priority")
            .and_then(|v| v.as_str())
            .and_then(Priority::parse)
            .unwrap_or(Priority::Standard),
    };
    let s = match h.submit(req) {
        Ok(s) => s,
        Err(SubmitError::QueueFull) => {
            return respond(
                stream,
                429,
                "application/json",
                "{\"error\":\"queue full\"}\n",
                false,
            )
        }
        Err(SubmitError::ShutDown) => {
            return respond(
                stream,
                503,
                "application/json",
                "{\"error\":\"shutting down\"}\n",
                false,
            )
        }
    };
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
          Cache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    // Opening event: the id is what `/v1/cancel/<id>` takes.
    let start = Json::obj(vec![("id", Json::from(s.id as usize))]);
    if write_event(stream, &start.to_string()).is_err() {
        s.cancel();
        return Ok(());
    }
    loop {
        match s.recv() {
            Some(StreamEvent::Token { index, token }) => {
                let j = Json::obj(vec![
                    ("index", Json::from(index)),
                    ("token", Json::from(token as usize)),
                ]);
                if write_event(stream, &j.to_string()).is_err() {
                    // Client went away: reclaim the request's KV.
                    s.cancel();
                    return Ok(());
                }
            }
            Some(StreamEvent::Done { cancelled, tokens }) => {
                let j = Json::obj(vec![
                    ("done", Json::from(true)),
                    ("cancelled", Json::from(cancelled)),
                    (
                        "tokens",
                        Json::Arr(tokens.iter().map(|t| Json::from(*t as usize)).collect()),
                    ),
                ]);
                let _ = write_event(stream, &j.to_string());
                let _ = write_event(stream, "[DONE]");
                return Ok(());
            }
            // Gateway shut down mid-stream.
            None => {
                s.cancel();
                return Ok(());
            }
        }
    }
}

fn write_event(stream: &mut TcpStream, data: &str) -> std::io::Result<()> {
    stream.write_all(format!("data: {data}\n\n").as_bytes())?;
    stream.flush()
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    ctype: &str,
    body: &str,
    keep: bool,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let conn = if keep { "keep-alive" } else { "close" };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()
}
