#!/usr/bin/env python3
"""Self-test for the bench-regression gate (``ci/check_bench.py``).

The gate is the last line of defense for three bench tables (serving
throughput, hotpath latency, gateway latency) — a bug here silently
disarms every perf regression check, so the gate itself is gated: CI
runs this file in a fast Python-only job. Each scenario builds a
results/baseline fixture in a temp directory and runs the real script
as a subprocess, asserting on exit status and output.

Run directly: ``python3 ci/test_check_bench.py``
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

CHECK = os.path.join(os.path.dirname(os.path.abspath(__file__)), "check_bench.py")


def serving_row(tput="100.0", hit="0.50", **over):
    row = {
        "Config": "Dense-WA16",
        "kv dtype": "f32",
        "spec": "off",
        "preempt": "off",
        "max_active": "4",
        "batched tok/s": tput,
        "prefix hit": hit,
    }
    row.update(over)
    return row


def latency_row(ttft="5.00", itl="2.00", **over):
    row = {
        "Config": "Dense-WA16",
        "kv dtype": "f32",
        "spec": "off",
        "preempt": "off",
        "arrival rate": "32",
        "p99 ttft ms": ttft,
        "p99 itl ms": itl,
    }
    row.update(over)
    return row


class GateHarness(unittest.TestCase):
    """Temp-dir fixture + subprocess runner shared by every scenario."""

    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.dir = self._tmp.name
        os.mkdir(os.path.join(self.dir, "ci"))

    def tearDown(self):
        self._tmp.cleanup()

    def write(self, relpath, rows, title="t"):
        path = os.path.join(self.dir, relpath)
        with open(path, "w") as f:
            json.dump({"title": title, "rows": rows}, f)
        return path

    def run_gate(self, *extra_args):
        proc = subprocess.run(
            [sys.executable, CHECK, *extra_args],
            cwd=self.dir,
            capture_output=True,
            text=True,
        )
        return proc

    def seed_passing_fixture(self):
        """Serving + latency tables, identical current and baseline
        (hotpath files absent → that gate skips with a note)."""
        self.write("BENCH_serving.json", [serving_row()])
        self.write("ci/bench_baseline.json", [serving_row()])
        self.write("BENCH_latency.json", [latency_row()])
        self.write("ci/bench_latency_baseline.json", [latency_row()])


class TestGate(GateHarness):
    def test_all_tables_within_tolerance_pass(self):
        self.seed_passing_fixture()
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("bench regression gate passed", proc.stdout)
        self.assertIn("1 latency baseline rows", proc.stdout)
        self.assertIn("hotpath gate skipped", proc.stdout)

    def test_serving_throughput_regression_fails(self):
        self.seed_passing_fixture()
        self.write("BENCH_serving.json", [serving_row(tput="60.0")])  # −40% > 25%
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 1, proc.stdout)
        self.assertIn("throughput regressed", proc.stdout)

    def test_latency_p99_regression_fails_one_sided(self):
        self.seed_passing_fixture()
        self.write("BENCH_latency.json", [latency_row(ttft="9.00")])  # +80% > 25%
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 1, proc.stdout)
        self.assertIn("p99 ttft ms regressed", proc.stdout)

    def test_latency_improvement_never_fails(self):
        self.seed_passing_fixture()
        self.write("BENCH_latency.json", [latency_row(ttft="0.10", itl="0.05")])
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 0, proc.stdout)

    def test_null_latency_baseline_is_record_only(self):
        self.seed_passing_fixture()
        self.write("ci/bench_latency_baseline.json", [latency_row(ttft=None, itl=None)])
        self.write("BENCH_latency.json", [latency_row(ttft="9999.0", itl="9999.0")])
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 0, proc.stdout)
        self.assertIn("not yet recorded", proc.stdout)

    def test_latency_coverage_is_symmetric(self):
        # A new current arm without a baseline row fails …
        self.seed_passing_fixture()
        self.write(
            "BENCH_latency.json",
            [latency_row(), latency_row(**{"kv dtype": "int8"})],
        )
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 1, proc.stdout)
        self.assertIn("missing from baseline", proc.stdout)
        # … and a baseline arm that disappeared from the current run
        # fails too.
        self.write("BENCH_latency.json", [latency_row()])
        self.write(
            "ci/bench_latency_baseline.json",
            [latency_row(), latency_row(**{"preempt": "on"})],
        )
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 1, proc.stdout)
        self.assertIn("missing from current results", proc.stdout)

    def test_absent_latency_files_skip_the_gate(self):
        self.write("BENCH_serving.json", [serving_row()])
        self.write("ci/bench_baseline.json", [serving_row()])
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertIn("latency gate skipped", proc.stdout)

    def test_update_with_missing_results_file_is_not_a_traceback(self):
        # The --update edge: no bench has run, so no BENCH_*.json
        # exists. The refresh must skip each table with a note — exit 0,
        # no exception — and leave the committed baselines untouched.
        baseline = self.write("ci/bench_baseline.json", [serving_row()])
        with open(baseline) as f:
            before = f.read()
        proc = self.run_gate("--update")
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        self.assertNotIn("Traceback", proc.stderr)
        self.assertIn("BENCH_serving.json absent", proc.stdout)
        self.assertIn("BENCH_latency.json absent", proc.stdout)
        with open(baseline) as f:
            self.assertEqual(f.read(), before, "baseline must be untouched")

    def test_update_refreshes_present_tables(self):
        self.seed_passing_fixture()
        self.write("BENCH_latency.json", [latency_row(ttft="7.77")])
        proc = self.run_gate("--update")
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        with open(os.path.join(self.dir, "ci/bench_latency_baseline.json")) as f:
            refreshed = json.load(f)
        self.assertEqual(refreshed["rows"][0]["p99 ttft ms"], "7.77")
        # Serving baseline refreshed too; hotpath (absent) skipped.
        self.assertIn("baseline refreshed from BENCH_serving.json", proc.stdout)
        self.assertIn("BENCH_hotpath.json absent", proc.stdout)

    def test_hotpath_regression_still_fails(self):
        # The merged bench job runs all three tables through one
        # invocation — make sure extending the script kept the hotpath
        # gate armed.
        self.seed_passing_fixture()
        self.write("BENCH_hotpath.json", [{"bench": "gemm", "median ms": "2.0"}])
        self.write("ci/bench_hotpath_baseline.json", [{"bench": "gemm", "median ms": "1.0"}])
        proc = self.run_gate()
        self.assertEqual(proc.returncode, 1, proc.stdout)
        self.assertIn("latency regressed", proc.stdout)


if __name__ == "__main__":
    unittest.main(verbosity=2)
