//! Fig. 1 — Pareto frontier: effective compute throughput vs perplexity
//! increase, for sparsification-only, quantization-only and SDQ on one
//! GPT and one LLaMA model (paper: OPT-6.7B / LLaMA-7B).

use sdq::harness;
use sdq::sdq::config::CompressionConfig;
use sdq::util::bench::Table;

fn main() {
    if !harness::artifacts_ready() {
        return;
    }
    let ds = harness::load_dataset().expect("corpus");
    for mname in ["gpt-micro", "llama-micro"] {
        let model = match harness::load_model(mname) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("skip {mname}: {e}");
                continue;
            }
        };
        let ecfg = harness::eval_cfg_for(&model, false);
        let mut table = Table::new(
            &format!("Fig 1: throughput vs Δppl Pareto — {mname}"),
            &["Configuration", "Family", "EffTput", "weight MiB", "vs dense", "ppl", "Δppl%"],
        );
        let mut baseline = f64::NAN;
        for cfg_str in harness::table2_configs() {
            let cfg: CompressionConfig = cfg_str.parse().unwrap();
            let family = if cfg_str.starts_with("SDQ") {
                "SDQ"
            } else if cfg_str.starts_with("S-") {
                "sparsify-only"
            } else if cfg_str.starts_with("Q-") {
                "quantize-only"
            } else {
                "baseline"
            };
            match harness::eval_config(&model, &ds, &cfg, ecfg) {
                Ok(r) => {
                    if cfg_str == "Dense-WA16" {
                        baseline = r.ppl.ppl;
                    }
                    let delta = (r.ppl.ppl - baseline) / baseline * 100.0;
                    eprintln!("  {mname} {cfg_str}: {:.3} ({delta:+.2}%)", r.ppl.ppl);
                    // Actual packed resident bytes (codes + scales +
                    // sparse metadata), not the analytic bits/weight —
                    // `vs dense` is the honest compression ratio.
                    table.row(vec![
                        cfg_str.to_string(),
                        family.to_string(),
                        format!("{:.2}", r.effective_throughput),
                        format!("{:.2}", r.weight_bytes as f64 / (1024.0 * 1024.0)),
                        format!("{:.2}x", r.dense_weight_bytes as f64 / r.weight_bytes as f64),
                        format!("{:.3}", r.ppl.ppl),
                        format!("{delta:+.2}"),
                    ]);
                }
                Err(e) => eprintln!("  {mname} {cfg_str}: {e}"),
            }
        }
        table.print();
        table.save_json(&format!("fig1_pareto_{mname}"));
    }
    println!("\nExpected shape: at 4x only SDQ rows stay near Δppl 0 (paper Fig. 1).");
}
