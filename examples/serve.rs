//! Serving demo: batched request serving with latency metrics, native
//! engine + the AOT PJRT scoring path side by side.
//!
//! Run: `cargo run --release --example serve -- [--model gpt-micro]
//!       [--config SDQ-W7:8-1:8int8-6:8fp4] [--requests 16] [--max-new 32]
//!       [--kv-dtype f32|fp8-e4m3|int8|int4]
//!       [--spec off|ngram|sdq-draft] [--spec-k 4]
//!       [--draft-config Q-VSQuant-WAint4]
//!       [--preempt] [--max-resident 32] [--no-packed-weights]`
//!
//! Flags:
//! * `--no-packed-weights` — strip the packed quantized weight planes
//!   (`QuantMat` codes + fp8 scales) after compression, forcing every
//!   GEMM back onto the dequantized dense f32 view. Greedy output is
//!   bit-identical either way; only `w_streamed` / `w_avoided` in the
//!   metrics move (the packed int8 plane streams ≥3.5× fewer weight
//!   bytes per decode round).
//! * `--preempt` — preemptive scheduling: admission charges resident
//!   KV blocks instead of worst-case footprints (oversubscription), and
//!   under pressure the scheduler swaps the lowest-priority active
//!   sequence out (and later back in) instead of refusing work. Greedy
//!   output is bit-identical with or without it.
//! * `--max-resident` — cap the paged pool's admission budget at this
//!   many blocks (tighter of this and the byte budget): the lever for
//!   demonstrating preemption under deliberate KV pressure.
//! * `--spec` — speculative decoding mode. `ngram` drafts from the
//!   sequence's own bytes (zero extra weights); `sdq-draft` builds a
//!   second, more aggressively compressed model from the same base
//!   weights (see `--draft-config`) and lets it propose tokens the
//!   serving model verifies in one fused pass. Speculation preserves
//!   greedy output bit-for-bit, so `--spec` forces temperature 0 on
//!   the demo requests (sampled requests never speculate).
//! * `--spec-k` — drafted tokens per sequence per round (default 4).
//! * `--draft-config` — compression config for the `sdq-draft` draft
//!   model (default `Q-VSQuant-WAint4`, deliberately rougher than the
//!   serving config: drafts are cheap, verification keeps them honest).
//! * `--gateway` — run the streaming HTTP/SSE serving gateway instead
//!   of the one-shot batch demo: `cargo run --release --example serve
//!   -- --gateway [--port 8090] [--queue-capacity 256]
//!   [--round-delay-ms 0] [--max-active 8] [--kv-dtype int8]
//!   [--preempt] [--max-resident 32] [--spec off|ngram]`. Serves
//!   `POST /v1/completions` (SSE token stream), `POST /v1/cancel/<id>`,
//!   `GET /metrics`, `GET /healthz` until killed. Falls back to the
//!   synthetic model when artifacts are absent, so the CI smoke step
//!   can exercise the full submit → stream → cancel → reclaim loop
//!   without `make artifacts`.
//! * `--swap-dir <path>` (gateway mode) — enable the disk spill tier
//!   for preempted sequences; with `--swap-resident-budget N` host
//!   bytes of resident snapshots allowed before spilling (default 0 =
//!   spill everything under pressure).
//! * `--replicas N` (gateway mode) — serve through the prefix-aware
//!   multi-engine router over N engine replicas; each replica gets a
//!   private subdirectory under `--swap-dir`. `--migrate-after K`
//!   additionally migrates every stream once to the least-loaded peer
//!   after K generated tokens (K=1 ≈ prefill→decode disaggregation).

use sdq::coordinator::{batcher::BatchPolicy, Engine, Request};
use sdq::data::Split;
use sdq::gateway::{Gateway, GatewayOpts};
use sdq::harness;
use sdq::spec::{SdqDrafter, SpecPolicy};
use sdq::util::cli::Args;

/// `--gateway` mode: continuous-batching streaming front-end over the
/// same scheduler the batch demo uses. Blocks in the accept loop until
/// the process is killed.
fn gateway_main(args: &Args) -> sdq::Result<()> {
    let mname = args.get_or("model", "gpt-micro").to_string();
    let model = if harness::artifacts_ready() {
        harness::load_model(&mname)?
    } else {
        eprintln!("artifacts missing: gateway serving the synthetic model");
        sdq::model::testutil::synth_model()
    };
    let kv_dtype = match args.get("kv-dtype") {
        Some(s) => Some(sdq::kv::KvDtype::parse(s)?),
        None => None,
    };
    let policy = BatchPolicy {
        max_active: args.get_usize("max-active", 8)?,
        kv_dtype,
        preempt: args.has("preempt"),
        max_resident_blocks: args.get("max-resident").map(|s| s.parse()).transpose()?,
        ..Default::default()
    };
    let spec_mode = args.get_or("spec", "off").to_string();
    let spec = match spec_mode.as_str() {
        "off" => None,
        "ngram" => Some(SpecPolicy::ngram(args.get_usize("spec-k", 4)?)),
        other => anyhow::bail!("--gateway supports --spec off | ngram (got {other})"),
    };
    let opts = GatewayOpts {
        queue_capacity: args.get_usize("queue-capacity", 256)?,
        round_delay: std::time::Duration::from_millis(
            args.get_usize("round-delay-ms", 0)? as u64,
        ),
    };
    let port = args.get_usize("port", 8090)?;
    let swap = match args.get("swap-dir") {
        None => None,
        Some(p) => Some(sdq::swap::SwapConfig {
            dir: Some(sdq::swap::SwapDir::new(p)?),
            resident_budget_bytes: args.get_usize("swap-resident-budget", 0)?,
            ..Default::default()
        }),
    };
    let replicas = args.get_usize("replicas", 1)?;
    let migrate_after = args.get("migrate-after").map(|s| s.parse()).transpose()?;
    let listener = std::net::TcpListener::bind(("127.0.0.1", port as u16))?;
    if replicas > 1 {
        anyhow::ensure!(
            spec.is_none(),
            "--replicas needs --spec off (drafters are per-engine)"
        );
        let ropts = sdq::router::RouterOpts { migrate_after };
        let router = sdq::router::Router::start(&model, replicas, policy, opts, ropts, swap)?;
        println!(
            "router listening on http://127.0.0.1:{port} \
             ({replicas} replicas, kv {}, preempt {}, migrate-after {migrate_after:?})",
            args.get_or("kv-dtype", "model-default"),
            policy.preempt,
        );
        sdq::gateway::http::serve(listener, router.handle())?;
        return Ok(());
    }
    let gw = Gateway::start_with_swap(model, policy, spec, opts, swap.unwrap_or_default());
    println!(
        "gateway listening on http://127.0.0.1:{port} \
         (kv {}, preempt {}, spec {spec_mode}, queue {})",
        args.get_or("kv-dtype", "model-default"),
        policy.preempt,
        opts.queue_capacity,
    );
    sdq::gateway::http::serve(listener, gw.handle())?;
    Ok(())
}

fn main() -> sdq::Result<()> {
    let args = Args::parse();
    if args.has("gateway") {
        return gateway_main(&args);
    }
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let mname = args.get_or("model", "gpt-micro").to_string();
    let cfg_str = args.get_or("config", "SDQ-W7:8-1:8int8-6:8fp4").to_string();
    let n_req = args.get_usize("requests", 16)?;
    let max_new = args.get_usize("max-new", 32)?;
    let spec_mode = args.get_or("spec", "off").to_string();
    let spec_k = args.get_usize("spec-k", 4)?;
    // Fail on flag typos before the expensive load/calibrate/compress
    // pipeline runs (the draft config parses here too).
    if !matches!(spec_mode.as_str(), "off" | "ngram" | "sdq-draft") {
        anyhow::bail!("unknown --spec mode: {spec_mode} (expected off | ngram | sdq-draft)");
    }
    let draft_cfg_str = args.get_or("draft-config", "Q-VSQuant-WAint4").to_string();
    let draft_cfg: Option<sdq::sdq::config::CompressionConfig> = (spec_mode == "sdq-draft")
        .then(|| draft_cfg_str.parse().map_err(|e: String| anyhow::anyhow!(e)))
        .transpose()?;

    let cfg = cfg_str.parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let mut model = harness::load_model(&mname)?;
    // Pre-compression weights seed the drafter; only clone them when a
    // draft model will actually be built.
    let base = (spec_mode == "sdq-draft").then(|| model.clone());
    let ds = harness::load_dataset()?;
    let calib = harness::calibrate(&model, &ds, 1024, harness::needs_gram(&cfg));
    model.compress(&cfg, &calib)?;
    if args.has("no-packed-weights") {
        model.strip_packed_weights();
        println!("packed weight planes stripped: GEMMs run on the dense f32 view");
    }
    let spec = match spec_mode.as_str() {
        "off" => None,
        "ngram" => Some(SpecPolicy::ngram(spec_k)),
        _ => {
            let base = base.as_ref().expect("cloned for sdq-draft above");
            let draft_cfg = draft_cfg.as_ref().expect("parsed for sdq-draft above");
            let drafter = SdqDrafter::from_base(base, draft_cfg, &calib)?;
            println!("drafting with a {draft_cfg_str} copy of {mname}");
            Some(SpecPolicy::sdq(spec_k, drafter))
        }
    };
    println!("serving {mname} under {cfg_str} (spec: {spec_mode})");

    let test = ds.split(Split::Test);
    let reqs: Vec<Request> = (0..n_req)
        .map(|i| {
            let start = (i * 709) % (test.len() - 65);
            let r = Request::new(i as u64, test[start..start + 32].to_vec(), max_new);
            // Speculation only applies to greedy requests; keep the
            // sampled demo flavour when it is off.
            if spec.is_some() {
                r
            } else {
                r.with_temperature(0.8)
            }
        })
        .collect();
    // Quantized KV storage (fp8-e4m3 / int8) stores pool blocks at ~¼
    // the bytes of f32 — same budget, ~4× the admission head-room. An
    // absent flag inherits the model config's `kv_dtype` (policy `None`)
    // rather than forcing f32.
    let kv_dtype = match args.get("kv-dtype") {
        Some(s) => Some(sdq::kv::KvDtype::parse(s)?),
        None => None,
    };
    let policy = BatchPolicy {
        max_active: args.get_usize("max-active", 8)?,
        kv_dtype,
        preempt: args.has("preempt"),
        max_resident_blocks: args.get("max-resident").map(|s| s.parse()).transpose()?,
        ..Default::default()
    };
    let (resps, metrics) = Engine::run_batch_spec(model, policy, spec, reqs);
    for r in resps.iter().take(4) {
        println!(
            "[req {}] ttft {:>6.1}ms total {:>7.1}ms  {:.40}…",
            r.id,
            r.timing.ttft.as_secs_f64() * 1e3,
            r.timing.total.as_secs_f64() * 1e3,
            r.text().replace('\n', " ")
        );
    }
    println!("\nnative engine: {}", metrics.summary());
    println!(
        "decode batches: width mean {:.2} / max {} → occupancy {:.0}% of {} slots, \
         KV peak {:.1} KiB (paged pool, referenced + cached blocks)",
        metrics.mean_decode_width(),
        metrics.decode_width_max,
        metrics.decode_occupancy(policy.max_active) * 100.0,
        policy.max_active,
        metrics.kv_bytes_peak as f64 / 1024.0,
    );
    println!(
        "paged KV [{} blocks of {} B, dtype {}]: prefill width mean {:.2}, \
         pool util peak {:.2}, prefix hit-rate {:.2}, evictions {}, COW copies {}",
        metrics.pool_budget_blocks,
        metrics.pool_block_bytes,
        metrics.kv_dtype,
        metrics.mean_prefill_width(),
        metrics.pool_utilization_peak,
        metrics.prefix_hit_rate(),
        metrics.kv_evictions,
        metrics.kv_cow_copies,
    );
    if policy.preempt {
        println!(
            "preemption [budget {} blocks]: {} swap-outs / {} swap-ins, {:.1} KiB swapped, \
             {} re-prefilled tokens (rate {:.2}/resume), preempt rate {:.3}/round",
            metrics.pool_budget_blocks,
            metrics.preemptions,
            metrics.resumes,
            metrics.swap_bytes as f64 / 1024.0,
            metrics.resume_reprefill_tokens,
            metrics.resume_reprefill_rate(),
            metrics.preemption_rate(),
        );
    }
    if metrics.spec_drafter != "off" {
        println!(
            "speculative decode [{}, k={}]: drafted {}, accepted {} (rate {:.2}), \
             {:.2} tokens/round",
            metrics.spec_drafter,
            spec_k,
            metrics.spec_drafted,
            metrics.spec_accepted,
            metrics.spec_acceptance_rate(),
            metrics.tokens_per_round(),
        );
    }

    // PJRT batch-scoring path: the AOT SDQ forward (fixed [4, 64] shape).
    let art_name = format!("model_fwd_sdq_{mname}");
    let art = sdq::runtime::artifact_path(&harness::repo_root(), &art_name);
    let bundle_path =
        harness::repo_root().join(format!("artifacts/models/{mname}.sdq.bin"));
    if art.exists() && bundle_path.exists() {
        let mut rt = sdq::runtime::PjrtRuntime::cpu()?;
        rt.load_hlo("fwd", &art)?;
        let bundle = sdq::artifacts::load_weights(&bundle_path)?;
        let (b, s) = (4usize, 64usize);
        let tokens: Vec<u8> = test[..b * s].to_vec();
        let mut inputs = vec![sdq::runtime::Input::tokens(&tokens, b, s)];
        for (_n, m) in bundle.tensors.iter() {
            inputs.push(sdq::runtime::Input::F32(m.clone()));
        }
        let t0 = std::time::Instant::now();
        let iters = 5;
        let mut out_len = 0;
        for _ in 0..iters {
            let out = rt.execute("fwd", &inputs)?;
            out_len = out[0].len();
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "PJRT AOT scoring ({art_name}): {} logits / batch, {:.1} ms/batch, {:.0} tok/s prefill",
            out_len,
            dt * 1e3,
            (b * s) as f64 / dt
        );
    } else {
        println!("(PJRT path skipped: {} missing)", art.display());
    }
    Ok(())
}
