//! N-gram prompt/self-lookup drafting (zero extra weights).
//!
//! The cheapest useful drafter: if the sequence's recent suffix has
//! occurred earlier in its own bytes (prompt *or* generation), propose
//! whatever followed that occurrence. Repetitive continuations —
//! templated text, code, looping generations, shared system prompts —
//! make this surprisingly effective, and a miss costs nothing: the
//! drafter abstains and the round degrades to plain decode.

use super::Drafter;

/// Longest-suffix self-lookup drafter.
///
/// For each round it tries suffix lengths `max_match` down to
/// `min_match`; the first length with an earlier occurrence in the
/// context wins, preferring the **most recent** occurrence (recency
/// tracks the current generation mode better than the first). The
/// continuation after the match — clipped to the context end and the
/// requested `k` — is the draft. Matches may overlap the suffix region;
/// only the suffix itself is excluded. O(`max_match` · len) scan per
/// call, fine at serving-context scale and free of any index to keep
/// coherent across rollbacks.
#[derive(Clone, Copy, Debug)]
pub struct NGramDrafter {
    /// Longest suffix n-gram tried first.
    pub max_match: usize,
    /// Shortest n-gram worth trusting (below this, abstain).
    pub min_match: usize,
}

impl Default for NGramDrafter {
    fn default() -> Self {
        NGramDrafter { max_match: 4, min_match: 2 }
    }
}

impl Drafter for NGramDrafter {
    fn name(&self) -> &'static str {
        "ngram"
    }

    fn draft(&mut self, context: &[u8], k: usize) -> Vec<u8> {
        let len = context.len();
        if k == 0 || self.min_match == 0 || len < self.min_match + 1 {
            return Vec::new();
        }
        let hi = self.max_match.min(len - 1);
        for n in (self.min_match..=hi).rev() {
            let suffix = &context[len - n..];
            // Most recent earlier occurrence; `i < len - n` excludes the
            // suffix itself, and `i + n < len` means the continuation is
            // never empty.
            for i in (0..len - n).rev() {
                if &context[i..i + n] == suffix {
                    let start = i + n;
                    return context[start..(start + k).min(len)].to_vec();
                }
            }
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draft(ctx: &[u8], k: usize) -> Vec<u8> {
        NGramDrafter::default().draft(ctx, k)
    }

    #[test]
    fn abstains_without_a_match() {
        assert!(draft(b"abcdefgh", 4).is_empty());
        assert!(draft(b"", 4).is_empty());
        assert!(draft(b"aa", 4).is_empty(), "context too short for suffix + prior");
        assert!(draft(b"abab", 0).is_empty(), "k = 0 never drafts");
    }

    #[test]
    fn proposes_continuation_of_repeated_motif() {
        // Suffix "ab" matched earlier; what followed was "cdx".
        let got = draft(b"abcdxzab", 3);
        assert_eq!(got, b"cdx");
    }

    #[test]
    fn prefers_longest_match() {
        // Suffix "bcd" (len 3) matches at 1 → continuation "Z"; the
        // shorter "cd" match later in the context must lose to it.
        let ctx = b"abcdZqcdWbcd";
        assert_eq!(draft(ctx, 2), b"Zq");
    }

    #[test]
    fn prefers_most_recent_among_equal_lengths() {
        // "ab" occurs at 0 (→ "X...") and 3 (→ "Y..."); recency wins.
        let ctx = b"abXabYzab";
        assert_eq!(draft(ctx, 1), b"Y");
    }

    #[test]
    fn clips_at_context_end_and_k() {
        // Overlapping self-match in a constant run: always ≥1 token.
        let ctx = &[7u8, 0, 0, 0, 0];
        let got = draft(ctx, 4);
        assert!(!got.is_empty() && got.iter().all(|t| *t == 0), "{got:?}");
        // k clips the continuation.
        assert_eq!(draft(b"abQRSTab", 2), b"QR");
    }

    #[test]
    fn min_match_zero_is_inert() {
        let mut d = NGramDrafter { max_match: 4, min_match: 0 };
        assert!(d.draft(b"ababab", 3).is_empty());
    }
}
