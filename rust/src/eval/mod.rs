//! Evaluation harness: perplexity (§6.2) and zero-shot tasks (§6.3).
//!
//! Perplexity follows the paper's raw-WikiText2 protocol on our corpus:
//! non-overlapping windows over the held-out split, next-token NLL,
//! `ppl = exp(mean nll)`.
//!
//! Zero-shot evaluation mirrors LM-Eval's multiple-choice scoring
//! (length-normalized continuation log-likelihood, argmax over choices)
//! over six synthetic tasks standing in for BoolQ / HellaSwag /
//! WinoGrande / ARC-e / ARC-c / PIQA (see DESIGN.md substitutions).

pub mod zeroshot;


use crate::data::{Split, TokenDataset};
use crate::model::Model;

/// Perplexity evaluation result.
#[derive(Clone, Copy, Debug)]
pub struct PplResult {
    pub ppl: f64,
    pub mean_nll: f64,
    pub tokens: usize,
}

/// Evaluate perplexity on a split, capped at `max_tokens` target tokens.
pub fn perplexity(
    model: &Model,
    ds: &TokenDataset,
    split: Split,
    batch: usize,
    seq: usize,
    max_tokens: usize,
) -> PplResult {
    let mut nll = 0.0f64;
    let mut tokens = 0usize;
    for (inp, tgt) in ds.windows(split, batch, seq) {
        let b = inp.len() / seq;
        nll += model.nll_sum(&inp, &tgt, b, seq);
        tokens += tgt.len();
        if tokens >= max_tokens {
            break;
        }
    }
    let mean = if tokens > 0 { nll / tokens as f64 } else { f64::NAN };
    PplResult { ppl: mean.exp(), mean_nll: mean, tokens }
}

/// Percentage perplexity increase vs a baseline (the paper's headline
/// quality metric; MLPerf's bar is 1%).
pub fn ppl_increase_pct(baseline: f64, compressed: f64) -> f64 {
    (compressed - baseline) / baseline * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_corpus, CorpusCfg};
    use crate::model::testutil::tiny_model;
    use crate::model::Arch;

    #[test]
    fn perplexity_of_random_model_near_uniform() {
        let m = tiny_model(Arch::Gpt, 1);
        let corpus = generate_corpus(&CorpusCfg {
            bytes: 40_000,
            vocab_words: 100,
            successors: 8,
            seed: 3,
        });
        let ds = TokenDataset::new(corpus);
        let r = perplexity(&m, &ds, Split::Test, 4, 32, 512);
        assert!(r.tokens >= 512);
        // An untrained model should be in the vicinity of uniform (256);
        // random inits give a broad band.
        assert!(r.ppl > 100.0 && r.ppl < 400.0, "ppl {}", r.ppl);
    }

    #[test]
    fn ppl_increase_math() {
        assert!((ppl_increase_pct(10.0, 10.1) - 1.0).abs() < 1e-9);
        assert!(ppl_increase_pct(10.0, 9.9) < 0.0);
    }
}
