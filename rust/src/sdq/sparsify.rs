//! Stage 1 — N:M structured sparsification.
//!
//! Three pruners, mirroring the paper's §5 Stage 1:
//!
//! * **Magnitude** — keep the largest |w| per M-block (Han et al., 2015).
//! * **Wanda** — keep the largest |w|·‖X_j‖₂ per M-block (Sun et al.,
//!   2023); needs calibration column norms.
//! * **SparseGPT** — OBS pruning with Hessian-aware mask selection *and*
//!   weight update to compensate the pruning error (Frantar & Alistarh,
//!   2023, Alg. 1); needs the calibration Gram matrix.
//!
//! All pruners operate on `[out_features, in_features]` weights with the
//! N:M constraint along the input (reduction) dimension.

use anyhow::{anyhow, bail};

use crate::util::par::par_chunks_mut;

use super::calib::LayerStats;
use super::config::{SparsifyCfg, SparsifyMethod};
use super::nm::{topn_block_mask, NmPattern};
use crate::tensor::Matrix;
use crate::Result;

/// SparseGPT lazy-update block size (columns). Must be a multiple of
/// every supported M; 128 covers M ∈ {4, 8, 16}.
const SPARSEGPT_BLOCK: usize = 128;

/// Relative Hessian dampening (SparseGPT's `percdamp`).
const PERC_DAMP: f64 = 0.01;

/// Prune `w` in place to `cfg.pattern`.
///
/// `stats` supplies calibration data: column norms for Wanda, Gram matrix
/// for SparseGPT. Magnitude needs none.
pub fn sparsify(w: &mut Matrix, cfg: SparsifyCfg, stats: Option<&LayerStats>) -> Result<()> {
    if cfg.pattern.is_dense() {
        return Ok(());
    }
    match cfg.method {
        SparsifyMethod::Magnitude => {
            mask_prune(w, cfg.pattern, |row, _| row.iter().map(|v| v.abs()).collect());
            Ok(())
        }
        SparsifyMethod::Wanda => {
            let st = stats.ok_or_else(|| anyhow!("Wanda requires calibration stats"))?;
            if st.in_features != w.cols {
                bail!("calibration width {} != weight width {}", st.in_features, w.cols);
            }
            let norms = st.col_norms();
            mask_prune(w, cfg.pattern, |row, _| {
                row.iter().zip(&norms).map(|(v, n)| v.abs() * n.max(1e-12)).collect()
            });
            Ok(())
        }
        SparsifyMethod::SparseGpt => {
            let st = stats.ok_or_else(|| anyhow!("SparseGPT requires calibration stats"))?;
            let gram = st
                .finalized_gram()
                .ok_or_else(|| anyhow!("SparseGPT requires Gram collection (with_gram)"))?;
            sparsegpt_prune(w, &gram, cfg.pattern)
        }
    }
}

/// Generic mask-based pruning: compute per-row scores, keep block-top-N.
fn mask_prune<F>(w: &mut Matrix, pat: NmPattern, score_fn: F)
where
    F: Fn(&[f32], usize) -> Vec<f32> + Sync,
{
    let cols = w.cols;
    par_chunks_mut(&mut w.data, cols, |r, row| {
        let scores = score_fn(row, r);
        let mut mask = vec![false; cols];
        topn_block_mask(&scores, pat, &mut mask);
        for (v, keep) in row.iter_mut().zip(&mask) {
            if !keep {
                *v = 0.0;
            }
        }
    });
}

/// SparseGPT: blocked OBS pruning with error compensation.
///
/// Follows Algorithm 1 of the paper: `U = chol(H⁻¹)` (upper), process
/// columns left→right in lazy-update blocks; inside a block, choose the
/// N:M mask per M-column group by the saliency `w²/U_cc²`, zero the
/// pruned weights, and fold the error `w/U_cc` into all not-yet-processed
/// columns.
fn sparsegpt_prune(w: &mut Matrix, gram: &super::linalg::SquareMat, pat: NmPattern) -> Result<()> {
    let d = w.cols;
    let rows = w.rows;
    assert_eq!(gram.d, d);
    if d % pat.m != 0 {
        bail!("in_features {d} not a multiple of M={}", pat.m);
    }
    let mut h = gram.clone();

    // Dead input columns: never activated ⇒ weight is free to prune.
    for i in 0..d {
        if h.at(i, i) == 0.0 {
            *h.at_mut(i, i) = 1.0;
            for r in 0..rows {
                *w.at_mut(r, i) = 0.0;
            }
        }
    }
    h.add_diag(PERC_DAMP * h.diag_mean());
    let hinv = h.spd_inverse().ok_or_else(|| anyhow!("Hessian not SPD after dampening"))?;
    let u = hinv.cholesky_upper().ok_or_else(|| anyhow!("H⁻¹ not SPD"))?;

    let bs = SPARSEGPT_BLOCK.max(pat.m);
    debug_assert_eq!(bs % pat.m, 0);

    // Work row-parallel: each output row prunes independently given the
    // shared U factor (the per-row masks differ, the updates are row-local).
    par_chunks_mut(&mut w.data, d, |_r, row| {
        let mut err = vec![0.0f64; bs];
        let mut i1 = 0;
        while i1 < d {
            let i2 = (i1 + bs).min(d);
            let count = i2 - i1;
            err[..count].fill(0.0);
            let mut mask = vec![true; count];
            for j in i1..i2 {
                let jj = j - i1;
                if jj % pat.m == 0 {
                    // Select the N:M mask for columns j..j+M by saliency.
                    let m_end = (jj + pat.m).min(count);
                    let mut scores: Vec<(f64, usize)> = (jj..m_end)
                        .map(|c| {
                            let ucc = u.at(i1 + c, i1 + c);
                            let wv = row[i1 + c] as f64;
                            (wv * wv / (ucc * ucc), c)
                        })
                        .collect();
                    // Prune the smallest (M-N) saliencies.
                    scores.sort_by(|a, b| {
                        a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal)
                    });
                    let prune_count = (m_end - jj).saturating_sub(pat.n);
                    for c in jj..m_end {
                        mask[c] = true;
                    }
                    for &(_, c) in scores.iter().take(prune_count) {
                        mask[c] = false;
                    }
                }
                let e = if mask[jj] {
                    0.0
                } else {
                    let ujj = u.at(j, j);
                    let e = row[j] as f64 / ujj;
                    row[j] = 0.0;
                    e
                };
                err[jj] = e;
                if e != 0.0 {
                    // Fold the pruning error into the rest of this block.
                    for k in j + 1..i2 {
                        row[k] -= (e * u.at(j, k)) as f32;
                    }
                }
            }
            // Lazy update of all later columns: W[r, i2..] -= err · U[i1..i2, i2..]
            for (jj, &e) in err[..count].iter().enumerate() {
                if e == 0.0 {
                    continue;
                }
                let j = i1 + jj;
                for k in i2..d {
                    row[k] -= (e * u.at(j, k)) as f32;
                }
            }
            i1 = i2;
        }
    });
    Ok(())
}

/// Pruning-quality diagnostic: relative output error `‖(W−Ŵ)X‖/‖WX‖`
/// proxied through the Gram matrix: `tr(ΔW H ΔWᵀ) / tr(W H Wᵀ)`.
pub fn output_error_proxy(
    orig: &Matrix,
    pruned: &Matrix,
    gram: &super::linalg::SquareMat,
) -> f64 {
    assert_eq!(orig.rows, pruned.rows);
    assert_eq!(orig.cols, pruned.cols);
    let d = orig.cols;
    let quad = |w: &Matrix, dw: bool| -> f64 {
        let mut acc = 0.0;
        for r in 0..w.rows {
            let row_a = orig.row(r);
            let row_b = pruned.row(r);
            // v = ΔW row or W row
            let v: Vec<f64> = (0..d)
                .map(|i| {
                    if dw {
                        (row_a[i] - row_b[i]) as f64
                    } else {
                        row_a[i] as f64
                    }
                })
                .collect();
            for i in 0..d {
                if v[i] == 0.0 {
                    continue;
                }
                let gi = &gram.data[i * d..(i + 1) * d];
                let mut s = 0.0;
                for j in 0..d {
                    s += gi[j] * v[j];
                }
                acc += v[i] * s;
            }
        }
        acc
    };
    let num = quad(orig, true);
    let den = quad(orig, false).max(1e-30);
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdq::calib::CalibStats;
    use crate::util::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from_u64(seed);
        Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    fn calib(rows: usize, d: usize, seed: u64, gram: bool) -> CalibStats {
        let mut st = CalibStats::new(gram);
        st.observe("l", &rand_matrix(rows, d, seed));
        st
    }

    #[test]
    fn magnitude_respects_pattern() {
        let mut w = rand_matrix(8, 32, 1);
        let pat = NmPattern::new(2, 8);
        sparsify(
            &mut w,
            SparsifyCfg { method: SparsifyMethod::Magnitude, pattern: pat },
            None,
        )
        .unwrap();
        assert!(pat.check(&w));
        // keeps exactly N per block here (random weights, no zeros)
        assert!((w.zero_fraction() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn magnitude_keeps_largest() {
        let mut w = Matrix::from_vec(1, 4, vec![0.1, -5.0, 0.2, 3.0]);
        sparsify(
            &mut w,
            SparsifyCfg { method: SparsifyMethod::Magnitude, pattern: NmPattern::new(2, 4) },
            None,
        )
        .unwrap();
        assert_eq!(w.data, vec![0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn wanda_uses_activation_norms() {
        // Column 0 weight is small but its activation norm is huge.
        let mut w = Matrix::from_vec(1, 4, vec![0.1, 0.5, 0.4, 0.3]);
        let mut st = CalibStats::new(false);
        st.observe("l", &Matrix::from_vec(1, 4, vec![100.0, 0.1, 0.1, 0.1]));
        let cfg = SparsifyCfg { method: SparsifyMethod::Wanda, pattern: NmPattern::new(1, 4) };
        sparsify(&mut w, cfg, st.get("l")).unwrap();
        assert_eq!(w.data, vec![0.1, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn wanda_requires_stats() {
        let mut w = rand_matrix(2, 8, 3);
        let cfg = SparsifyCfg { method: SparsifyMethod::Wanda, pattern: NmPattern::new(4, 8) };
        assert!(sparsify(&mut w, cfg, None).is_err());
    }

    #[test]
    fn sparsegpt_respects_pattern_and_beats_magnitude() {
        let d = 64;
        let mut rng = Rng::seed_from_u64(7);
        // Correlated activations make the Hessian non-trivial.
        let mut x = Matrix::zeros(256, d);
        for t in 0..x.rows {
            let base: f32 = rng.range_f32(-1.0, 1.0);
            for j in 0..d {
                *x.at_mut(t, j) = base * 0.5 + rng.range_f32(-1.0, 1.0);
            }
        }
        let mut st = CalibStats::new(true);
        st.observe("l", &x);
        let orig = rand_matrix(16, d, 8);
        let pat = NmPattern::new(4, 8);

        let mut w_sgpt = orig.clone();
        sparsify(
            &mut w_sgpt,
            SparsifyCfg { method: SparsifyMethod::SparseGpt, pattern: pat },
            st.get("l"),
        )
        .unwrap();
        assert!(pat.check(&w_sgpt), "sparsegpt output must satisfy N:M");

        let mut w_mag = orig.clone();
        sparsify(
            &mut w_mag,
            SparsifyCfg { method: SparsifyMethod::Magnitude, pattern: pat },
            None,
        )
        .unwrap();

        let gram = st.get("l").unwrap().finalized_gram().unwrap();
        let e_sgpt = output_error_proxy(&orig, &w_sgpt, &gram);
        let e_mag = output_error_proxy(&orig, &w_mag, &gram);
        assert!(
            e_sgpt < e_mag,
            "SparseGPT ({e_sgpt:.4}) should beat magnitude ({e_mag:.4}) on output error"
        );
    }

    #[test]
    fn sparsegpt_zero_fraction() {
        let d = 32;
        let mut w = rand_matrix(4, d, 11);
        let st = calib(64, d, 12, true);
        sparsify(
            &mut w,
            SparsifyCfg { method: SparsifyMethod::SparseGpt, pattern: NmPattern::new(2, 8) },
            st.get("l"),
        )
        .unwrap();
        // At least 6/8 of entries pruned (updates never resurrect zeros in
        // pruned positions within a processed block).
        assert!(w.zero_fraction() >= 0.75 - 1e-9);
    }

    #[test]
    fn dense_pattern_is_noop() {
        let orig = rand_matrix(4, 16, 5);
        let mut w = orig.clone();
        sparsify(
            &mut w,
            SparsifyCfg { method: SparsifyMethod::Magnitude, pattern: NmPattern::new(8, 8) },
            None,
        )
        .unwrap();
        assert_eq!(w, orig);
    }
}
