//! Continuous-batching scheduler.
//!
//! Each scheduling **round**: admit + prefill a bounded burst of waiting
//! requests, then decode one token for every active sequence in a
//! **single ragged batch** ([`Model::decode_step`]): the last token of
//! each sequence is stacked into one `[n_active, d]` activation matrix
//! so every linear layer streams its (compressed) weights once per
//! round instead of once per sequence — the memory-bound regime where
//! SDQ's compressed formats pay off. Attention stays per-sequence
//! (heterogeneous KV prefixes, parallel over `(seq, head)`). A
//! per-sequence fallback (`BatchPolicy::batched_decode = false`) keeps
//! the old path alive as the benchmark baseline. Completed sequences
//! retire at the end of the round.
//!
//! Admission budgets against *actual* KV residency ([`KvCache::bytes`])
//! plus each waiting request's projected growth — caches are chunked
//! and grow on demand, so the budget reflects real memory, not
//! worst-case reservations.

use std::time::Instant;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::request::{InFlight, Request, Response};
use crate::model::generate::KvCache;
use crate::model::Model;
use crate::util::par::par_chunks_mut;

/// Scheduler over a (possibly compressed) model.
pub struct Scheduler<'m> {
    model: &'m Model,
    pub policy: BatchPolicy,
    active: Vec<InFlight>,
    pub metrics: Metrics,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m Model, policy: BatchPolicy) -> Self {
        Scheduler { model, policy, active: Vec::new(), metrics: Metrics::default() }
    }

    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Whether any work remains (active or waiting).
    pub fn has_work(&self, batcher: &Batcher) -> bool {
        !self.active.is_empty() || batcher.waiting() > 0
    }

    /// Actual KV bytes resident across the active set.
    pub fn kv_bytes_in_use(&self) -> usize {
        self.active.iter().filter_map(|f| f.cache.as_ref()).map(|c| c.bytes()).sum()
    }

    /// KV bytes charged against the admission budget: each active
    /// sequence is charged the larger of its actual residency and its
    /// admission-time projection, so caches growing *after* admission
    /// can never push the active set past `kv_budget_bytes`.
    pub fn kv_bytes_reserved(&self) -> usize {
        self.active
            .iter()
            .map(|f| {
                let actual = f.cache.as_ref().map(|c| c.bytes()).unwrap_or(0);
                actual.max(f.kv_projected)
            })
            .sum()
    }

    /// Projected eventual KV residency of a request: its (clamped)
    /// prompt plus full decode budget, chunk-aligned.
    pub fn projected_kv_bytes(&self, req: &Request) -> usize {
        let cfg = &self.model.cfg;
        let prompt = req.prompt.len().min(cfg.max_seq - 1);
        let tokens = (prompt + req.max_new_tokens).min(cfg.max_seq);
        KvCache::bytes_for_tokens(cfg, tokens)
    }

    /// One scheduling round. Returns completed responses.
    pub fn round(&mut self, batcher: &mut Batcher) -> Vec<Response> {
        let t0 = Instant::now();
        // ---- admission + prefill ----
        let kv_reserved = self.kv_bytes_reserved();
        let mut admitted = batcher.admit(&self.policy, self.active.len(), kv_reserved, |r| {
            self.projected_kv_bytes(r)
        });
        for f in &mut admitted {
            f.kv_projected = self.projected_kv_bytes(&f.req);
            f.started = Some(Instant::now());
            let mut cache = KvCache::new(self.model);
            // Clamp over-long prompts to leave ≥1 slot for generation.
            let keep = f.req.prompt.len().min(self.model.cfg.max_seq - 1);
            let prompt = &f.req.prompt[f.req.prompt.len() - keep..];
            let logits = self.model.forward_cached(prompt, &mut cache);
            self.metrics.prefill_tokens += prompt.len() as u64;
            let tok = self.model.sample(&logits, f.req.temperature, &mut f.rng);
            f.generated.push(tok);
            f.first_token = Some(Instant::now());
            f.cache = Some(cache);
        }
        self.active.append(&mut admitted);

        // ---- decode one token for all active sequences ----
        let model = self.model;
        let td = Instant::now();
        if self.policy.batched_decode {
            // One fused GEMM per layer per round across the whole
            // ragged batch.
            let decode_idx: Vec<usize> = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, f)| f.decodable())
                .map(|(i, _)| i)
                .collect();
            if !decode_idx.is_empty() {
                let last: Vec<u8> = decode_idx
                    .iter()
                    .map(|&i| *self.active[i].generated.last().expect("has first token"))
                    .collect();
                let logits = {
                    // Disjoint &mut borrows of each selected sequence's
                    // cache (indices are ascending).
                    let mut caches: Vec<&mut KvCache> = Vec::with_capacity(decode_idx.len());
                    let mut rest: &mut [InFlight] = &mut self.active;
                    let mut base = 0usize;
                    for &i in &decode_idx {
                        let (head, tail) =
                            std::mem::take(&mut rest).split_at_mut(i - base + 1);
                        caches.push(head[i - base].cache.as_mut().expect("prefilled"));
                        rest = tail;
                        base = i + 1;
                    }
                    model.decode_step(&last, &mut caches)
                };
                for (row, &i) in decode_idx.iter().enumerate() {
                    let f = &mut self.active[i];
                    let tok = model.sample_row(&logits, row, f.req.temperature, &mut f.rng);
                    f.generated.push(tok);
                }
                self.metrics.record_decode_batch(decode_idx.len());
            }
        } else {
            // Per-sequence baseline: one batch-1 forward per sequence,
            // parallel across sequences (each GEMM re-streams weights).
            let width = self.active.iter().filter(|f| f.decodable()).count();
            par_chunks_mut(&mut self.active, 1, |_i, slot| {
                let f = &mut slot[0];
                if !f.decodable() {
                    return;
                }
                let cache = f.cache.as_mut().expect("prefilled");
                let last = *f.generated.last().expect("has first token");
                let logits = model.forward_cached(&[last], cache);
                let tok = model.sample(&logits, f.req.temperature, &mut f.rng);
                f.generated.push(tok);
            });
            for _ in 0..width {
                self.metrics.record_decode_batch(1);
            }
        }
        self.metrics.decode_time += td.elapsed();
        self.metrics.decode_rounds += 1;
        let resident = self.kv_bytes_in_use();
        self.metrics.kv_bytes_peak = self.metrics.kv_bytes_peak.max(resident);

        // ---- retire completed ----
        let mut done = Vec::new();
        let mut still = Vec::with_capacity(self.active.len());
        for f in self.active.drain(..) {
            let out_of_cache =
                f.cache.as_ref().map(|c| c.remaining() == 0).unwrap_or(false);
            if f.remaining() == 0 || out_of_cache {
                let resp = f.finish();
                self.metrics.requests_completed += 1;
                self.metrics.tokens_generated += resp.tokens.len() as u64;
                self.metrics.ttft.record(resp.timing.ttft);
                self.metrics.total_latency.record(resp.timing.total);
                done.push(resp);
            } else {
                still.push(f);
            }
        }
        self.active = still;
        self.metrics.serve_time += t0.elapsed();
        done
    }

    /// Drive rounds until the queue and active set drain.
    pub fn run_to_completion(&mut self, batcher: &mut Batcher) -> Vec<Response> {
        let mut out = Vec::new();
        while self.has_work(batcher) {
            out.extend(self.round(batcher));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::model::testutil::tiny_model;
    use crate::model::Arch;

    #[test]
    fn serves_all_requests() {
        let model = tiny_model(Arch::Gpt, 1);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        for i in 0..6 {
            batcher.enqueue(Request::new(i, vec![(i + 65) as u8; 4], 5));
        }
        let responses = sched.run_to_completion(&mut batcher);
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert_eq!(r.tokens.len(), 5);
            assert!(r.timing.ttft <= r.timing.total);
        }
        assert_eq!(sched.metrics.requests_completed, 6);
        assert_eq!(sched.metrics.tokens_generated, 30);
    }

    #[test]
    fn deterministic_greedy_matches_generate() {
        let model = tiny_model(Arch::Llama, 2);
        let prompt = b"abcd".to_vec();
        let direct = model.generate(&prompt, 6, 0.0, 0);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, prompt, 6));
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp[0].tokens, direct);
    }

    #[test]
    fn respects_max_active() {
        let model = tiny_model(Arch::Gpt, 3);
        let policy = BatchPolicy { max_active: 2, max_prefill_per_round: 2, ..Default::default() };
        let mut sched = Scheduler::new(&model, policy);
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 2], 3));
        }
        let _ = sched.round(&mut batcher);
        assert!(sched.active() <= 2);
        let all = sched.run_to_completion(&mut batcher);
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn long_prompt_is_clamped() {
        let model = tiny_model(Arch::Gpt, 4);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        batcher.enqueue(Request::new(0, vec![66u8; 200], 4)); // > max_seq=64
        let resp = sched.run_to_completion(&mut batcher);
        assert_eq!(resp.len(), 1);
        assert!(!resp[0].tokens.is_empty());
    }

    #[test]
    fn per_seq_fallback_matches_batched() {
        // The A/B lever must not change tokens: greedy output is
        // bit-identical between the fused ragged batch and the
        // per-sequence baseline.
        let model = tiny_model(Arch::Llama, 5);
        let run = |batched: bool| {
            let policy = BatchPolicy { batched_decode: batched, ..Default::default() };
            let mut sched = Scheduler::new(&model, policy);
            let mut batcher = Batcher::new();
            for i in 0..5u64 {
                let plen = 1 + (i as usize * 2) % 7;
                batcher.enqueue(Request::new(i, vec![(65 + i) as u8; plen], 3 + i as usize));
            }
            let mut resp = sched.run_to_completion(&mut batcher);
            resp.sort_by_key(|r| r.id);
            resp.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn decode_width_metrics() {
        let model = tiny_model(Arch::Gpt, 6);
        let mut sched = Scheduler::new(&model, BatchPolicy::default());
        let mut batcher = Batcher::new();
        for i in 0..6 {
            batcher.enqueue(Request::new(i, vec![65u8; 4], 5));
        }
        sched.run_to_completion(&mut batcher);
        let m = &sched.metrics;
        assert!(m.decode_batches > 0);
        // Round 1 admits 4 (prefill burst limit) and decodes width 4;
        // round 2 admits the remaining 2 and decodes width 6.
        assert_eq!(m.decode_width_max, 6);
        assert!(m.mean_decode_width() > 1.0);
        assert!(m.kv_bytes_peak > 0);
        assert!(!m.decode_time.is_zero());
    }

    #[test]
    fn admission_budgets_on_projected_kv() {
        let model = tiny_model(Arch::Gpt, 7);
        // Budget fits exactly two projected caches (prompt 4 + 8 new).
        let one = KvCache::bytes_for_tokens(&model.cfg, 4 + 8);
        let policy = BatchPolicy { kv_budget_bytes: 2 * one, ..Default::default() };
        let mut sched = Scheduler::new(&model, policy);
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 4], 8));
        }
        let _ = sched.round(&mut batcher);
        assert_eq!(sched.active(), 2, "projected KV budget must cap admission");
        // Everything still completes once the first wave retires.
        let all = sched.run_to_completion(&mut batcher);
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn budget_holds_across_cache_growth() {
        // Requests whose caches grow over several chunks after
        // admission: the reserved-projection accounting must keep both
        // the active count and the *actual* residency under budget in
        // every round, not just at admission time.
        let model = tiny_model(Arch::Gpt, 8);
        let one = KvCache::bytes_for_tokens(&model.cfg, 4 + 40);
        let policy = BatchPolicy { kv_budget_bytes: 2 * one, ..Default::default() };
        let mut sched = Scheduler::new(&model, policy);
        let mut batcher = Batcher::new();
        for i in 0..4 {
            batcher.enqueue(Request::new(i, vec![65u8; 4], 40));
        }
        let mut rounds = 0;
        while sched.has_work(&batcher) && rounds < 200 {
            let _ = sched.round(&mut batcher);
            rounds += 1;
            assert!(sched.active() <= 2, "admission exceeded the projection budget");
            assert!(
                sched.kv_bytes_in_use() <= policy.kv_budget_bytes,
                "actual KV residency broke the budget"
            );
        }
        assert_eq!(sched.metrics.requests_completed, 4);
    }
}
