//! The paper's core contribution: **S**parsify → **D**ecompose →
//! **Q**uantize.
//!
//! * [`nm`] — N:M structured-sparsity patterns and masks (§3.1).
//! * [`config`] — configuration system, including a parser for the
//!   paper's own naming scheme (`SDQ-W7:8-1:8int8-6:8fp4`).
//! * [`calib`] — calibration pipeline: per-layer activation statistics
//!   (column norms for Wanda/product metrics, Gram/Hessian for
//!   SparseGPT).
//! * [`sparsify`] — Stage 1: magnitude / Wanda / SparseGPT-OBS pruning
//!   under an N:M constraint (§5 Stage 1).
//! * [`decompose`] — Stage 2: N:M *local outlier extraction* splitting a
//!   weight tensor into structured-sparse outliers + inliers (§4, §5
//!   Stage 2), plus the Fig. 5 coverage analysis.
//! * [`quantize`] — Stage 3: VS-Quant per-vector scaled quantization with
//!   quantized scale factors (§5 Stage 3, Fig. 11).
//! * [`packed`] — ELLPACK-like packed N:M storage (values + index
//!   metadata) feeding the bits-per-weight model (§3.3, Fig. 4).
//! * [`qmat`] — packed quantized dense plane ([`qmat::QuantMat`]): real
//!   int8 / nibble codes + fp8-e4m3 scales served straight into the
//!   fused GEMM ([`crate::tensor::matmul_q_into`]), bit-identical to
//!   the dequantized f32 view.
//! * [`pipeline`] — applies a full [`config::CompressionConfig`] to every
//!   linear layer of a model.
//! * [`linalg`] — small dense linear algebra (Cholesky, inversion) used
//!   by SparseGPT.

pub mod calib;
pub mod config;
pub mod decompose;
pub mod gptq;
pub mod linalg;
pub mod nm;
pub mod packed;
pub mod pipeline;
pub mod qmat;
pub mod quantize;
pub mod sparsify;
