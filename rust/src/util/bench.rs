//! Micro/macro benchmark harness substrate (no external `criterion`).
//!
//! Benches under `benches/` are `harness = false` binaries that call
//! [`bench`] / [`Table`] to produce warm-up-adjusted medians with spread,
//! and aligned tables matching the paper's rows.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub iters: usize,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    /// Throughput given work items per iteration.
    pub fn per_second(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns / 1e9)
    }
}

/// Run `f` repeatedly: warm up, then sample until `min_runtime_ms` or
/// `max_iters` is reached. Returns the median (robust to scheduler noise).
pub fn bench<F: FnMut()>(name: &str, min_runtime_ms: u64, mut f: F) -> Measurement {
    // Warm-up: one untimed call.
    f();
    let budget = std::time::Duration::from_millis(min_runtime_ms);
    let start = Instant::now();
    let mut samples: Vec<f64> = Vec::new();
    while start.elapsed() < budget || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    Measurement {
        name: name.to_string(),
        median_ns: median,
        min_ns: samples[0],
        max_ns: *samples.last().unwrap(),
        iters: samples.len(),
    }
}

/// Print a measurement in a criterion-like line.
pub fn report(m: &Measurement) {
    println!(
        "{:<44} {:>12.3} ms  (min {:.3}, max {:.3}, n={})",
        m.name,
        m.median_ms(),
        m.min_ns / 1e6,
        m.max_ns / 1e6,
        m.iters
    );
}

/// Aligned text table builder for paper-style outputs.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }

    /// Also emit as JSON (machine-readable record for EXPERIMENTS.md).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::Obj(
                    self.headers
                        .iter()
                        .zip(r)
                        .map(|(h, c)| (h.clone(), Json::Str(c.clone())))
                        .collect(),
                )
            })
            .collect();
        Json::obj(vec![("title", Json::from(self.title.clone())), ("rows", Json::Arr(rows))])
    }

    /// Write the JSON record under `target/bench-results/`.
    pub fn save_json(&self, file_stem: &str) {
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(dir.join(format!("{file_stem}.json")), self.to_json().to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("spin", 5, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            std::hint::black_box(s);
        });
        assert!(m.median_ns > 0.0);
        assert!(m.iters >= 3);
        assert!(m.min_ns <= m.median_ns && m.median_ns <= m.max_ns);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new("demo", &["config", "ppl"]);
        t.row(vec!["Dense-WA16".into(), "10.86".into()]);
        t.print();
        let j = t.to_json();
        assert!(j.to_string().contains("Dense-WA16"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
