//! Minimal JSON substrate (no external `serde`).
//!
//! Covers everything the repo needs: the weight-bundle manifest written
//! by `train.py`, report emission from benches/examples, and config
//! files. Full RFC 8259 parsing for the subset python's `json.dumps`
//! emits (incl. unicode escapes), plus a compact writer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field `{key}`"))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!("expected `{}` at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => anyhow::bail!("expected , or }} got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => anyhow::bail!("expected , or ] got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs
                            let cp = if (0xd800..0xdc00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00)
                            } else {
                                cp
                            };
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at {}", self.i),
                    }
                }
                _ => {
                    // Collect raw UTF-8 bytes.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] != b'"' && self.b[end] != b'\\' {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_python_style_manifest() {
        let s = r#"{"config": {"d_model": 96, "arch": "gpt", "eps": 1e-05},
                    "tensors": [{"name": "tok_emb", "rows": 256, "cols": 96, "offset": 0}]}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("config").unwrap().req_usize("d_model").unwrap(), 96);
        assert_eq!(j.get("config").unwrap().req_str("arch").unwrap(), "gpt");
        assert!((j.get("config").unwrap().req_f64("eps").unwrap() - 1e-5).abs() < 1e-12);
        let t = &j.get("tensors").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.req_usize("rows").unwrap(), 256);
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::from(1.5)),
            ("b", Json::from("he\"llo\n")),
            ("c", Json::Arr(vec![Json::Null, Json::Bool(true), Json::from(3usize)])),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let j = Json::parse("[-3.5, 2e3, -1E-2]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -3.5);
        assert_eq!(a[1].as_f64().unwrap(), 2000.0);
        assert_eq!(a[2].as_f64().unwrap(), -0.01);
    }
}
