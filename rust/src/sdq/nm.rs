//! N:M structured-sparsity patterns.
//!
//! An `N:M` pattern keeps **at most N non-zero values in every block of M
//! consecutive values** along the reduction (input-feature) dimension —
//! the layout structured-sparse tensor cores consume (§3.1). `8:8` (or
//! any N==M) degenerates to dense.

use std::fmt;
use std::str::FromStr;

use crate::tensor::Matrix;

/// An `N:M` structured sparsity pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct NmPattern {
    /// Maximum non-zeros per block.
    pub n: usize,
    /// Block (S-vector) size.
    pub m: usize,
}

impl NmPattern {
    /// Construct, validating `0 < n <= m` and `m` power-of-two-ish sanity.
    pub fn new(n: usize, m: usize) -> Self {
        assert!(n >= 1 && n <= m, "invalid N:M pattern {n}:{m}");
        NmPattern { n, m }
    }

    /// True when the pattern keeps everything (dense).
    pub fn is_dense(&self) -> bool {
        self.n == self.m
    }

    /// Density `N/M`.
    pub fn density(&self) -> f64 {
        self.n as f64 / self.m as f64
    }

    /// Effective compute-throughput multiplier on N:M sparse hardware:
    /// `M/N` (§3.1).
    pub fn throughput_multiplier(&self) -> f64 {
        self.m as f64 / self.n as f64
    }

    /// Index-metadata bits per *non-zero* value in an ELLPACK-like packed
    /// format: `log2(M)` (§3.3).
    pub fn index_bits(&self) -> u32 {
        (self.m as f64).log2().ceil() as u32
    }

    /// Complement pattern `(M-N):M` — what remains after extracting this
    /// pattern from a dense block (§5 Stage 2).
    pub fn complement(&self) -> NmPattern {
        assert!(self.n < self.m, "dense pattern has empty complement");
        NmPattern::new(self.m - self.n, self.m)
    }

    /// Check a row satisfies the pattern (at most N non-zeros per block;
    /// ragged tail blocks are checked pro-rata).
    pub fn check_row(&self, row: &[f32]) -> bool {
        if self.is_dense() {
            return true;
        }
        row.chunks(self.m).all(|blk| {
            let nnz = blk.iter().filter(|v| **v != 0.0).count();
            nnz <= self.n
        })
    }

    /// Check every row of a matrix satisfies the pattern along `cols`.
    pub fn check(&self, w: &Matrix) -> bool {
        (0..w.rows).all(|r| self.check_row(w.row(r)))
    }
}

impl fmt::Display for NmPattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.n, self.m)
    }
}

impl FromStr for NmPattern {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (n, m) = s.split_once(':').ok_or_else(|| format!("bad N:M pattern: {s}"))?;
        let n: usize = n.trim().parse().map_err(|_| format!("bad N in {s}"))?;
        let m: usize = m.trim().parse().map_err(|_| format!("bad M in {s}"))?;
        if n == 0 || n > m {
            return Err(format!("invalid pattern {n}:{m}"));
        }
        Ok(NmPattern { n, m })
    }
}

/// Keep the top-`n` entries of `scores` within each `m`-block of a row,
/// writing `true` into `mask` for kept positions. Ties broken by lower
/// index (deterministic). `scores` and `mask` must have equal length.
pub fn topn_block_mask(scores: &[f32], pat: NmPattern, mask: &mut [bool]) {
    assert_eq!(scores.len(), mask.len());
    if pat.is_dense() {
        mask.fill(true);
        return;
    }
    mask.fill(false);
    let mut idx: Vec<usize> = Vec::with_capacity(pat.m);
    for (b, blk) in scores.chunks(pat.m).enumerate() {
        idx.clear();
        idx.extend(0..blk.len());
        // Keep top-N by score, stable towards lower index on ties.
        idx.sort_by(|&a, &c| {
            blk[c].partial_cmp(&blk[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&c))
        });
        // Ragged tail blocks keep a pro-rata count (only full blocks are
        // guaranteed by construction in the model dims we use).
        let keep = pat.n.min(blk.len());
        for &i in idx.iter().take(keep) {
            mask[b * pat.m + i] = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display() {
        let p: NmPattern = "2:4".parse().unwrap();
        assert_eq!(p, NmPattern::new(2, 4));
        assert_eq!(p.to_string(), "2:4");
        assert!("0:4".parse::<NmPattern>().is_err());
        assert!("5:4".parse::<NmPattern>().is_err());
        assert!("24".parse::<NmPattern>().is_err());
    }

    #[test]
    fn throughput_and_bits() {
        assert_eq!(NmPattern::new(2, 4).throughput_multiplier(), 2.0);
        assert_eq!(NmPattern::new(1, 8).throughput_multiplier(), 8.0);
        assert_eq!(NmPattern::new(2, 4).index_bits(), 2);
        assert_eq!(NmPattern::new(1, 8).index_bits(), 3);
        assert_eq!(NmPattern::new(6, 8).complement(), NmPattern::new(2, 8));
    }

    #[test]
    fn topn_mask_keeps_largest() {
        let scores = [0.1, 5.0, 3.0, 0.2, 9.0, 0.0, 1.0, 2.0];
        let mut mask = [false; 8];
        topn_block_mask(&scores, NmPattern::new(2, 4), &mut mask);
        assert_eq!(mask, [false, true, true, false, true, false, false, true]);
    }

    #[test]
    fn topn_mask_tie_break_deterministic() {
        let scores = [1.0, 1.0, 1.0, 1.0];
        let mut mask = [false; 4];
        topn_block_mask(&scores, NmPattern::new(2, 4), &mut mask);
        assert_eq!(mask, [true, true, false, false]);
    }

    #[test]
    fn dense_pattern_keeps_all() {
        let scores = [0.0, -1.0, 2.0];
        let mut mask = [false; 3];
        topn_block_mask(&scores, NmPattern::new(4, 4), &mut mask[..3]);
        assert!(mask.iter().all(|&b| b));
    }

    #[test]
    fn check_row_detects_violation() {
        let p = NmPattern::new(2, 4);
        assert!(p.check_row(&[1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 3.0]));
        assert!(!p.check_row(&[1.0, 1.0, 2.0, 0.0]));
    }
}
