//! Low-bit-width number formats.
//!
//! Implements every format the paper's quantization stage uses:
//! integer grids (`int4`, `int8`), minifloats (`fp4-e2m1`, `fp8-e4m3`,
//! `fp8-e5m2`), the unsigned scale-factor format `ufp8-e6m2` from the
//! Fig. 11 sensitivity study, plus `fp16`/`fp32` for baselines.
//!
//! All quantizers are *round-to-nearest-even* onto the representable
//! grid, matching VS-Quant (Dai et al., 2021). A format knows its
//! `bits()` (for the bits-per-weight model), its `max_value()` (for
//! scale computation), and how to snap an `f32` onto its grid.

use std::fmt;
use std::str::FromStr;

/// A quantization target format.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NumFormat {
    /// IEEE-754 binary32 (no quantization; reference).
    Fp32,
    /// IEEE-754 binary16.
    Fp16,
    /// OCP FP8 E4M3 (bias 7, max 448, no infinities).
    Fp8E4M3,
    /// OCP FP8 E5M2 (bias 15, max 57344).
    Fp8E5M2,
    /// FP4 E2M1 (bias 1, grid ±{0, .5, 1, 1.5, 2, 3, 4, 6}).
    Fp4E2M1,
    /// Unsigned FP8 E6M2 (bias 31) — scale-factor format from Fig. 11.
    UFp8E6M2,
    /// Symmetric signed integer, `bits` total (e.g. 4 → grid −7..7).
    Int(u8),
}

impl NumFormat {
    /// Storage bits per element.
    pub fn bits(&self) -> u32 {
        match self {
            NumFormat::Fp32 => 32,
            NumFormat::Fp16 => 16,
            NumFormat::Fp8E4M3 | NumFormat::Fp8E5M2 | NumFormat::UFp8E6M2 => 8,
            NumFormat::Fp4E2M1 => 4,
            NumFormat::Int(b) => *b as u32,
        }
    }

    /// Largest representable magnitude (used as the scale anchor:
    /// `scale = max_abs / max_value`).
    pub fn max_value(&self) -> f32 {
        match self {
            NumFormat::Fp32 => f32::MAX,
            NumFormat::Fp16 => 65504.0,
            NumFormat::Fp8E4M3 => 448.0,
            NumFormat::Fp8E5M2 => 57344.0,
            NumFormat::Fp4E2M1 => 6.0,
            // e6m2, bias 31: exponent field 0..63, max = 2^(63-31) * 1.75
            NumFormat::UFp8E6M2 => 2.0f32.powi(32) * 1.75,
            NumFormat::Int(b) => ((1i64 << (b - 1)) - 1) as f32,
        }
    }

    /// True for integer grids.
    pub fn is_int(&self) -> bool {
        matches!(self, NumFormat::Int(_))
    }

    /// Physical bits per code in packed weight storage
    /// ([`crate::sdq::qmat::QuantMat`]): 4 for formats whose codes fit a
    /// nibble (fp4-e2m1, int2..int4), 8 for int5..int8, `None` for
    /// formats the packed plane does not store (fp8/fp16/fp32 weights
    /// stay dense f32 — no byte win worth a decode step, or no integral
    /// code representation at all).
    pub fn packed_code_bits(&self) -> Option<u32> {
        match self {
            NumFormat::Fp4E2M1 => Some(4),
            NumFormat::Int(b) if *b <= 4 => Some(4),
            NumFormat::Int(b) if *b <= 8 => Some(8),
            _ => None,
        }
    }

    /// True for unsigned formats (only valid for non-negative inputs).
    pub fn is_unsigned(&self) -> bool {
        matches!(self, NumFormat::UFp8E6M2)
    }

    /// Snap `x` onto this format's representable grid
    /// (round-to-nearest-even, clamp to ±max).
    pub fn quantize(&self, x: f32) -> f32 {
        if !x.is_finite() {
            return x.signum() * self.max_value();
        }
        match self {
            NumFormat::Fp32 => x,
            NumFormat::Fp16 => f16_round(x),
            NumFormat::Fp8E4M3 => minifloat_round(x, 4, 3, 7, 448.0),
            NumFormat::Fp8E5M2 => minifloat_round(x, 5, 2, 15, 57344.0),
            NumFormat::Fp4E2M1 => fp4_round_fast(x),
            NumFormat::UFp8E6M2 => {
                debug_assert!(x >= 0.0, "ufp8 is unsigned");
                minifloat_round(x.max(0.0), 6, 2, 31, self.max_value())
            }
            NumFormat::Int(_) => {
                let m = self.max_value();
                round_half_even(x).clamp(-m, m)
            }
        }
    }

    /// Mean-squared quantization error of `xs` snapped to this grid
    /// (diagnostics for the decomposition error metric).
    pub fn mse(&self, xs: &[f32]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter()
            .map(|x| {
                let d = (x - self.quantize(*x)) as f64;
                d * d
            })
            .sum::<f64>()
            / xs.len() as f64
    }
}

impl fmt::Display for NumFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NumFormat::Fp32 => write!(f, "fp32"),
            NumFormat::Fp16 => write!(f, "fp16"),
            NumFormat::Fp8E4M3 => write!(f, "fp8-e4m3"),
            NumFormat::Fp8E5M2 => write!(f, "fp8-e5m2"),
            NumFormat::Fp4E2M1 => write!(f, "fp4"),
            NumFormat::UFp8E6M2 => write!(f, "ufp8-e6m2"),
            NumFormat::Int(b) => write!(f, "int{b}"),
        }
    }
}

impl FromStr for NumFormat {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "fp32" => Ok(NumFormat::Fp32),
            "fp16" => Ok(NumFormat::Fp16),
            "fp8" | "fp8-e4m3" | "fp8e4m3" => Ok(NumFormat::Fp8E4M3),
            "fp8-e5m2" | "fp8e5m2" => Ok(NumFormat::Fp8E5M2),
            "fp4" | "fp4-e2m1" | "fp4e2m1" => Ok(NumFormat::Fp4E2M1),
            "ufp8-e6m2" | "ufp8e6m2" | "ufp8" => Ok(NumFormat::UFp8E6M2),
            _ => {
                if let Some(b) = s.strip_prefix("int") {
                    let bits: u8 =
                        b.parse().map_err(|_| format!("bad int format: {s}"))?;
                    if !(2..=16).contains(&bits) {
                        return Err(format!("unsupported int width: {bits}"));
                    }
                    Ok(NumFormat::Int(bits))
                } else {
                    Err(format!("unknown number format: {s}"))
                }
            }
        }
    }
}

/// The eight non-negative FP4-E2M1 magnitudes in nibble-index order:
/// `FP4_GRID[m]` is the value whose packed sign-magnitude nibble has
/// magnitude bits `m` (sign lives in bit 3). Shared by the packed
/// weight codec ([`crate::sdq::qmat`]) and its round-trip tests.
pub const FP4_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Round-half-to-even for scalar f32 (matches hardware RNE rounding).
/// Uses the `roundeven` intrinsic (§Perf iteration 4: branch-free int
/// grid snap on the activation-quantization hot loop).
#[inline(always)]
pub fn round_half_even(x: f32) -> f32 {
    x.round_ties_even()
}

/// Fast FP4-E2M1 grid snap: the grid has only 8 magnitudes, so a
/// comparison chain beats the generic log2/floor path by ~4× — this is
/// the activation-quantization hot loop for SDQ's inlier path (§Perf
/// iteration 2). Tie boundaries implement round-to-nearest-even over the
/// grid (ties land on even grid indices: 0, 1.0, 2.0, 4.0), matching
/// `minifloat_round(x, 2, 1, 1, 6.0)` exactly.
#[inline(always)]
fn fp4_round_fast(x: f32) -> f32 {
    let a = x.abs();
    let q = if a <= 0.25 {
        0.0
    } else if a < 0.75 {
        0.5
    } else if a <= 1.25 {
        1.0
    } else if a < 1.75 {
        1.5
    } else if a <= 2.5 {
        2.0
    } else if a < 3.5 {
        3.0
    } else if a <= 5.0 {
        4.0
    } else {
        6.0
    };
    if x < 0.0 {
        -q
    } else {
        q
    }
}

/// Generic minifloat round-to-nearest-even with subnormal support.
///
/// `exp_bits`/`man_bits` describe the layout, `bias` the exponent bias and
/// `max` the largest finite magnitude (encodes OCP's reserved-NaN
/// conventions without modelling the bit patterns).
fn minifloat_round(x: f32, exp_bits: u32, man_bits: u32, bias: i32, max: f32) -> f32 {
    if x == 0.0 {
        return 0.0;
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let a = x.abs();
    if a >= max {
        return sign * max;
    }
    let _ = exp_bits; // layout documented by caller; max encodes the ceiling
    // Exponent of the value, clamped to the subnormal floor.
    let e = a.log2().floor() as i32;
    let e_min = 1 - bias; // smallest normal exponent
    let e_eff = e.max(e_min);
    let quantum = 2.0f32.powi(e_eff - man_bits as i32);
    let q = round_half_even(a / quantum) * quantum;
    sign * q.min(max)
}

/// f32 → f16 → f32 rounding via bit manipulation (RNE).
fn f16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let sign = bits >> 31;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;
    // f16: 5 exp bits (bias 15), 10 mantissa bits
    let e16 = exp - 127 + 15;
    let half: u16 = if exp == 0xff {
        // inf/nan
        ((sign as u16) << 15) | 0x7c00 | if man != 0 { 1 } else { 0 }
    } else if e16 >= 0x1f {
        ((sign as u16) << 15) | 0x7bff // clamp to max finite
    } else if e16 <= 0 {
        // subnormal in f16
        if e16 < -10 {
            (sign as u16) << 15
        } else {
            let m = man | 0x80_0000;
            let shift = 14 - e16; // 14..24
            let rounded = rne_shift(m as u64, shift as u32);
            ((sign as u16) << 15) | rounded as u16
        }
    } else {
        let rounded = rne_shift(man as u64, 13);
        let mut e = e16 as u32;
        let mut m = rounded as u32;
        if m == 0x400 {
            m = 0;
            e += 1;
        }
        if e >= 0x1f {
            ((sign as u16) << 15) | 0x7bff
        } else {
            ((sign as u16) << 15) | ((e as u16) << 10) | m as u16
        }
    };
    // decode back to f32
    f16_to_f32(half)
}

/// Shift right by `s` with round-to-nearest-even on the dropped bits.
fn rne_shift(v: u64, s: u32) -> u64 {
    if s == 0 {
        return v;
    }
    let keep = v >> s;
    let rem = v & ((1 << s) - 1);
    let half = 1u64 << (s - 1);
    if rem > half || (rem == half && keep & 1 == 1) {
        keep + 1
    } else {
        keep
    }
}

fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h >> 15) & 1) as u32;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign << 31
        } else {
            // subnormal: normalize
            let mut e = 113u32; // 127 - 14
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            (sign << 31) | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        (sign << 31) | (0xff << 23) | (man << 13)
    } else {
        (sign << 31) | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp4_grid_is_the_e2m1_grid() {
        let f = NumFormat::Fp4E2M1;
        // All representable positives
        let grid = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
        for g in grid {
            assert_eq!(f.quantize(g), g, "grid point {g} must be fixed");
            assert_eq!(f.quantize(-g), -g);
        }
        // Midpoint ties round to even mantissa
        assert_eq!(f.quantize(2.5), 2.0); // tie between 2 and 3 → even (2)
        assert_eq!(f.quantize(5.0), 4.0); // tie between 4 and 6 → even (4)
        assert_eq!(f.quantize(7.0), 6.0); // clamp
        assert_eq!(f.quantize(100.0), 6.0);
        assert_eq!(f.quantize(0.2), 0.0); // below 0.25 → 0
        assert_eq!(f.quantize(0.3), 0.5);
    }

    #[test]
    fn fp4_grid_const_matches_quantizer_fixed_points() {
        for (m, g) in FP4_GRID.iter().enumerate() {
            assert_eq!(NumFormat::Fp4E2M1.quantize(*g), *g, "index {m}");
        }
        // Strictly increasing → nibble decode is injective on magnitudes.
        for w in FP4_GRID.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn packed_code_bits_covers_exactly_the_low_bit_formats() {
        assert_eq!(NumFormat::Fp4E2M1.packed_code_bits(), Some(4));
        assert_eq!(NumFormat::Int(2).packed_code_bits(), Some(4));
        assert_eq!(NumFormat::Int(4).packed_code_bits(), Some(4));
        assert_eq!(NumFormat::Int(5).packed_code_bits(), Some(8));
        assert_eq!(NumFormat::Int(8).packed_code_bits(), Some(8));
        for fmt in [
            NumFormat::Fp32,
            NumFormat::Fp16,
            NumFormat::Fp8E4M3,
            NumFormat::Fp8E5M2,
            NumFormat::UFp8E6M2,
            NumFormat::Int(12),
        ] {
            assert_eq!(fmt.packed_code_bits(), None, "{fmt}");
        }
    }

    #[test]
    fn int_grids() {
        assert_eq!(NumFormat::Int(4).max_value(), 7.0);
        assert_eq!(NumFormat::Int(8).max_value(), 127.0);
        assert_eq!(NumFormat::Int(4).quantize(3.4), 3.0);
        assert_eq!(NumFormat::Int(4).quantize(-9.0), -7.0);
        assert_eq!(NumFormat::Int(8).quantize(127.6), 127.0);
        // RNE on ties
        assert_eq!(NumFormat::Int(8).quantize(2.5), 2.0);
        assert_eq!(NumFormat::Int(8).quantize(3.5), 4.0);
    }

    #[test]
    fn fp8_e4m3_max_and_rounding() {
        let f = NumFormat::Fp8E4M3;
        assert_eq!(f.quantize(448.0), 448.0);
        assert_eq!(f.quantize(1000.0), 448.0);
        assert_eq!(f.quantize(-1000.0), -448.0);
        // 1.0..2.0 has quantum 1/8
        assert_eq!(f.quantize(1.05), 1.0);
        assert_eq!(f.quantize(1.07), 1.125);
    }

    #[test]
    fn fp16_roundtrip_exact_values() {
        let f = NumFormat::Fp16;
        for v in [0.0f32, 1.0, -2.5, 65504.0, 0.000061035156] {
            assert_eq!(f.quantize(v), v, "f16-exact value {v}");
        }
        assert_eq!(f.quantize(1e9), 65504.0);
        // 1.0 + 2^-11 is exactly between 1.0 and 1.0 + 2^-10 → RNE → 1.0
        assert_eq!(f.quantize(1.0 + 2.0f32.powi(-11)), 1.0);
    }

    #[test]
    fn ufp8_is_unsigned_and_coarse() {
        let f = NumFormat::UFp8E6M2;
        // only 2 mantissa bits → quantum 1/4 in [1,2)
        assert_eq!(f.quantize(1.1), 1.0);
        assert_eq!(f.quantize(1.2), 1.25);
        assert!(f.max_value() > 1e9);
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["fp16", "fp8-e4m3", "fp8-e5m2", "fp4", "ufp8-e6m2", "int8", "int4"] {
            let f: NumFormat = s.parse().unwrap();
            let back: NumFormat = f.to_string().parse().unwrap();
            assert_eq!(f, back);
        }
        assert!("int99".parse::<NumFormat>().is_err());
        assert!("bf16".parse::<NumFormat>().is_err());
    }

    #[test]
    fn fp4_fast_path_matches_generic() {
        // Exhaustive-ish sweep incl. tie points: the comparison chain must
        // agree with the generic minifloat path everywhere.
        let mut i = -80000i64;
        while i <= 80000 {
            let x = i as f32 * 1e-4; // covers [-8, 8] at 1e-4 steps
            let fast = fp4_round_fast(x);
            let generic = minifloat_round(x, 2, 1, 1, 6.0);
            assert_eq!(fast, generic, "mismatch at {x}");
            i += 1;
        }
        for x in [0.25f32, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0, 6.0, 7.0, 1e9] {
            assert_eq!(fp4_round_fast(x), minifloat_round(x, 2, 1, 1, 6.0), "tie {x}");
            assert_eq!(fp4_round_fast(-x), minifloat_round(-x, 2, 1, 1, 6.0));
        }
    }

    #[test]
    fn quantize_is_idempotent() {
        for fmt in [
            NumFormat::Fp4E2M1,
            NumFormat::Fp8E4M3,
            NumFormat::Fp8E5M2,
            NumFormat::Fp16,
            NumFormat::Int(4),
            NumFormat::Int(8),
        ] {
            for i in -100..100 {
                let x = i as f32 * 0.37;
                let q = fmt.quantize(x);
                assert_eq!(fmt.quantize(q), q, "{fmt} at {x}");
            }
        }
    }
}
