//! Admission queue + batch-formation policy.
//!
//! Continuous batching with a KV-memory budget. The admission rule is
//! unit-agnostic: the scheduler passes the units already charged, a
//! budget, and a per-request cost projection. In **paged** mode
//! (default) the units are pool *blocks* — each active sequence is
//! charged its worst-case final footprint, so growth after admission
//! can never exhaust the [`crate::kv::BlockPool`]. In the legacy
//! per-sequence mode the units are bytes of chunked-cache residency
//! plus projected growth, exactly as in PR 1. Waiting requests queue
//! FIFO. The policy mirrors vLLM's admission control at the granularity
//! this engine needs.

use std::collections::VecDeque;

use super::request::{InFlight, Request};

/// Batching policy knobs.
///
/// `BatchPolicy` stays `Copy` — it is the value-type config surface the
/// benches sweep. The speculative-decode policy
/// ([`crate::spec::SpecPolicy`]) rides next to it instead of inside it,
/// because a drafter may own a whole draft `Model`; pass it through
/// [`Scheduler::with_spec`](super::scheduler::Scheduler::with_spec) or
/// [`Engine::start_with_spec`](super::engine::Engine::start_with_spec).
/// Speculation only applies in paged mode (`batched_decode = true`) —
/// the legacy per-sequence baseline has no rollback story.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max concurrently-active sequences (decode round width).
    pub max_active: usize,
    /// KV memory budget in bytes across active sequences. Paged mode
    /// converts this to a block budget for the shared pool; legacy mode
    /// budgets actual residency + projected growth against it directly.
    pub kv_budget_bytes: usize,
    /// Max prompts prefilled per scheduling round (prefill burst limit —
    /// keeps decode latency bounded while the queue drains).
    pub max_prefill_per_round: usize,
    /// `true` (default): paged serving — KV in the shared block pool
    /// with prefix sharing, batched multi-prompt prefill, and one fused
    /// ragged decode batch per round. `false` falls back to the
    /// per-sequence chunked-cache baseline (one batch-1 forward per
    /// sequence, weights re-streamed each time) — kept as the A/B lever
    /// for `benches/serving.rs`.
    pub batched_decode: bool,
    /// Within paged mode: pack every prompt admitted in a round into
    /// one fused ragged prefill (`false` prefills them one at a time —
    /// the prefill A/B lever).
    pub batched_prefill: bool,
    /// KV block storage dtype for the paged pool. `None` (default)
    /// inherits the model's `ModelConfig::kv_dtype`; `Some` overrides it
    /// per engine (the serving-time sweep lever). Quantized dtypes store
    /// blocks at ~¼ the bytes, so the same `kv_budget_bytes` admits ~4×
    /// the blocks.
    pub kv_dtype: Option<crate::kv::KvDtype>,
    /// Preemptive scheduling (paged mode only). `false` (default):
    /// admission reserves every active sequence's **worst-case** final
    /// footprint — safe, conservative, and the A/B baseline. `true`:
    /// admission charges only **resident** blocks (oversubscription),
    /// and when a round's staged rows no longer fit the pool, the
    /// scheduler swaps out the lowest-priority active sequence
    /// ([`crate::kv::BlockPool::suspend`]) instead of stalling; swapped
    /// sequences resume FIFO, ahead of any new admission, so no request
    /// can starve. Greedy output is bit-identical either way — only
    /// which rounds a sequence progresses in changes.
    pub preempt: bool,
    /// Optional cap on the paged pool's admission budget, in blocks
    /// (tighter of this and the byte-derived budget). The operator lever
    /// for deliberate KV pressure (`examples/serve.rs --max-resident`);
    /// `None` leaves the byte budget in charge.
    pub max_resident_blocks: Option<usize>,
    /// Anti-thrash hysteresis: a sequence resumed from the swapped
    /// queue cannot be preempted again for this many rounds, unless it
    /// is the only eligible victim left. Guards against swap-in/swap-out
    /// ping-pong under sustained pressure.
    pub resume_hysteresis_rounds: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_active: 8,
            kv_budget_bytes: 512 << 20,
            max_prefill_per_round: 4,
            batched_decode: true,
            batched_prefill: true,
            kv_dtype: None,
            preempt: false,
            max_resident_blocks: None,
            resume_hysteresis_rounds: 2,
        }
    }
}

/// FIFO admission queue.
#[derive(Debug, Default)]
pub struct Batcher {
    waiting: VecDeque<InFlight>,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn enqueue(&mut self, req: Request) {
        self.waiting.push_back(InFlight::new(req));
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Pop the head of the queue unconditionally (the scheduler's
    /// forced-admission path: an over-budget request still runs alone
    /// rather than livelocking the queue).
    pub fn pop_front(&mut self) -> Option<InFlight> {
        self.waiting.pop_front()
    }

    /// Remove a queued request by id before it reaches the scheduler
    /// (the gateway's queue-stage cancellation). Returns the removed
    /// entry so the caller can account for it; `None` if the id is not
    /// waiting here (already admitted, or never enqueued).
    pub fn cancel(&mut self, id: u64) -> Option<InFlight> {
        let i = self.waiting.iter().position(|f| f.req.id == id)?;
        self.waiting.remove(i)
    }

    /// Admit up to the policy limits given the current active set size,
    /// the KV units already charged against `kv_budget`, and a cost
    /// projection per waiting request (blocks in paged mode, bytes in
    /// legacy mode — see module docs). Admission stops at the first
    /// request whose projection would break the budget (FIFO — no
    /// starvation of large requests by skipping ahead).
    pub fn admit(
        &mut self,
        policy: &BatchPolicy,
        active: usize,
        kv_in_use: usize,
        kv_budget: usize,
        kv_cost: impl Fn(&Request) -> usize,
    ) -> Vec<InFlight> {
        let mut out = Vec::new();
        let mut kv = kv_in_use;
        while out.len() < policy.max_prefill_per_round && active + out.len() < policy.max_active
        {
            let cost = match self.waiting.front() {
                Some(f) => kv_cost(&f.req),
                None => break,
            };
            if kv + cost > kv_budget {
                break;
            }
            kv += cost;
            out.push(self.waiting.pop_front().expect("peeked"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1u8; 4], 8)
    }

    #[test]
    fn fifo_order() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let admitted = b.admit(&BatchPolicy::default(), 0, 0, usize::MAX, |_| 1);
        let ids: Vec<u64> = admitted.iter().map(|f| f.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]); // max_prefill_per_round = 4
        assert_eq!(b.waiting(), 1);
    }

    #[test]
    fn respects_max_active() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let policy = BatchPolicy { max_active: 3, ..Default::default() };
        let admitted = b.admit(&policy, 2, 0, usize::MAX, |_| 1);
        assert_eq!(admitted.len(), 1);
    }

    #[test]
    fn respects_kv_budget() {
        let mut b = Batcher::new();
        for i in 0..5 {
            b.enqueue(req(i));
        }
        // 60 units in use of 100, 30 projected per request → one fits.
        let admitted = b.admit(&BatchPolicy::default(), 0, 60, 100, |_| 30);
        assert_eq!(admitted.len(), 1);
    }

    #[test]
    fn budget_uses_per_request_projection() {
        let mut b = Batcher::new();
        // Alternating decode budgets → alternating projections.
        for i in 0..4 {
            b.enqueue(Request::new(i, vec![1u8; 4], if i % 2 == 0 { 8 } else { 64 }));
        }
        // Costs: 20, 70, 20, 70 → FIFO admits 20 + 70 = 90, then stops:
        // the third request's 20 would push residency to 110 > 100.
        let admitted = b.admit(
            &BatchPolicy::default(),
            0,
            0,
            100,
            |r| if r.max_new_tokens == 8 { 20 } else { 70 },
        );
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.waiting(), 2);
    }

    #[test]
    fn empty_queue() {
        let mut b = Batcher::new();
        assert!(b.admit(&BatchPolicy::default(), 0, 0, usize::MAX, |_| 1).is_empty());
        assert!(b.pop_front().is_none());
    }

    #[test]
    fn cancel_removes_only_the_target() {
        let mut b = Batcher::new();
        for i in 0..4 {
            b.enqueue(req(i));
        }
        assert_eq!(b.cancel(2).map(|f| f.req.id), Some(2));
        assert!(b.cancel(2).is_none(), "second cancel of the same id is a no-op");
        assert!(b.cancel(99).is_none());
        let admitted = b.admit(&BatchPolicy::default(), 0, 0, usize::MAX, |_| 1);
        let ids: Vec<u64> = admitted.iter().map(|f| f.req.id).collect();
        assert_eq!(ids, vec![0, 1, 3], "FIFO order preserved around the hole");
    }

    #[test]
    fn pop_front_bypasses_budget() {
        let mut b = Batcher::new();
        b.enqueue(req(9));
        // Zero budget admits nothing…
        assert!(b.admit(&BatchPolicy::default(), 0, 0, 0, |_| 1).is_empty());
        // …but the forced path still drains the queue head.
        assert_eq!(b.pop_front().unwrap().req.id, 9);
    }
}
