//! End-to-end compression pipeline (Fig. 7): applies a full
//! [`CompressionConfig`] to a weight matrix, producing an executable
//! [`CompressedLayer`] plus a quality/size report.
//!
//! The compressed layer carries everything the inference engine needs:
//! the dequantized weight view(s) for fake-quant evaluation, optional
//! packed N:M forms for the structured-sparse compute path, real packed
//! code planes ([`QuantMat`]) for quantized dense planes (served via the
//! fused GEMM, bit-identical to the f32 view), and the activation
//! formats each path expects (§5.1: `A_o` int8 / `A_i` fp4).


use super::calib::LayerStats;
use super::config::{CompressionConfig, QuantAlgo, Stages};
use super::gptq::gptq_fake_quant;
use super::decompose::decompose;
use super::packed::{pack, PackedNm};
use super::qmat::QuantMat;
use super::quantize::{quantize_tensor, QuantizedTensor, VsQuantCfg};
use super::sparsify::sparsify;
use crate::formats::NumFormat;
use crate::tensor::Matrix;
use crate::Result;

/// Density threshold below which the packed SpMM path beats dense GEMM
/// on CPU (gather overhead vs. skipped MACs). Tuned in benches/hotpath.
pub const PACK_DENSITY_THRESHOLD: f64 = 0.3;

/// How a compressed layer executes its GEMM.
#[derive(Clone, Debug)]
pub enum ExecPath {
    /// Single GEMM against one (possibly fake-quantized, possibly
    /// sparsified) weight view.
    Dense {
        w: Matrix,
        /// Quantize activations to this format before the GEMM
        /// (dual quantization); `None` keeps activations fp16/fp32.
        act_fmt: Option<NumFormat>,
        /// Packed form when the weight is structured-sparse enough.
        packed: Option<PackedNm>,
        /// Real packed codes for the quantized dense plane, served via
        /// the fused [`crate::tensor::matmul_q_into`] (bit-identical to
        /// the `w` GEMM). Built only when the plane actually executes
        /// dense (`packed.is_none()`) and the value format has a packed
        /// representation; `None` otherwise (fp16, GPTQ, SpMM plane).
        qw: Option<QuantMat>,
    },
    /// SDQ two-path execution: `Y = Q_o(X)·W_oᵀ + Q_i(X)·W_iᵀ` (Fig. 8).
    Decomposed {
        outlier_w: Matrix,
        outlier_packed: Option<PackedNm>,
        /// Packed codes for the outlier plane when it executes dense.
        outlier_q: Option<QuantMat>,
        outlier_act: NumFormat,
        inlier_w: Matrix,
        inlier_packed: Option<PackedNm>,
        /// Packed codes for the inlier plane when it executes dense.
        inlier_q: Option<QuantMat>,
        inlier_act: NumFormat,
    },
}

/// Per-layer compression report.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub config: String,
    /// Fraction of non-zero weights kept.
    pub density: f64,
    /// Relative Frobenius error of the executable weight view vs. the
    /// original dense weights.
    pub rel_err: f64,
    /// Average bits per (original) weight element incl. metadata (§3.3).
    pub bits_per_weight: f64,
    /// Effective compute-throughput multiplier (§3.1–3.2).
    pub effective_throughput: f64,
}

/// A compressed, executable linear layer.
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    pub path: ExecPath,
    pub report: LayerReport,
    /// Q-vector size used for dynamic activation quantization.
    pub qvec: usize,
}

/// Compress one `[out, in]` weight matrix per `cfg`.
///
/// `stats` carries calibration data for this layer (required by Wanda /
/// SparseGPT / the product decomposition metric).
pub fn compress_layer(
    name: &str,
    w: &Matrix,
    cfg: &CompressionConfig,
    stats: Option<&LayerStats>,
) -> Result<CompressedLayer> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    let fp16 = |m: &Matrix| {
        let mut out = m.clone();
        for v in &mut out.data {
            *v = NumFormat::Fp16.quantize(*v);
        }
        out
    };
    // VS-Quant a plane and keep *both* views: the dequantized f32
    // matrix (eval / quality accounting / bit-identity reference) and
    // the quantized tensor the packed code plane is built from.
    let vsq = |m: &Matrix, fmt: NumFormat| -> (Matrix, QuantizedTensor) {
        let qt = quantize_tensor(m, VsQuantCfg { fmt, qvec: cfg.qvec, scale_fmt: cfg.scale_fmt });
        (qt.dequantize(), qt)
    };

    let (path, rel_err, density) = match &cfg.stages {
        Stages::Dense => {
            let wq = fp16(w);
            let rel = wq.rel_frob_dist(w);
            (ExecPath::Dense { w: wq, act_fmt: None, packed: None, qw: None }, rel, 1.0)
        }
        Stages::SparsifyOnly(sp) => {
            let mut ws = w.clone();
            sparsify(&mut ws, *sp, stats)?;
            let ws = fp16(&ws);
            let rel = ws.rel_frob_dist(w);
            let density = 1.0 - ws.zero_fraction();
            let packed = (sp.pattern.density() <= PACK_DENSITY_THRESHOLD)
                .then(|| pack(&ws, sp.pattern))
                .transpose()?;
            (ExecPath::Dense { w: ws, act_fmt: None, packed, qw: None }, rel, density)
        }
        Stages::QuantOnly { weight_fmt, act_fmt, algo } => {
            let (wq, qw) = match algo {
                QuantAlgo::VsQuant => {
                    let (wq, qt) = vsq(w, *weight_fmt);
                    (wq, QuantMat::try_from_tensor(&qt))
                }
                QuantAlgo::Gptq => {
                    // GPTQ rounds in a data-dependent order and never
                    // materializes a QuantizedTensor → no packed plane.
                    let gram = stats
                        .and_then(|st| st.finalized_gram())
                        .ok_or_else(|| anyhow::anyhow!("GPTQ requires Gram calibration"))?;
                    let mut wq = w.clone();
                    gptq_fake_quant(&mut wq, &gram, *weight_fmt, cfg.qvec, cfg.scale_fmt)?;
                    (wq, None)
                }
            };
            let rel = wq.rel_frob_dist(w);
            (ExecPath::Dense { w: wq, act_fmt: *act_fmt, packed: None, qw }, rel, 1.0)
        }
        Stages::Sdq { sparsify: sp, decompose: dc } => {
            let mut ws = w.clone();
            if let Some(sp) = sp {
                sparsify(&mut ws, *sp, stats)?;
            }
            let parts = decompose(&ws, dc, stats, cfg.qvec)?;
            let (out_q, out_qt) = vsq(&parts.outliers, dc.outlier_fmt);
            let (in_q, in_qt) = vsq(&parts.inliers, dc.inlier_fmt);
            // Quality accounting against the original dense weights.
            let mut sum = out_q.clone();
            for (s, i) in sum.data.iter_mut().zip(&in_q.data) {
                *s += *i;
            }
            let rel = sum.rel_frob_dist(w);
            let density = 1.0 - ws.zero_fraction();
            let outlier_packed =
                (dc.outlier_pattern.density() <= PACK_DENSITY_THRESHOLD)
                    .then(|| pack(&out_q, dc.outlier_pattern))
                    .transpose()?;
            let inlier_packed = (dc.inlier_pattern.density() <= PACK_DENSITY_THRESHOLD)
                .then(|| pack(&in_q, dc.inlier_pattern))
                .transpose()?;
            // Packed codes only for planes that execute as dense GEMM —
            // a plane with an SpMM form never streams its dense codes.
            let outlier_q =
                outlier_packed.is_none().then(|| QuantMat::try_from_tensor(&out_qt)).flatten();
            let inlier_q =
                inlier_packed.is_none().then(|| QuantMat::try_from_tensor(&in_qt)).flatten();
            (
                ExecPath::Decomposed {
                    outlier_w: out_q,
                    outlier_packed,
                    outlier_q,
                    outlier_act: dc.outlier_fmt,
                    inlier_w: in_q,
                    inlier_packed,
                    inlier_q,
                    inlier_act: dc.inlier_fmt,
                },
                rel,
                density,
            )
        }
    };

    let report = LayerReport {
        name: name.to_string(),
        config: cfg.to_string(),
        density,
        rel_err,
        bits_per_weight: crate::perfmodel::bits_per_weight(cfg),
        effective_throughput: cfg.effective_throughput(),
    };
    Ok(CompressedLayer { path, report, qvec: cfg.qvec })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdq::calib::CalibStats;
    use crate::util::rng::Rng;

    fn rand_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seed_from_u64(seed);
        Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    fn calib(d: usize, seed: u64) -> CalibStats {
        let mut st = CalibStats::new(true);
        st.observe("l", &rand_matrix(128, d, seed));
        st
    }

    #[test]
    fn dense_is_nearly_lossless() {
        let w = rand_matrix(8, 32, 1);
        let c = compress_layer("l", &w, &"Dense-WA16".parse().unwrap(), None).unwrap();
        assert!(c.report.rel_err < 1e-3);
        assert_eq!(c.report.effective_throughput, 1.0);
    }

    #[test]
    fn sdq_full_stack_runs_and_partitions() {
        let w = rand_matrix(16, 64, 2);
        let st = calib(64, 3);
        let cfg: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
        let c = compress_layer("l", &w, &cfg, st.get("l")).unwrap();
        match &c.path {
            ExecPath::Decomposed { outlier_w, inlier_w, outlier_packed, inlier_packed, .. } => {
                // outlier path is 1:8 → packed; inlier 6:8 → dense
                assert!(outlier_packed.is_some());
                assert!(inlier_packed.is_none());
                // disjoint support
                for (o, i) in outlier_w.data.iter().zip(&inlier_w.data) {
                    assert!(*o == 0.0 || *i == 0.0);
                }
            }
            _ => panic!("expected decomposed path"),
        }
        assert!((c.report.density - 7.0 / 8.0).abs() < 0.02);
        assert!(c.report.rel_err < 0.2);
    }

    #[test]
    fn error_ordering_across_methods() {
        // SDQ must beat plain 4-bit dual quant on reconstruction error for
        // outlier-heavy weights.
        let mut w = rand_matrix(32, 128, 4);
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..120 {
            let i = rng.below(w.data.len());
            w.data[i] = rng.range_f32(4.0, 8.0) * if rng.bool(0.5) { 1.0 } else { -1.0 };
        }
        let st = calib(128, 6);
        let q4 = compress_layer("l", &w, &"Q-VSQuant-WAfp4".parse().unwrap(), None).unwrap();
        let sdq = compress_layer(
            "l",
            &w,
            &"SDQ-8:8-1:8int8-7:8fp4".parse().unwrap(),
            st.get("l"),
        )
        .unwrap();
        assert!(
            sdq.report.rel_err < q4.report.rel_err,
            "SDQ ({}) must beat fp4 dual-quant ({}) on outlier-heavy weights",
            sdq.report.rel_err,
            q4.report.rel_err
        );
    }

    #[test]
    fn sparsify_only_reports_density() {
        let w = rand_matrix(8, 64, 7);
        let st = calib(64, 8);
        let c = compress_layer("l", &w, &"S-Wanda-4:8".parse().unwrap(), st.get("l")).unwrap();
        assert!((c.report.density - 0.5).abs() < 0.02);
        match &c.path {
            // 4:8 density (0.5) is above PACK_DENSITY_THRESHOLD (0.3):
            // dense GEMM beats the gather SpMM there (hotpath bench).
            ExecPath::Dense { packed, .. } => assert!(packed.is_none()),
            _ => panic!(),
        }
        // 2:8 is below the threshold -> packed path.
        let c = compress_layer("l", &w, &"S-Wanda-2:8".parse().unwrap(), st.get("l")).unwrap();
        match &c.path {
            ExecPath::Dense { packed, .. } => assert!(packed.is_some()),
            _ => panic!(),
        }
    }

    #[test]
    fn quant_only_sets_act_fmt() {
        let w = rand_matrix(4, 32, 9);
        let c =
            compress_layer("l", &w, &"Q-VSQuant-WAint8".parse().unwrap(), None).unwrap();
        match &c.path {
            ExecPath::Dense { act_fmt, .. } => assert_eq!(*act_fmt, Some(NumFormat::Int(8))),
            _ => panic!(),
        }
    }
}
