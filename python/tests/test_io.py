"""SDQW1 bundle format round-trip (python side)."""

import numpy as np

from compile import io


def test_roundtrip(tmp_path):
    cfg = {"name": "x", "d_model": 32}
    tensors = {
        "b": np.arange(6, dtype=np.float32).reshape(2, 3),
        "a": np.array([1.5, -2.5], dtype=np.float32),  # 1-D promoted to [1,2]
    }
    p = tmp_path / "w.bin"
    io.save_weights(p, cfg, tensors)
    cfg2, t2 = io.load_weights(p)
    assert cfg2 == cfg
    np.testing.assert_array_equal(t2["b"], tensors["b"])
    assert t2["a"].shape == (1, 2)


def test_sorted_order_on_disk(tmp_path):
    """Tensor data must be laid out in sorted-name order (the contract
    with the Rust loader and the AOT parameter ordering)."""
    p = tmp_path / "w.bin"
    io.save_weights(
        p,
        {},
        {"z": np.full((1, 1), 9.0, np.float32), "a": np.full((1, 1), 1.0, np.float32)},
    )
    _, t = io.load_weights(p)
    raw = p.read_bytes()
    data = np.frombuffer(raw[-8:], dtype="<f4")
    assert data[0] == 1.0 and data[1] == 9.0  # 'a' first
