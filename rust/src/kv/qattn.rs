//! Quantized-domain attention kernels: compute over raw KV codes.
//!
//! The scratch route ([`super::BlockPool::layer_views`]) services a
//! quantized pool by dequantizing every resident block's K/V rows into
//! an fp32 [`super::KvScratch`] arena each layer, then attending over
//! the borrowed fp32 segments. At int8's 4× residency that staging copy
//! — write `rows × d` floats, read them straight back — is pure memory
//! traffic: the decode itself is one multiply per element.
//!
//! This module is the fused alternative ([`super::BlockPool::
//! layer_code_views`] hands out [`QuantSeg`]s): attention streams the
//! 1-byte codes directly and decodes **in register**, inside the dot /
//! accumulate loops, with the block's per-layer scale applied per
//! element. No scratch write, no fp32 re-read — the win the pool's
//! `dequant_bytes_avoided` counter measures.
//!
//! # Bit-exactness
//!
//! These kernels are bit-identical to dequantize-then-attend for *all*
//! quantized dtypes, which is what lets the serving path switch over
//! without disturbing any pinned logits:
//!
//! * each element decodes as `fl(raw(code) · scale)` — exactly the op
//!   `KvStore::dequant_into` applies (int8: `code as f32`, exact; fp8:
//!   a 256-entry table of the pure [`super::fp8_e4m3_decode`]; int4:
//!   sign-extended nibble `as f32`, exact — and an int4 **outlier** row
//!   resolves to its stored f32s, so its dot *is*
//!   [`crate::tensor::dot`] and its axpy replays the fp32 loop);
//! * [`dot_head`] then replays [`crate::tensor::dot`]'s exact
//!   schedule (32-lane accumulator array, pairwise tree reduction,
//!   scalar tail) over the decoded values, and [`axpy_head`] replays
//!   attention's elementwise `out += w · v`.
//!
//! Same inputs, same ops, same order ⇒ same f32 bits. The property
//! tests in `tests/qattn.rs` pin this against the scratch route under
//! random block boundaries, amax growth, COW forks and truncation.
//!
//! The issue's `score_blk = scale_k · Σ q·code` factoring (hoisting the
//! scale out of the partial dot) is mathematically equal for int8 but
//! *not* bit-equal under f32 rounding; decoding in register keeps the
//! fusion win while staying on the dequantize path's exact bit pattern.

use std::sync::OnceLock;

use super::store::{fp8_e4m3_decode, nib_at, KvDtype};

/// One block's worth of raw K or V codes for one layer, plus the
/// effective decode scale (`amax / code_max`), in the slab layout
/// `KvStore` keeps: one byte per element (int8 / fp8-e4m3), or packed
/// nibbles with an exact-f32 outlier side-table (dense-and-sparse
/// int4). Row-major either way.
#[derive(Clone, Copy, Debug)]
pub enum QuantSeg<'a> {
    /// `rows × d` one-byte codes.
    Byte { codes: &'a [u8], scale: f32 },
    /// `rows × d.div_ceil(2)` packed nibble bytes; `outliers` is the
    /// slab's sorted `(row, exact f32 row)` side-table (rows in it have
    /// zero nibbles in `codes` and decode from the table instead).
    Nibble { codes: &'a [u8], scale: f32, outliers: &'a [(u16, Vec<f32>)] },
}

impl QuantSeg<'_> {
    /// Stored elements this segment covers (`rows × d` — the packed
    /// nibble byte count is divided back out), for shape checks.
    pub fn elems(&self, d: usize) -> usize {
        match self {
            QuantSeg::Byte { codes, .. } => codes.len(),
            QuantSeg::Nibble { codes, .. } => codes.len() / d.div_ceil(2) * d,
        }
    }
}

/// One row's head-column span resolved out of a [`QuantSeg`] — what the
/// kernels below actually consume. `Exact` is the int4 outlier-row
/// override: the row never had quantized codes, so the kernels fall
/// back to the plain fp32 ops (identical to the scratch route's).
#[derive(Clone, Copy, Debug)]
pub enum HeadCodes<'a> {
    /// `dh` one-byte codes.
    Byte { codes: &'a [u8], scale: f32 },
    /// One full packed nibble row; the head span starts at element
    /// `start` (a nibble, not byte, offset — head columns may straddle
    /// a byte).
    Nibble { row: &'a [u8], start: usize, scale: f32 },
    /// Exact f32 head slice of an int4 outlier row.
    Exact(&'a [f32]),
}

/// 256-entry decode table for fp8-e4m3 codes. [`fp8_e4m3_decode`] is a
/// pure function of the byte, so a table lookup is bit-identical to
/// calling it — it just drops the per-element branch chain.
fn fp8_lut() -> &'static [f32; 256] {
    static LUT: OnceLock<[f32; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0.0f32; 256];
        for (b, slot) in t.iter_mut().enumerate() {
            *slot = fp8_e4m3_decode(b as u8);
        }
        t
    })
}

/// Decode one raw code byte (scale not yet applied).
#[inline]
pub fn raw_decode(dtype: KvDtype, b: u8) -> f32 {
    match dtype {
        KvDtype::Int8 => (b as i8) as f32,
        KvDtype::Fp8E4M3 => fp8_lut()[b as usize],
        KvDtype::Int4Outlier => unreachable!("int4 decodes nibbles, not whole bytes"),
        KvDtype::F32 => unreachable!("f32 pools read zero-copy, not via codes"),
    }
}

/// Dot product of an fp32 query head slice against a quantized K head
/// span, decoding in register. Bit-identical to
/// `dot(q, dequantized_k_row)` — see the module docs. The `Exact` arm
/// (int4 outlier row) *is* [`crate::tensor::dot`] over the stored f32s,
/// so it matches the scratch route by construction.
#[inline]
pub fn dot_head(q: &[f32], hc: HeadCodes, dtype: KvDtype) -> f32 {
    match hc {
        HeadCodes::Byte { codes, scale } => {
            debug_assert_eq!(q.len(), codes.len());
            match dtype {
                KvDtype::Int8 => dot_head_at(q, |i| (codes[i] as i8) as f32 * scale),
                KvDtype::Fp8E4M3 => {
                    let lut = fp8_lut();
                    dot_head_at(q, |i| lut[codes[i] as usize] * scale)
                }
                _ => unreachable!("byte codes are int8/fp8 only"),
            }
        }
        HeadCodes::Nibble { row, start, scale } => {
            dot_head_at(q, |i| nib_at(row, start + i) as f32 * scale)
        }
        HeadCodes::Exact(vals) => crate::tensor::dot(q, vals),
    }
}

/// The [`crate::tensor::dot`] schedule — 32 independent
/// accumulators, pairwise tree reduction, scalar tail — replayed over
/// `get(i)` elements (each a `fl(code · scale)` decode). Any change
/// here must stay in lockstep with `dot` or the bit-exactness pins
/// break.
#[inline]
fn dot_head_at(x: &[f32], get: impl Fn(usize) -> f32) -> f32 {
    let n = x.len();
    const W: usize = 32;
    let mut acc = [0.0f32; W];
    let chunks = n / W;
    for i in 0..chunks {
        let xi = &x[i * W..i * W + W];
        for l in 0..W {
            acc[l] += xi[l] * get(i * W + l);
        }
    }
    let mut width = W / 2;
    while width > 0 {
        for l in 0..width {
            acc[l] += acc[l + width];
        }
        width /= 2;
    }
    let mut s = acc[0];
    for i in chunks * W..n {
        s += x[i] * get(i);
    }
    s
}

/// `out[l] += w · decode(l)` — the score·V accumulation with the
/// V decode fused in. Bit-identical to the fp32 path's
/// `out += w · v_row` over a dequantized row (the `Exact` arm replays
/// that loop verbatim over the stored outlier f32s).
#[inline]
pub fn axpy_head(out: &mut [f32], w: f32, hc: HeadCodes, dtype: KvDtype) {
    match hc {
        HeadCodes::Byte { codes, scale } => match dtype {
            KvDtype::Int8 => {
                for (o, &b) in out.iter_mut().zip(codes) {
                    *o += w * ((b as i8) as f32 * scale);
                }
            }
            KvDtype::Fp8E4M3 => {
                let lut = fp8_lut();
                for (o, &b) in out.iter_mut().zip(codes) {
                    *o += w * (lut[b as usize] * scale);
                }
            }
            _ => unreachable!("byte codes are int8/fp8 only"),
        },
        HeadCodes::Nibble { row, start, scale } => {
            for (i, o) in out.iter_mut().enumerate() {
                *o += w * (nib_at(row, start + i) as f32 * scale);
            }
        }
        HeadCodes::Exact(vals) => {
            for (o, vv) in out.iter_mut().zip(vals) {
                *o += w * vv;
            }
        }
    }
}

/// Decode a head span into `dst` (`dst[l] = decode(l)`) — used to fill
/// the per-head K panel that RoPE rotates in place. Same per-element op
/// as `KvStore::dequant_into` (outlier rows copy their exact f32s), so
/// the panel holds the same bits the scratch route would have copied in.
#[inline]
pub fn decode_head_into(dst: &mut [f32], hc: HeadCodes, dtype: KvDtype) {
    match hc {
        HeadCodes::Byte { codes, scale } => {
            debug_assert_eq!(dst.len(), codes.len());
            match dtype {
                KvDtype::Int8 => {
                    for (o, &b) in dst.iter_mut().zip(codes) {
                        *o = (b as i8) as f32 * scale;
                    }
                }
                KvDtype::Fp8E4M3 => {
                    let lut = fp8_lut();
                    for (o, &b) in dst.iter_mut().zip(codes) {
                        *o = lut[b as usize] * scale;
                    }
                }
                _ => unreachable!("byte codes are int8/fp8 only"),
            }
        }
        HeadCodes::Nibble { row, start, scale } => {
            for (i, o) in dst.iter_mut().enumerate() {
                *o = nib_at(row, start + i) as f32 * scale;
            }
        }
        HeadCodes::Exact(vals) => dst.copy_from_slice(vals),
    }
}

/// Head-column span of a quantized row: the code analogue of the fp32
/// path's `seg_head`. `r` is the absolute row over the concatenated
/// segments (`seg_tokens` rows per segment), `col0..col0+dh` the head
/// columns. Int4 outlier rows resolve to their exact f32 span here, so
/// every kernel sees the override uniformly.
#[inline]
pub fn seg_head_codes<'a>(
    segs: &[QuantSeg<'a>],
    seg_tokens: usize,
    d: usize,
    col0: usize,
    dh: usize,
    r: usize,
) -> HeadCodes<'a> {
    let row = r % seg_tokens;
    match &segs[r / seg_tokens] {
        QuantSeg::Byte { codes, scale } => {
            HeadCodes::Byte { codes: &codes[row * d + col0..][..dh], scale: *scale }
        }
        QuantSeg::Nibble { codes, scale, outliers } => {
            match outliers.binary_search_by_key(&(row as u16), |(rr, _)| *rr) {
                Ok(i) => HeadCodes::Exact(&outliers[i].1[col0..col0 + dh]),
                Err(_) => {
                    let stride = d.div_ceil(2);
                    HeadCodes::Nibble {
                        row: &codes[row * stride..(row + 1) * stride],
                        start: col0,
                        scale: *scale,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;

    fn codes_and_floats(dtype: KvDtype, n: usize, seed: u64) -> (Vec<u8>, Vec<f32>, f32) {
        let mut s = seed;
        let mut next = move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
            (s >> 33) as u32
        };
        let scale = 0.0173f32;
        let codes: Vec<u8> = (0..n)
            .map(|_| {
                let b: i32 = match dtype {
                    KvDtype::Int8 => (next() % 255) as i32 - 127,
                    _ => {
                        // Any non-NaN fp8 byte pattern.
                        let mut b = (next() % 256) as i32;
                        if b & 0x7f == 0x7f {
                            b &= !0x08;
                        }
                        b
                    }
                };
                b as u8
            })
            .collect();
        let deq: Vec<f32> = codes.iter().map(|&b| raw_decode(dtype, b) * scale).collect();
        (codes, deq, scale)
    }

    #[test]
    fn fp8_lut_matches_decoder() {
        for b in 0..=255u8 {
            assert_eq!(fp8_lut()[b as usize].to_bits(), fp8_e4m3_decode(b).to_bits());
        }
    }

    #[test]
    fn dot_head_bit_matches_dequant_then_dot() {
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            // 67 exercises two 32-lane chunks plus the scalar tail.
            for n in [8usize, 32, 67] {
                let (codes, deq, scale) = codes_and_floats(dtype, n, 7 + n as u64);
                let q: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
                let fused =
                    dot_head(&q, HeadCodes::Byte { codes: &codes, scale }, dtype);
                let reference = dot(&q, &deq);
                assert_eq!(fused.to_bits(), reference.to_bits(), "{dtype:?} n={n}");
            }
        }
    }

    fn nibble_row(n: usize, seed: u64) -> (Vec<u8>, Vec<f32>, f32) {
        let mut s = seed;
        let mut next = move || {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
            (s >> 33) as u32
        };
        let scale = 0.31f32;
        let mut packed = vec![0u8; n.div_ceil(2)];
        let mut deq = Vec::with_capacity(n);
        for i in 0..n {
            let c = (next() % 15) as i8 - 7;
            packed[i / 2] |= ((c as u8) & 0x0f) << (4 * (i % 2));
            deq.push(c as f32 * scale);
        }
        (packed, deq, scale)
    }

    #[test]
    fn nibble_dot_head_bit_matches_dequant_then_dot() {
        for n in [8usize, 32, 67] {
            let (packed, deq, scale) = nibble_row(n, 11 + n as u64);
            let q: Vec<f32> = (0..n).map(|i| (i as f32 * 0.41).cos()).collect();
            let fused = dot_head(
                &q,
                HeadCodes::Nibble { row: &packed, start: 0, scale },
                KvDtype::Int4Outlier,
            );
            assert_eq!(fused.to_bits(), dot(&q, &deq).to_bits(), "n={n}");
        }
    }

    #[test]
    fn nibble_head_span_may_straddle_a_byte() {
        // start = 3 (odd): the span begins on a high nibble.
        let (packed, deq, scale) = nibble_row(16, 23);
        let (start, dh) = (3, 8);
        let q: Vec<f32> = (0..dh).map(|i| 0.2 + i as f32 * 0.1).collect();
        let fused = dot_head(
            &q,
            HeadCodes::Nibble { row: &packed, start, scale },
            KvDtype::Int4Outlier,
        );
        assert_eq!(fused.to_bits(), dot(&q, &deq[start..start + dh]).to_bits());
        let mut dst = vec![0.0f32; dh];
        decode_head_into(
            &mut dst,
            HeadCodes::Nibble { row: &packed, start, scale },
            KvDtype::Int4Outlier,
        );
        for (a, b) in dst.iter().zip(&deq[start..start + dh]) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn exact_arm_matches_f32_ops() {
        let vals: Vec<f32> = (0..12).map(|i| (i as f32 * 0.7).sin() * 40.0).collect();
        let q: Vec<f32> = (0..12).map(|i| 0.05 * i as f32 - 0.3).collect();
        let fused = dot_head(&q, HeadCodes::Exact(&vals), KvDtype::Int4Outlier);
        assert_eq!(fused.to_bits(), dot(&q, &vals).to_bits());
        let mut fused_o: Vec<f32> = (0..12).map(|i| i as f32 * 0.01).collect();
        let mut ref_o = fused_o.clone();
        axpy_head(&mut fused_o, 0.375, HeadCodes::Exact(&vals), KvDtype::Int4Outlier);
        for (o, vv) in ref_o.iter_mut().zip(&vals) {
            *o += 0.375 * vv;
        }
        for (a, b) in fused_o.iter().zip(&ref_o) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn axpy_head_bit_matches_dequant_then_axpy() {
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let n = 24;
            let (codes, deq, scale) = codes_and_floats(dtype, n, 99);
            let mut fused: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
            let mut reference = fused.clone();
            axpy_head(&mut fused, 0.625, HeadCodes::Byte { codes: &codes, scale }, dtype);
            for (o, &v) in reference.iter_mut().zip(&deq) {
                *o += 0.625 * v;
            }
            for (a, b) in fused.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?}");
            }
        }
        let n = 24;
        let (packed, deq, scale) = nibble_row(n, 101);
        let mut fused: Vec<f32> = (0..n).map(|i| i as f32 * 0.02).collect();
        let mut reference = fused.clone();
        axpy_head(
            &mut fused,
            0.625,
            HeadCodes::Nibble { row: &packed, start: 0, scale },
            KvDtype::Int4Outlier,
        );
        for (o, &v) in reference.iter_mut().zip(&deq) {
            *o += 0.625 * v;
        }
        for (a, b) in fused.iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits(), "int4");
        }
    }

    #[test]
    fn decode_head_matches_reference() {
        for dtype in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let (codes, deq, scale) = codes_and_floats(dtype, 16, 5);
            let mut dst = vec![0.0f32; 16];
            decode_head_into(&mut dst, HeadCodes::Byte { codes: &codes, scale }, dtype);
            for (a, b) in dst.iter().zip(&deq) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn seg_head_codes_walks_segments() {
        let (d, st, dh) = (4, 2, 2);
        let a: Vec<u8> = (0..st * d).map(|i| i as u8).collect();
        let b: Vec<u8> = (0..st * d).map(|i| 100 + i as u8).collect();
        let segs = [
            QuantSeg::Byte { codes: &a, scale: 1.0 },
            QuantSeg::Byte { codes: &b, scale: 2.0 },
        ];
        match seg_head_codes(&segs, st, d, 2, dh, 3) {
            HeadCodes::Byte { codes, scale } => {
                assert_eq!(codes, &[106, 107]);
                assert_eq!(scale, 2.0);
            }
            other => panic!("expected byte span, got {other:?}"),
        }
    }

    #[test]
    fn seg_head_codes_resolves_nibble_outlier_rows() {
        let d = 4; // stride 2
        let st = 2;
        let codes: Vec<u8> = vec![0x21, 0x43, 0, 0]; // row 0 dense, row 1 zeroed
        let exact = vec![10.0f32, -20.0, 30.0, -40.0];
        let outliers = vec![(1u16, exact.clone())];
        let segs = [QuantSeg::Nibble { codes: &codes, scale: 0.5, outliers: &outliers }];
        match seg_head_codes(&segs, st, d, 2, 2, 0) {
            HeadCodes::Nibble { row, start, scale } => {
                assert_eq!(row, &[0x21, 0x43]);
                assert_eq!(start, 2);
                assert_eq!(scale, 0.5);
                assert_eq!(nib_at(row, 2), 3);
                assert_eq!(nib_at(row, 3), 4);
            }
            other => panic!("expected nibble span, got {other:?}"),
        }
        match seg_head_codes(&segs, st, d, 2, 2, 1) {
            HeadCodes::Exact(vals) => assert_eq!(vals, &[30.0, -40.0]),
            other => panic!("expected exact override, got {other:?}"),
        }
    }
}
