//! Block storage backends: fp32 or quantized (fp8-e4m3 / int8) with
//! per-block, per-layer K/V scales.
//!
//! A [`KvStore`] holds one block's K and V rows for every layer. The
//! `F32` variant is the exact baseline (rows stored verbatim). The `Q8`
//! variant stores one byte per element plus, per layer and per side (K
//! or V), a single `amax` — the running max-abs over the rows written so
//! far. The effective scale is `amax / code_max` (127 for int8, 448 for
//! fp8-e4m3), so every committed row decodes as `code · scale`.
//!
//! Rows arrive append-only (the pool's staged-write discipline). When a
//! new row raises `amax`, the rows already in the slab are requantized
//! onto the new scale (decode with the old scale, re-encode with the
//! new). A slab never holds more than `KV_BLOCK_TOKENS` rows, so the
//! rescale is a bounded, block-local walk — and because rows are always
//! written in order, the final codes are a pure function of the row
//! values, which keeps freeze-time dedup exact: identical token chains
//! produce bit-identical quantized blocks.

use crate::formats::NumFormat;

/// Storage dtype for KV blocks (the `kv_dtype` knob).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum KvDtype {
    /// Exact fp32 rows (the baseline; zero-copy reads).
    #[default]
    F32,
    /// OCP fp8-e4m3 codes with per-block-per-layer f32 scales.
    Fp8E4M3,
    /// Symmetric int8 codes with per-block-per-layer f32 scales.
    Int8,
}

impl KvDtype {
    /// Storage bytes per stored K/V element.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::Fp8E4M3 | KvDtype::Int8 => 1,
        }
    }

    /// Scale metadata bytes per (layer, K/V side) per block: one f32
    /// `amax` for quantized stores, nothing for fp32.
    pub fn scale_bytes(self) -> usize {
        match self {
            KvDtype::F32 => 0,
            KvDtype::Fp8E4M3 | KvDtype::Int8 => 4,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Fp8E4M3 => "fp8-e4m3",
            KvDtype::Int8 => "int8",
        }
    }

    /// Parse the CLI/JSON spelling (accepts the same aliases as
    /// [`crate::formats::NumFormat`] where they overlap).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" | "fp32" => Ok(KvDtype::F32),
            "fp8" | "fp8-e4m3" | "fp8e4m3" => Ok(KvDtype::Fp8E4M3),
            "int8" => Ok(KvDtype::Int8),
            _ => anyhow::bail!("unknown kv dtype: {s} (expected f32 | fp8-e4m3 | int8)"),
        }
    }

    /// Largest code magnitude of the storage grid — the scale anchor
    /// (`scale = amax / code_max`).
    pub(crate) fn code_max(self) -> f32 {
        match self {
            KvDtype::F32 => unreachable!("f32 blocks are not scaled"),
            KvDtype::Fp8E4M3 => 448.0,
            KvDtype::Int8 => 127.0,
        }
    }
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.tag())
    }
}

/// Encode an (already scale-normalized) value to an fp8-e4m3 byte:
/// sign(1) · exponent(4, bias 7) · mantissa(3), round-to-nearest-even,
/// clamped to ±448. The NaN patterns (`0x7f`/`0xff`) are never produced.
pub fn fp8_e4m3_encode(x: f32) -> u8 {
    // Snap onto the grid first (RNE, clamp) so the bit extraction below
    // is exact: an on-grid value has at most 3 significant mantissa bits.
    let q = NumFormat::Fp8E4M3.quantize(if x.is_nan() { 0.0 } else { x });
    let sign = if q.is_sign_negative() { 0x80u8 } else { 0 };
    let a = q.abs();
    if a == 0.0 {
        return sign;
    }
    let bits = a.to_bits();
    let e = ((bits >> 23) & 0xff) as i32 - 127;
    if e < -6 {
        // Subnormal: a = m · 2⁻⁹ with m ∈ 1..=7 exactly on-grid.
        sign | (a * 512.0) as u8
    } else {
        let mant = ((bits >> 20) & 0x7) as u8;
        sign | (((e + 7) as u8) << 3) | mant
    }
}

/// Decode an fp8-e4m3 byte back to f32 (exact).
pub fn fp8_e4m3_decode(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0xf) as i32;
    let m = (b & 0x7) as f32;
    if e == 0 {
        sign * m * (1.0 / 512.0) // subnormal: m · 2⁻⁹
    } else {
        sign * (1.0 + m / 8.0) * (2.0f32).powi(e - 7)
    }
}

/// Encode one element under `scale` (`amax / code_max`).
#[inline]
fn enc(dtype: KvDtype, scale: f32, x: f32) -> u8 {
    if scale == 0.0 {
        return 0;
    }
    match dtype {
        KvDtype::F32 => unreachable!("f32 rows are stored verbatim"),
        KvDtype::Int8 => (x / scale).round_ties_even().clamp(-127.0, 127.0) as i8 as u8,
        KvDtype::Fp8E4M3 => fp8_e4m3_encode(x / scale),
    }
}

/// Decode one element under `scale`.
#[inline]
fn dec(dtype: KvDtype, scale: f32, b: u8) -> f32 {
    match dtype {
        KvDtype::F32 => unreachable!("f32 rows are stored verbatim"),
        KvDtype::Int8 => (b as i8) as f32 * scale,
        KvDtype::Fp8E4M3 => fp8_e4m3_decode(b) * scale,
    }
}

/// One block's K/V payload for all layers (layer-major slabs of
/// `block_tokens × d`, exactly like the fp32 layout it generalizes).
/// `Clone` is the speculative-decode checkpoint primitive: a clone of a
/// partial tail block (codes *and* scales) is a bit-exact snapshot that
/// [`super::BlockPool::rollback`] can re-install after rejected drafts.
/// `PartialEq` compares payload bytes and scales exactly — the guard a
/// preemption resume uses before re-attaching an indexed block in place
/// of its swapped-out copy (quantized codes must match bit-for-bit or
/// the resume installs its own snapshot bytes instead).
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum KvStore {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
    },
    Q8 {
        dtype: KvDtype,
        k: Vec<u8>,
        v: Vec<u8>,
        /// Per-layer running max-abs of the K rows written so far
        /// (`scale = amax / code_max`).
        k_amax: Vec<f32>,
        /// Per-layer running max-abs of the V rows.
        v_amax: Vec<f32>,
    },
}

impl KvStore {
    pub fn new(dtype: KvDtype, n_layer: usize, block_tokens: usize, d: usize) -> Self {
        let n = n_layer * block_tokens * d;
        match dtype {
            KvDtype::F32 => KvStore::F32 { k: vec![0.0; n], v: vec![0.0; n] },
            _ => KvStore::Q8 {
                dtype,
                k: vec![0; n],
                v: vec![0; n],
                k_amax: vec![0.0; n_layer],
                v_amax: vec![0.0; n_layer],
            },
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match self {
            KvStore::F32 { .. } => KvDtype::F32,
            KvStore::Q8 { dtype, .. } => *dtype,
        }
    }

    /// Reset per-slot state on (re)allocation. Quantized scales MUST be
    /// cleared: a stale `amax` from the slot's previous tenant would
    /// change the codes new rows quantize to, breaking the determinism
    /// freeze-time dedup relies on. Codes/rows need no clearing — reads
    /// never pass the written row count.
    pub fn reset(&mut self) {
        if let KvStore::Q8 { k_amax, v_amax, .. } = self {
            k_amax.fill(0.0);
            v_amax.fill(0.0);
        }
    }

    /// Stage the K/V row for layer `li` at block-local row index `row`.
    /// Quantized stores grow the layer's scale first if this row raises
    /// `amax`, requantizing the rows already in the slab.
    pub fn write_row(
        &mut self,
        li: usize,
        row: usize,
        bt: usize,
        d: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let base = li * bt * d + row * d;
        match self {
            KvStore::F32 { k, v } => {
                k[base..base + d].copy_from_slice(k_row);
                v[base..base + d].copy_from_slice(v_row);
            }
            KvStore::Q8 { dtype, k, v, k_amax, v_amax } => {
                let slab = li * bt * d;
                write_side(*dtype, &mut k[slab..slab + bt * d], &mut k_amax[li], row, d, k_row);
                write_side(*dtype, &mut v[slab..slab + bt * d], &mut v_amax[li], row, d, v_row);
            }
        }
    }

    /// Copy the first `rows` rows of every layer from `src` (the
    /// copy-on-write path). Scales come along verbatim: the source's
    /// `amax` covers exactly its committed rows, so the copy decodes
    /// bit-identically.
    pub fn copy_rows_from(
        &mut self,
        src: &KvStore,
        rows: usize,
        n_layer: usize,
        bt: usize,
        d: usize,
    ) {
        match (self, src) {
            (KvStore::F32 { k, v }, KvStore::F32 { k: sk, v: sv }) => {
                for li in 0..n_layer {
                    let base = li * bt * d;
                    k[base..base + rows * d].copy_from_slice(&sk[base..base + rows * d]);
                    v[base..base + rows * d].copy_from_slice(&sv[base..base + rows * d]);
                }
            }
            (
                KvStore::Q8 { dtype, k, v, k_amax, v_amax },
                KvStore::Q8 { dtype: sd, k: sk, v: sv, k_amax: ska, v_amax: sva },
            ) => {
                debug_assert_eq!(dtype, sd, "pool blocks share one dtype");
                for li in 0..n_layer {
                    let base = li * bt * d;
                    k[base..base + rows * d].copy_from_slice(&sk[base..base + rows * d]);
                    v[base..base + rows * d].copy_from_slice(&sv[base..base + rows * d]);
                }
                k_amax.copy_from_slice(ska);
                v_amax.copy_from_slice(sva);
            }
            _ => unreachable!("pool blocks share one dtype"),
        }
    }

    /// Borrowed fp32 row slices for layer `li` (`rows × d`). F32 stores
    /// only — the zero-copy fast path.
    pub fn f32_slices(&self, li: usize, rows: usize, bt: usize, d: usize) -> (&[f32], &[f32]) {
        match self {
            KvStore::F32 { k, v } => {
                let base = li * bt * d;
                (&k[base..base + rows * d], &v[base..base + rows * d])
            }
            KvStore::Q8 { .. } => unreachable!("quantized blocks dequantize via scratch"),
        }
    }

    /// Borrowed *code* slices for layer `li` (`rows × d` raw bytes each)
    /// plus the layer's effective K and V scales — the quantized-domain
    /// read path ([`super::qattn`]): attention decodes elements in
    /// register (`code · scale`, the exact op [`Self::dequant_into`]
    /// applies) instead of staging an fp32 copy in scratch. Q8 stores
    /// only.
    pub fn code_slices(
        &self,
        li: usize,
        rows: usize,
        bt: usize,
        d: usize,
    ) -> (&[u8], &[u8], f32, f32) {
        match self {
            KvStore::F32 { .. } => unreachable!("f32 blocks read zero-copy via f32_slices"),
            KvStore::Q8 { dtype, k, v, k_amax, v_amax } => {
                let base = li * bt * d;
                let ks = k_amax[li] / dtype.code_max();
                let vs = v_amax[li] / dtype.code_max();
                (&k[base..base + rows * d], &v[base..base + rows * d], ks, vs)
            }
        }
    }

    /// Dequantize the first `rows` rows of layer `li` into `k_out` /
    /// `v_out` (each `rows × d`).
    pub fn dequant_into(
        &self,
        li: usize,
        rows: usize,
        bt: usize,
        d: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        debug_assert_eq!(k_out.len(), rows * d);
        debug_assert_eq!(v_out.len(), rows * d);
        match self {
            KvStore::F32 { k, v } => {
                let base = li * bt * d;
                k_out.copy_from_slice(&k[base..base + rows * d]);
                v_out.copy_from_slice(&v[base..base + rows * d]);
            }
            KvStore::Q8 { dtype, k, v, k_amax, v_amax } => {
                let base = li * bt * d;
                let ks = k_amax[li] / dtype.code_max();
                let vs = v_amax[li] / dtype.code_max();
                for (o, b) in k_out.iter_mut().zip(&k[base..base + rows * d]) {
                    *o = dec(*dtype, ks, *b);
                }
                for (o, b) in v_out.iter_mut().zip(&v[base..base + rows * d]) {
                    *o = dec(*dtype, vs, *b);
                }
            }
        }
    }
}

/// Append one row to a quantized layer slab, growing the scale (and
/// requantizing the `row` prior rows) when the new row's max-abs
/// exceeds the running `amax`.
fn write_side(dtype: KvDtype, slab: &mut [u8], amax: &mut f32, row: usize, d: usize, vals: &[f32]) {
    debug_assert_eq!(vals.len(), d);
    let m = vals.iter().fold(0.0f32, |a, x| a.max(x.abs()));
    if m > *amax {
        let old_scale = *amax / dtype.code_max();
        *amax = m;
        let new_scale = m / dtype.code_max();
        if old_scale > 0.0 {
            for b in slab[..row * d].iter_mut() {
                *b = enc(dtype, new_scale, dec(dtype, old_scale, *b));
            }
        }
    }
    let s = *amax / dtype.code_max();
    for (c, x) in slab[row * d..(row + 1) * d].iter_mut().zip(vals) {
        *c = enc(dtype, s, *x);
    }
}

/// Reusable dequantization arena for [`super::BlockPool::layer_views`]:
/// owns the fp32 buffers quantized blocks decode into, so attention can
/// keep borrowing plain `&[f32]` segments whatever the pool dtype. The
/// buffers persist across calls (cleared, not freed) — one scratch per
/// forward pass amortizes the allocations across layers.
#[derive(Debug, Default)]
pub struct KvScratch {
    bufs: Vec<Vec<f32>>,
    used: usize,
    /// Heap-allocation events (new buffer pushed, or an existing buffer
    /// regrown past its capacity). A warm scratch reused across rounds
    /// of the same shape must not advance this — the no-per-round-
    /// allocation tests pin that.
    allocs: u64,
}

impl KvScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocation events so far (see the field doc). Monotonic; never
    /// reset so tests can difference across rounds.
    pub fn alloc_events(&self) -> u64 {
        self.allocs
    }

    pub(crate) fn reset(&mut self) {
        self.used = 0;
    }

    /// Claim a buffer of `len` floats; returns its index. Contents are
    /// unspecified (recycled buffers keep stale data) — the fill phase
    /// in [`super::BlockPool::layer_views`] overwrites every row before
    /// any view is taken, so re-zeroing here would only double the
    /// memory writes of the dequant hot path.
    pub(crate) fn take(&mut self, len: usize) -> usize {
        if self.used == self.bufs.len() {
            self.bufs.push(Vec::with_capacity(len));
            self.allocs += 1;
        }
        let i = self.used;
        self.used += 1;
        let b = &mut self.bufs[i];
        if b.capacity() < len {
            self.allocs += 1;
        }
        b.resize(len, 0.0);
        i
    }

    pub(crate) fn buf(&self, i: usize) -> &[f32] {
        &self.bufs[i]
    }

    /// Two distinct buffers mutably at once (`i < j` — `take` hands out
    /// ascending indices, so a K/V pair always satisfies this).
    pub(crate) fn bufs_pair_mut(&mut self, i: usize, j: usize) -> (&mut [f32], &mut [f32]) {
        assert!(i < j, "pair indices must be distinct and ascending");
        let (a, b) = self.bufs.split_at_mut(j);
        (&mut a[i], &mut b[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp8_codec_roundtrips_every_byte() {
        // Every non-NaN byte decodes to a finite on-grid value and
        // re-encodes to itself (modulo -0 → +0).
        for b in 0..=255u8 {
            if b & 0x7f == 0x7f {
                continue; // OCP NaN patterns — never produced
            }
            let x = fp8_e4m3_decode(b);
            assert!(x.is_finite() && x.abs() <= 448.0, "byte {b:#04x} → {x}");
            let back = fp8_e4m3_encode(x);
            if b == 0x80 {
                assert!(back == 0x80 || back == 0, "-0 may normalize");
            } else {
                assert_eq!(back, b, "byte {b:#04x} → {x} → {back:#04x}");
            }
        }
    }

    #[test]
    fn fp8_encode_matches_grid_quantizer() {
        // decode(encode(x)) must equal NumFormat::Fp8E4M3.quantize(x):
        // the byte codec and the eval-path quantizer share one grid.
        let mut x = -500.0f32;
        while x < 500.0 {
            let via_codec = fp8_e4m3_decode(fp8_e4m3_encode(x));
            let via_grid = NumFormat::Fp8E4M3.quantize(x);
            assert_eq!(via_codec, via_grid, "x = {x}");
            x += 0.173;
        }
    }

    #[test]
    fn int8_write_read_roundtrip_is_tight() {
        let (bt, d) = (4, 8);
        let mut s = KvStore::new(KvDtype::Int8, 1, bt, d);
        let row: Vec<f32> = (0..d).map(|i| (i as f32 - 3.5) * 0.25).collect();
        s.write_row(0, 0, bt, d, &row, &row);
        let mut k = vec![0.0; d];
        let mut v = vec![0.0; d];
        s.dequant_into(0, 1, bt, d, &mut k, &mut v);
        let amax = row.iter().fold(0.0f32, |a, x| a.max(x.abs()));
        for (got, want) in k.iter().zip(&row) {
            assert!((got - want).abs() <= amax / 254.0 + 1e-7, "{got} vs {want}");
        }
        assert_eq!(k, v);
    }

    #[test]
    fn growing_amax_requantizes_prior_rows() {
        let (bt, d) = (4, 4);
        let mut s = KvStore::new(KvDtype::Int8, 1, bt, d);
        s.write_row(0, 0, bt, d, &[0.1, -0.2, 0.3, 0.05], &[0.0; 4]);
        // Second row is 100× larger: row 0 must survive the rescale.
        s.write_row(0, 1, bt, d, &[30.0, -10.0, 5.0, 1.0], &[0.0; 4]);
        let mut k = vec![0.0; 2 * d];
        let mut v = vec![0.0; 2 * d];
        s.dequant_into(0, 2, bt, d, &mut k, &mut v);
        // Row 0 is now on a 30/127 ≈ 0.24 grid: coarse but centered.
        for (got, want) in k[..d].iter().zip(&[0.1, -0.2, 0.3, 0.05]) {
            assert!((got - want).abs() <= 30.0 / 127.0, "{got} vs {want}");
        }
        for (got, want) in k[d..].iter().zip(&[30.0, -10.0, 5.0, 1.0]) {
            assert!((got - want).abs() <= 30.0 / 254.0 + 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn reset_clears_scales_for_slot_reuse() {
        let (bt, d) = (2, 2);
        let mut s = KvStore::new(KvDtype::Fp8E4M3, 1, bt, d);
        s.write_row(0, 0, bt, d, &[100.0, -100.0], &[7.0, 7.0]);
        s.reset();
        s.write_row(0, 0, bt, d, &[0.01, 0.02], &[0.01, 0.02]);
        let mut k = vec![0.0; d];
        let mut v = vec![0.0; d];
        s.dequant_into(0, 1, bt, d, &mut k, &mut v);
        // Under the stale 100.0 scale these would collapse to ~0 codes;
        // after reset they round-trip within fp8 relative error.
        assert!((k[0] - 0.01).abs() < 0.01 * 0.07, "stale scale survived reset: {}", k[0]);
        assert!((k[1] - 0.02).abs() < 0.02 * 0.07);
    }

    #[test]
    fn scratch_reuses_capacity_across_rounds() {
        let mut s = KvScratch::new();
        // Cold round: allocations expected.
        s.reset();
        assert_eq!(s.take(64), 0);
        assert_eq!(s.take(128), 1);
        assert!(s.alloc_events() > 0);
        let warm = s.alloc_events();
        // Warm rounds of the same shape: zero new allocations.
        for _ in 0..10 {
            s.reset();
            s.take(64);
            s.take(128);
        }
        assert_eq!(s.alloc_events(), warm, "warm rounds must not allocate");
        // Growing a buffer past capacity is an allocation event again.
        s.reset();
        s.take(256);
        assert!(s.alloc_events() > warm);
    }

    #[test]
    fn code_slices_match_dequant_into() {
        let (bt, d) = (4, 8);
        let mut s = KvStore::new(KvDtype::Int8, 2, bt, d);
        for r in 0..3 {
            let row: Vec<f32> = (0..d).map(|i| ((r * d + i) as f32).sin() * 2.0).collect();
            for li in 0..2 {
                s.write_row(li, r, bt, d, &row, &row);
            }
        }
        for li in 0..2 {
            let (kc, vc, ks, vs) = s.code_slices(li, 3, bt, d);
            let mut k = vec![0.0; 3 * d];
            let mut v = vec![0.0; 3 * d];
            s.dequant_into(li, 3, bt, d, &mut k, &mut v);
            for (i, (&b, &want)) in kc.iter().zip(&k).enumerate() {
                assert_eq!((b as i8) as f32 * ks, want, "k elem {i}");
            }
            for (i, (&b, &want)) in vc.iter().zip(&v).enumerate() {
                assert_eq!((b as i8) as f32 * vs, want, "v elem {i}");
            }
        }
    }

    #[test]
    fn identical_write_histories_produce_identical_bytes() {
        // The determinism freeze-time dedup depends on: same rows in the
        // same order ⇒ same codes and scales, even across rescales.
        let (bt, d) = (4, 8);
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..d).map(|i| ((r * d + i) as f32).sin() * (r as f32 + 0.1)).collect())
            .collect();
        let mut a = KvStore::new(KvDtype::Int8, 2, bt, d);
        let mut b = KvStore::new(KvDtype::Int8, 2, bt, d);
        for (r, row) in rows.iter().enumerate() {
            for li in 0..2 {
                a.write_row(li, r, bt, d, row, row);
                b.write_row(li, r, bt, d, row, row);
            }
        }
        match (&a, &b) {
            (
                KvStore::Q8 { k, v, k_amax, v_amax, .. },
                KvStore::Q8 { k: k2, v: v2, k_amax: ka2, v_amax: va2, .. },
            ) => {
                assert_eq!(k, k2);
                assert_eq!(v, v2);
                assert_eq!(k_amax, ka2);
                assert_eq!(v_amax, va2);
            }
            _ => unreachable!(),
        }
    }
}
