//! GPTQ / OPTQ weight quantization (Frantar et al., 2023).
//!
//! The Table-2 "S-GPTQ-W4" rows are weight-only 4-bit quantization with
//! OBS error compensation — the quantization twin of SparseGPT's
//! pruning (same `U = chol(H⁻¹)` factor, same blocked lazy updates):
//! columns are quantized left→right, and each column's quantization
//! error `(w−q)/U_jj` is folded into the not-yet-quantized columns.
//!
//! Group (Q-vector) scales are computed when the group's first column is
//! reached, from the *current* (error-compensated) weights — matching
//! GPTQ's `groupsize` behaviour.

use anyhow::{anyhow, bail};

use super::linalg::SquareMat;
use crate::formats::NumFormat;
use crate::tensor::Matrix;
use crate::util::par::par_chunks_mut;
use crate::Result;

/// Lazy-update block (columns); multiple of all supported Q-vector sizes.
const BLOCK: usize = 128;
const PERC_DAMP: f64 = 0.01;

/// Quantize `w` in place (fake-quant: values land on the dequantized
/// grid) with OBS error compensation.
pub fn gptq_fake_quant(
    w: &mut Matrix,
    gram: &SquareMat,
    fmt: NumFormat,
    qvec: usize,
    scale_fmt: NumFormat,
) -> Result<()> {
    let d = w.cols;
    if gram.d != d {
        bail!("gram width {} != weight width {d}", gram.d);
    }
    if d % qvec != 0 {
        bail!("in_features {d} not a multiple of qvec {qvec}");
    }
    let mut h = gram.clone();
    for i in 0..d {
        if h.at(i, i) == 0.0 {
            *h.at_mut(i, i) = 1.0;
        }
    }
    h.add_diag(PERC_DAMP * h.diag_mean());
    let hinv = h.spd_inverse().ok_or_else(|| anyhow!("Hessian not SPD"))?;
    let u = hinv.cholesky_upper().ok_or_else(|| anyhow!("H⁻¹ not SPD"))?;

    let bs = BLOCK.max(qvec);
    par_chunks_mut(&mut w.data, d, |_r, row| {
        let mut err = vec![0.0f64; bs];
        let mut scale = 1.0f32;
        let mut i1 = 0;
        while i1 < d {
            let i2 = (i1 + bs).min(d);
            err[..i2 - i1].fill(0.0);
            for j in i1..i2 {
                if j % qvec == 0 {
                    // Group scale from the current compensated weights.
                    let grp = &row[j..(j + qvec).min(d)];
                    let max_abs = grp.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                    let raw = max_abs / fmt.max_value();
                    scale = if raw > 0.0 { scale_fmt.quantize(raw).max(1e-30) } else { 1.0 };
                }
                let q = fmt.quantize(row[j] / scale) * scale;
                let e = (row[j] - q) as f64 / u.at(j, j);
                row[j] = q;
                err[j - i1] = e;
                if e != 0.0 {
                    for k in j + 1..i2 {
                        row[k] -= (e * u.at(j, k)) as f32;
                    }
                }
            }
            for (jj, &e) in err[..i2 - i1].iter().enumerate() {
                if e == 0.0 {
                    continue;
                }
                let j = i1 + jj;
                for k in i2..d {
                    row[k] -= (e * u.at(j, k)) as f32;
                }
            }
            i1 = i2;
        }
    });
    Ok(())
}

/// Proxy output error (same quadratic form as the pruners use).
pub fn output_error(orig: &Matrix, quant: &Matrix, gram: &SquareMat) -> f64 {
    super::sparsify::output_error_proxy(orig, quant, gram)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sdq::calib::CalibStats;
    use crate::sdq::quantize::{fake_quant, VsQuantCfg};
    use crate::util::rng::Rng;

    fn correlated_calib(d: usize, seed: u64) -> CalibStats {
        let mut rng = Rng::seed_from_u64(seed);
        let mut st = CalibStats::new(true);
        let mut x = Matrix::zeros(256, d);
        for t in 0..x.rows {
            let base = rng.normal();
            for j in 0..d {
                *x.at_mut(t, j) = 0.6 * base + rng.normal();
            }
        }
        st.observe("l", &x);
        st
    }

    #[test]
    fn gptq_respects_grid_scale_structure() {
        let d = 64;
        let mut rng = Rng::seed_from_u64(1);
        let mut w = Matrix::from_vec(8, d, (0..8 * d).map(|_| rng.normal()).collect());
        let st = correlated_calib(d, 2);
        let gram = st.get("l").unwrap().finalized_gram().unwrap();
        gptq_fake_quant(&mut w, &gram, NumFormat::Int(4), 16, NumFormat::Fp8E4M3).unwrap();
        // every value must be scale·grid-code; verify via per-group
        // requantization being a fixed point
        for r in 0..w.rows {
            for g in 0..d / 16 {
                let grp = &w.row(r)[g * 16..(g + 1) * 16];
                let max_abs = grp.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                if max_abs == 0.0 {
                    continue;
                }
                // 15 distinct |values| at most for int4
                let mut vals: Vec<i64> = Vec::new();
                let step = grp.iter().filter(|v| **v != 0.0).fold(f32::MAX, |m, v| m.min(v.abs()));
                for v in grp {
                    vals.push((v / step).round() as i64);
                }
                for (v, q) in grp.iter().zip(&vals) {
                    assert!((v - *q as f32 * step).abs() < step * 0.51, "off-grid value {v}");
                }
            }
        }
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_activations() {
        let d = 64;
        let mut rng = Rng::seed_from_u64(3);
        let orig = Matrix::from_vec(16, d, (0..16 * d).map(|_| rng.normal()).collect());
        let st = correlated_calib(d, 4);
        let gram = st.get("l").unwrap().finalized_gram().unwrap();

        let mut w_gptq = orig.clone();
        gptq_fake_quant(&mut w_gptq, &gram, NumFormat::Int(4), 16, NumFormat::Fp8E4M3)
            .unwrap();
        let w_rtn = fake_quant(
            &orig,
            VsQuantCfg { fmt: NumFormat::Int(4), qvec: 16, scale_fmt: NumFormat::Fp8E4M3 },
        );
        let e_gptq = output_error(&orig, &w_gptq, &gram);
        let e_rtn = output_error(&orig, &w_rtn, &gram);
        assert!(
            e_gptq < e_rtn,
            "GPTQ output error {e_gptq} must beat RTN {e_rtn} on correlated data"
        );
    }

    #[test]
    fn gptq_rejects_bad_shapes() {
        let mut w = Matrix::zeros(2, 60); // not a multiple of qvec 16
        let gram = SquareMat::identity(60);
        assert!(gptq_fake_quant(&mut w, &gram, NumFormat::Int(4), 16, NumFormat::Fp8E4M3)
            .is_err());
    }
}
