//! # SDQ — Sparse Decomposed Quantization for LLM Inference
//!
//! Full-system reproduction of *SDQ: Sparse Decomposed Quantization for
//! LLM Inference* (Jeong, Tsai, Keckler, Krishna; cs.LG 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the compression library (sparsify → decompose
//!   → quantize), the serving coordinator, the analytical performance
//!   model for N:M structured-sparse tensor-core hardware, and every
//!   substrate the paper's evaluation depends on (transformer inference
//!   engine, perplexity / zero-shot harness, synthetic corpus).
//! * **L2 (python/compile/model.py)** — JAX model graphs lowered AOT to
//!   HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the decomposed
//!   dual-quantized GEMM hot spot (interpret=True for CPU PJRT).
//!
//! Python never runs on the request path: the [`runtime`] module loads
//! the AOT artifacts via PJRT and the coordinator serves from Rust.
//!
//! ## Serving architecture: paged KV + ragged batching
//!
//! Both serving phases are **batched across sequences**, not across
//! time. Each scheduler round packs every prompt admitted that round
//! into one fused ragged prefill and stacks the last token of every
//! active sequence into one `[n_active, d]` decode batch
//! ([`model::Model::forward_paged`]), so every (compressed) weight
//! matrix streams from memory once per round instead of once per
//! sequence — the regime where SDQ's compressed formats actually pay
//! off. Attention stays per-sequence (ragged KV prefix lengths,
//! parallel over `(sequence, head)`) and *borrows* each sequence's KV
//! in place.
//!
//! Decode can additionally run **speculatively** ([`spec`]): an n-gram
//! or aggressively-SDQ-compressed drafter proposes `k` tokens per
//! sequence per round, one fused verify pass scores all of them, the
//! longest greedy-exact prefix is kept (speculative output is
//! bit-identical to plain greedy decode), and rejected tokens roll back
//! by truncating the sequence's block table.
//!
//! KV memory is a shared, decomposed resource ([`kv::BlockPool`]):
//! fixed-size ref-counted blocks addressed by content, so identical
//! prompt prefixes resolve to the same physical blocks
//! (`attach_prefix`), finished sequences leave their blocks cached for
//! future hits until LRU eviction reclaims them, and forked sequences
//! copy-on-write at divergence. The coordinator admits against pool
//! free blocks ([`coordinator::scheduler::Scheduler`]), and the chunked
//! per-request [`model::generate::KvCache`] survives as the
//! per-sequence baseline the serving benchmark A/Bs against. Under
//! `BatchPolicy::preempt` the scheduler **oversubscribes** instead of
//! reserving worst-case footprints: sequences swap out to byte-exact
//! [`kv::Snapshot`]s under pressure and swap back in ahead of new
//! admissions — same greedy tokens, more admitted work per block.
//!
//! ## Quick tour
//!
//! ```no_run
//! use sdq::sdq::config::CompressionConfig;
//! // Parse the paper's own configuration naming scheme:
//! let cfg: CompressionConfig = "SDQ-W7:8-1:8int8-6:8fp4".parse().unwrap();
//! assert_eq!(cfg.effective_throughput(), 4.0);
//! ```
//!
//! Serve a batch through the coordinator (greedy decode is
//! bit-identical to per-request [`model::Model::generate`]):
//!
//! ```no_run
//! use sdq::coordinator::{batcher::BatchPolicy, Engine, Request};
//! # let model = sdq::model::testutil::tiny_model(sdq::model::Arch::Gpt, 1);
//! let reqs: Vec<Request> =
//!     (0..8).map(|i| Request::new(i, vec![65u8; 16], 32)).collect();
//! let (_responses, metrics) = Engine::run_batch(model, BatchPolicy::default(), reqs);
//! println!("{} — occupancy {:.2}", metrics.summary(), metrics.decode_occupancy(8));
//! ```

pub mod artifacts;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod formats;
pub mod gateway;
pub mod harness;
pub mod kv;
pub mod model;
pub mod perfmodel;
pub mod router;
pub mod runtime;
pub mod sdq;
pub mod spec;
pub mod swap;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
