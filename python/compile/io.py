"""SDQW1 weight-bundle writer/reader — python mirror of
`rust/src/artifacts.rs` (the interchange format between the JAX trainer
and the Rust engine)."""

from __future__ import annotations

import json
import struct
from pathlib import Path

import numpy as np

MAGIC = b"SDQW1\n"


def save_weights(path, config: dict, tensors: dict[str, np.ndarray]) -> None:
    """Write a bundle. Tensors are stored sorted by name (matching the
    Rust side's BTreeMap ordering) as little-endian f32."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    entries = []
    offset = 0
    names = sorted(tensors)
    for name in names:
        a = np.asarray(tensors[name], dtype=np.float32)
        if a.ndim == 1:
            a = a[None, :]
        assert a.ndim == 2, f"{name}: tensors must be 1-D or 2-D"
        entries.append(
            {"name": name, "rows": a.shape[0], "cols": a.shape[1], "offset": offset}
        )
        offset += a.size
    header = json.dumps({"config": config, "tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(header)))
        f.write(header)
        for name in names:
            a = np.asarray(tensors[name], dtype=np.float32)
            f.write(a.astype("<f4").tobytes())


def load_weights(path) -> tuple[dict, dict[str, np.ndarray]]:
    """Read a bundle back (tests + aot reuse)."""
    with open(path, "rb") as f:
        magic = f.read(6)
        assert magic == MAGIC, f"{path}: bad magic"
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        data = np.frombuffer(f.read(), dtype="<f4")
    tensors = {}
    for t in header["tensors"]:
        n = t["rows"] * t["cols"]
        tensors[t["name"]] = (
            data[t["offset"] : t["offset"] + n].reshape(t["rows"], t["cols"]).copy()
        )
    return header["config"], tensors
